"""Deterministic synthetic token pipeline (host-sharded, restart-safe).

Every (step, host) pair maps to a unique counter-based RNG stream, so:
  * restarts resume mid-epoch exactly (the checkpoint stores only `step`);
  * elastic re-meshing re-partitions deterministically (host h of H hosts
    always draws the same global batch rows h::H);
  * straggler back-up workers can recompute any row independently.

The stream is a Zipf-ish token distribution with induced bigram structure
(so models actually learn during the example runs rather than staying at
uniform entropy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataCfg:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenPipeline:
    def __init__(self, cfg: DataCfg, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // n_hosts
        # stationary unigram distribution (Zipf over a permuted vocab)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab)

    def _row(self, step: int, row: int) -> np.ndarray:
        """One deterministic (seq_len+1)-token row."""
        rng = np.random.default_rng(
            (self.cfg.seed, step, row))
        toks = rng.choice(self.cfg.vocab, size=self.cfg.seq_len + 1,
                          p=self._probs)
        # bigram structure: with p=.5 the next token is a function of the
        # previous one (learnable signal)
        follow = rng.random(self.cfg.seq_len + 1) < 0.5
        shifted = (toks * 31 + 7) % self.cfg.vocab
        toks = np.where(follow, np.roll(shifted, 1), toks)
        return self._perm[toks].astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = [self._row(step, self.host_id + self.n_hosts * i)
                for i in range(self.local_batch)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "targets": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def stub_frames(batch: int, t: int, d: int, step: int = 0,
                dtype=np.float32) -> np.ndarray:
    """Deterministic stand-in for the audio conv frontend / ViT patches."""
    rng = np.random.default_rng((1234, step))
    return rng.standard_normal((batch, t, d)).astype(dtype) * 0.02
