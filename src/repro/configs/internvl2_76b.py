"""internvl2-76b: InternViT (stubbed patch embeddings) + 80L LLM backbone
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[arXiv:2404.16821; unverified]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    arch_id="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, head_dim=128, activation="swiglu", rope_theta=500000.0,
    n_patches=256,
    source="arXiv:2404.16821; unverified",
))
