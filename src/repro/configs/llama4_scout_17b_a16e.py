"""llama4-scout-17b-a16e: 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoESpec, register

CFG = register(ArchConfig(
    arch_id="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, activation="swiglu", rope_theta=500000.0,
    moe=MoESpec(n_experts=16, top_k=1, shared_expert=True),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
