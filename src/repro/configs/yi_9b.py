"""yi-9b: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
(llama-arch GQA). [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    arch_id="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab=64000, head_dim=128, activation="swiglu",
    source="arXiv:2403.04652; hf",
))
