"""zamba2-2.7b: 54 Mamba2 layers d_model=2560 + shared attention block
(32H kv=32, d_ff=10240) applied periodically, ssm_state=64, vocab=32000.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig, SSMSpec, register

CFG = register(ArchConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, activation="gelu", share_every=6,
    ssm=SSMSpec(d_state=64, expand=2, d_conv=4, head_dim=64),
    source="arXiv:2411.15242; hf",
))
