"""qwen1.5-4b: 40L d_model=2560 20H (kv=20, MHA) d_ff=6912 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    arch_id="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, head_dim=128, qkv_bias=True, activation="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
))
