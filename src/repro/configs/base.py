"""Architecture + shape configuration registry.

Every assigned architecture provides one ``ArchConfig`` (exact dims from the
assignment table) plus a ``reduced()`` smoke-test variant. Shapes are the
four assigned input-shape cells; ``long_500k`` is only *runnable* for
sub-quadratic archs (ssm / hybrid) — full-attention archs record a skip
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    shared_expert: bool = False
    capacity_factor: float = 1.25
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncDecSpec:
    n_enc_layers: int = 6
    enc_len: int = 1500          # whisper 30 s -> 1500 frames (stub input)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    activation: str = "swiglu"
    norm: str = "rms"            # rms | ln
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    residual_scale: float = 1.0  # minicpm depth-scaled residuals
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    share_every: int = 0         # hybrid: shared attn block cadence
    encdec: Optional[EncDecSpec] = None
    n_patches: int = 256         # vlm stub patch count
    dtype: str = "bfloat16"
    remat: bool = True           # activation checkpointing per layer
    attention_impl: str = "full"     # full | chunked (online-softmax scan)
    attention_chunk: int = 1024
    moe_impl: str = "shard_map"      # shard_map (local EP, §Perf A2: 149x
                                     #   less collective) | gspmd (baseline)
    source: str = ""             # provenance tag from the assignment

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 so the vocab
        dim shards evenly over the 16-way model axis (padded logit columns
        are masked in the loss and at decode). Standard production practice
        (MaxText/Megatron pad vocab the same way)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True              # all assigned archs decode (none enc-only)

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration: same family, tiny dims."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=2 if self.share_every == 0 else max(2, 2 * 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
        )
        if self.moe:
            kw["moe"] = MoESpec(n_experts=4,
                                top_k=min(self.moe.top_k, 2),
                                shared_expert=self.moe.shared_expert)
        else:
            kw["moe"] = None
        if self.ssm:
            kw["ssm"] = SSMSpec(d_state=16, expand=2, d_conv=4, head_dim=16,
                                chunk=16)
        else:
            kw["ssm"] = None
        if self.share_every:
            kw["share_every"] = 2
            kw["n_layers"] = 4
        if self.encdec:
            kw["encdec"] = EncDecSpec(n_enc_layers=2, enc_len=32)
        kw["n_patches"] = 8 if self.family == "vlm" else self.n_patches
        kw["remat"] = False
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ArchConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all() -> None:
    """Import every config module (they self-register)."""
    from repro.configs import (llama4_scout_17b_a16e, granite_moe_3b_a800m,  # noqa
                               minicpm_2b, internlm2_20b, qwen1_5_4b, yi_9b,
                               mamba2_1_3b, zamba2_2_7b, internvl2_76b,
                               whisper_base)


def cell_runnable(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (False, reason) if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, ("full quadratic attention at 524k context is not "
                       "deployable; arch ships no sub-quadratic variant "
                       "(DESIGN.md §4)")
    return True, ""
