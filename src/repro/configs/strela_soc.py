"""The paper's own system configuration (Sec. VI-A): the STRELA SoC.

Not an LM architecture — this config parameterizes the fidelity layer
(fabric dimensions, bus, clock, memory map) and is what the Table I/II
benchmarks instantiate. Kept alongside the LM configs per the repository
layout convention (configs/ holds every selectable system).
"""
from __future__ import annotations

import dataclasses

from repro.core.fabric import Fabric
from repro.core.streams import BusConfig


@dataclasses.dataclass(frozen=True)
class StrelaSoC:
    # CGRA fabric: 4x4 PEs, 32-bit datapath (Sec. VI-A)
    rows: int = 4
    cols: int = 4
    datapath_bits: int = 32
    n_imns: int = 4
    n_omns: int = 4
    # memory subsystem: 8 x 32 KiB banks, last 4 interleaved
    n_banks_total: int = 8
    bank_kib: int = 32
    n_interleaved: int = 4
    # clocking / process (for energy conversion)
    clock_mhz: float = 250.0
    process: str = "TSMC 65nm LP"
    # control core
    cpu: str = "CV32E40P (RV32IMC, 4-stage, -O3)"

    def fabric(self) -> Fabric:
        return Fabric(rows=self.rows, cols=self.cols, n_imns=self.n_imns,
                      n_omns=self.n_omns)

    def bus(self) -> BusConfig:
        return BusConfig(n_banks=self.n_interleaved)

    def peak_gops(self) -> float:
        """All 16 FUs firing every cycle at 250 MHz = 4.0 GOPs theoretical;
        the paper's measured peak (fft) is bus-limited at 1.22 GOPs."""
        return self.rows * self.cols * self.clock_mhz / 1e3


SOC = StrelaSoC()
