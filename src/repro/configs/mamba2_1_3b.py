"""mamba2-1.3b: 48L d_model=2048 attention-free SSD (state-space duality),
ssm_state=128, vocab=50280. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMSpec, register

CFG = register(ArchConfig(
    arch_id="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, tie_embeddings=True,
    ssm=SSMSpec(d_state=128, expand=2, d_conv=4, head_dim=64),
    source="arXiv:2405.21060; unverified",
))
