"""whisper-base: enc-dec, 6L encoder + 6L decoder, d_model=512 8H (MHA)
d_ff=2048 vocab=51865; conv frontend stubbed (precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, EncDecSpec, register

CFG = register(ArchConfig(
    arch_id="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64, activation="gelu", norm="ln",
    tie_embeddings=True, encdec=EncDecSpec(n_enc_layers=6, enc_len=1500),
    source="arXiv:2212.04356; unverified",
))
