"""minicpm-2b: 40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753;
WSD schedule, depth-scaled residuals, tied embeddings (llama-like arch).
[arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    arch_id="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64, activation="swiglu", tie_embeddings=True,
    residual_scale=1.4 / (40 ** 0.5),      # scale_depth / sqrt(L)
    source="arXiv:2404.06395; hf",
))
