"""Class-affinity placement: the fleet's cost model + router.

Placement answers one question per arriving request: *which fabric runs
it?* The answer is driven by a **measured** cost model, not a guess —
geometry genuinely changes modeled cost on this fabric family (the config
fetch path scales with rows, so a 2x2 serves relu ~8% cheaper than a 4x4;
fft needs column width and costs ~3x more on a 2x2; ``div_loop`` does not
map below a 4x4 at all). The model is built once per fleet by compiling
every class recipe against every fabric geometry and replaying one seeded
request through a throwaway engine, so the cost of a class on a fabric is
the same quantity the serving clock will charge: modeled execution cycles
times ``us_per_cycle``, plus the amortized share of the configuration
fetch a continuous batch pays.

The :class:`Router` then pins each class to its cheapest feasible fabric
(**class affinity** — keeps each fabric's continuous batcher fed with
same-class runs, which is where PR 8's config-amortization wins live) and
**work-steals** past the pin when the pinned fabric's queue is deep:
overflow goes to the least-loaded feasible live peer. Both decisions are
pure functions of (cost table, queue state), so the fleet trace digest
stays a pure function of (seed, FleetConfig).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClassCost:
    """Measured cost of one config class on one fabric geometry."""

    label: str
    geometry: Tuple[int, int, int, int]
    feasible: bool
    service_us: float = float("inf")   # modeled batch-amortized us/request
    exec_cycles: int = 0               # total modeled cycles of one run
    config_cycles: int = 0             # full (cold) configuration fetch
    n_shots: int = 1
    error: str = ""                    # why infeasible (named diagnostic)


def measure_class_costs(geometry: Tuple[int, int, int, int],
                        labels: Sequence[str], length: int,
                        us_per_cycle: float, max_batch: int,
                        backend: str = "sim", cache=None
                        ) -> Tuple[Dict[str, ClassCost], Dict[str, object]]:
    """Compile every class recipe against ``geometry`` and measure one
    seeded request through a throwaway engine.

    Returns ``(costs, artifacts)``; an infeasible class (compile or
    capability failure — e.g. ``div_loop`` below 4x4) gets a named
    ``ClassCost(feasible=False)`` and no artifact. The throwaway engine
    shares the caller's artifact cache, so fleet workers (and later
    processes) reuse the compile + timing traces instead of repeating
    them; its cycle tally never touches any worker's ledger.

    ``service_us`` amortizes the cold configuration fetch across a full
    ``max_batch`` — the steady-state quantity the continuous batcher
    actually charges — so the router compares fabrics on what serving
    them costs, not on worst-case cold dispatch.
    """
    from repro.core.fabric import Fabric
    from repro.engine.scheduler import Engine
    from repro.serve.load import (compile_recipe, mix_recipes,
                                  request_inputs)

    rows, cols, n_imns, n_omns = geometry
    eng = Engine(Fabric(rows=rows, cols=cols, n_imns=n_imns,
                        n_omns=n_omns), backend=backend, cache=cache)
    # the full label namespace (paper + model-layer classes), so fleet
    # configs can mix both without a second resolution path
    recipes = mix_recipes(length, "all")
    costs: Dict[str, ClassCost] = {}
    artifacts: Dict[str, object] = {}
    rng = np.random.default_rng(0)     # fixed probe seed: the cost table
    #                                    must not depend on the soak seed
    for label in labels:
        if label not in recipes:
            raise ValueError(f"unknown config class {label!r} "
                             f"(have {sorted(recipes)})")
        try:
            art = compile_recipe(eng, label, length, recipes)
            before = eng.tally.total
            eng.run(art, request_inputs(art, length, rng, label=label))
            exec_cycles = eng.tally.total - before
        except Exception as e:
            costs[label] = ClassCost(
                label=label, geometry=geometry, feasible=False,
                error=f"{type(e).__name__}: {e}")
            continue
        cfg = art.config_cycles()
        # a cold run measured above includes the full config fetch; the
        # batcher pays it once per max_batch same-class requests
        amortized = exec_cycles - cfg + cfg / max(1, max_batch)
        costs[label] = ClassCost(
            label=label, geometry=geometry, feasible=True,
            service_us=amortized * us_per_cycle,
            exec_cycles=int(exec_cycles), config_cycles=int(cfg),
            n_shots=art.n_shots)
        artifacts[label] = art
    return costs, artifacts


class UnroutableError(RuntimeError):
    """No live fabric in the fleet can serve a class — named rejection,
    mirroring ``AdmissionError``'s style."""


class Router:
    """Deterministic class-affinity placement over an ordered worker set.

    ``ranked[label]`` is the full feasibility-filtered preference list.
    Ties on cost (homogeneous fleets) break by a per-class *rotated*
    worker index, so six classes over four identical fabrics pin
    round-robin instead of piling onto worker 0.
    """

    def __init__(self, workers: Sequence[str],
                 costs: Dict[str, Dict[str, ClassCost]],
                 steal_depth: int):
        # costs: {worker_name: {label: ClassCost}}
        self.workers = list(workers)
        self.steal_depth = steal_depth
        self.ranked: Dict[str, List[str]] = {}
        labels = sorted({l for per in costs.values() for l in per})
        for rank, label in enumerate(labels):
            feas = [(costs[w][label].service_us, i, w)
                    for i, w in enumerate(self.workers)
                    if label in costs[w] and costs[w][label].feasible]
            feas.sort()
            # rotate every equal-cost run by the label's rank: classes
            # that tie on cost (homogeneous fleets, or the small-fabric
            # tier of a heterogeneous one) spread their pins round-robin
            # across the tied fabrics instead of piling onto the first —
            # and rare classes land packed two-to-a-fabric, where the
            # work-conserving switch-close serves them early instead of
            # each idling a whole fabric until its batch deadline
            order: List[str] = []
            i = 0
            while i < len(feas):
                j = i
                while j < len(feas) and feas[j][0] == feas[i][0]:
                    j += 1
                run = [w for _, _, w in feas[i:j]]
                k = rank % len(run)
                order.extend(run[k:] + run[:k])
                i = j
            self.ranked[label] = order

    def pin(self, label: str) -> Optional[str]:
        """The class's home fabric (cheapest feasible), ignoring health."""
        r = self.ranked.get(label)
        return r[0] if r else None

    def feasible(self, label: str) -> List[str]:
        return list(self.ranked.get(label, ()))

    def place(self, label: str, depths: Dict[str, int],
              loads: Dict[str, float], dead: frozenset
              ) -> Tuple[str, str]:
        """Route one request: returns ``(worker_name, 'pin' | 'steal')``.

        The pinned fabric is the first live entry of the preference list.
        When its queue depth has reached ``steal_depth`` the request
        overflows to the least-loaded feasible live peer (ties break by
        preference rank — still deterministic). Raises
        :class:`UnroutableError` when no live fabric can serve the class.
        """
        live = [w for w in self.ranked.get(label, ()) if w not in dead]
        if not live:
            raise UnroutableError(
                f"class {label!r} has no live feasible fabric "
                f"(preference {self.ranked.get(label, [])}, "
                f"dead {sorted(dead)})")
        pinned = live[0]
        if len(live) == 1 or depths.get(pinned, 0) < self.steal_depth:
            return pinned, "pin"
        victim = min(live, key=lambda w: (loads.get(w, 0.0),
                                          live.index(w)))
        if victim == pinned:
            return pinned, "pin"
        return victim, "steal"
