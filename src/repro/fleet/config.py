"""Fleet configuration: fabric geometry specs + scheduling policy knobs.

A :class:`FleetConfig` fully determines a fleet soak together with the
workload seed (DESIGN.md §15): fabric geometries, per-fabric serving
policy, the work-stealing threshold, the calibration/serving stream
length, the served class mix, and any scripted mid-soak fabric failures
all live here, so ``FleetEngine.trace_digest()`` is a pure function of
``(seed, FleetConfig)`` — the same replay contract PR 8 pinned for the
single-fabric ``ServeEngine``, extended across N fabrics.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

from repro.serve.loop import ServeConfig

#: the PR 8 six-class serve mix (short streaming kernels, a reduction,
#: a multi-shot plan, an irregular loop)
DEFAULT_CLASSES: Tuple[str, ...] = (
    "relu", "vadd", "fft", "mac1", "axpby_ms", "div_loop")


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """One fabric worker: a name plus the geometry its engine is built
    around. Heterogeneous fleets mix specs — that is the aligned-
    provisioning lever (give fft a wide fabric, give the short kernels a
    small one whose config path is cheaper)."""

    name: str
    rows: int = 4
    cols: int = 4
    n_imns: int = 4
    n_omns: int = 4
    backend: str = "sim"

    @property
    def geometry(self) -> Tuple[int, int, int, int]:
        return (self.rows, self.cols, self.n_imns, self.n_omns)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Everything that shapes a fleet soak besides the workload seed."""

    fabrics: Tuple[FabricSpec, ...]
    steal_depth: int = 6            # pinned-queue depth that triggers
    #                                 overflow onto the least-loaded peer
    max_batch: int = 8              # per-fabric ServeConfig knobs
    max_wait_us: float = 400.0
    queue_capacity: int = 64        # per fabric
    preempt_wait_us: float = 150.0
    us_per_cycle: float = 0.01
    slo_p99_us: Optional[float] = None
    length: int = 64                # request stream length (also used to
    #                                 calibrate the placement cost model)
    classes: Tuple[str, ...] = DEFAULT_CLASSES
    fail_at: Tuple[Tuple[str, float], ...] = ()   # scripted failures:
    #                                 (fabric name, virtual t_us) pairs
    # workload shape — lives here (not in the soak driver) so the fleet
    # trace digest is a pure function of (seed, FleetConfig) alone
    n_requests: int = 200
    rate_per_us: float = 0.05       # offered arrival rate
    bursty: bool = False
    burst_size: int = 8
    weights: Tuple[Tuple[str, float], ...] = ()   # class-mix bias

    def __post_init__(self):
        if not self.fabrics:
            raise ValueError("FleetConfig needs at least one FabricSpec")
        names = [s.name for s in self.fabrics]
        if len(set(names)) != len(names):
            raise ValueError(f"fabric names must be unique, got {names}")
        if not (0 < self.steal_depth <= self.queue_capacity):
            raise ValueError(
                f"steal_depth must be in (0, queue_capacity="
                f"{self.queue_capacity}], got {self.steal_depth}")
        for name, t in self.fail_at:
            if name not in names:
                raise ValueError(f"fail_at names unknown fabric {name!r} "
                                 f"(have {names})")
        for label, _ in self.weights:
            if label not in self.classes:
                raise ValueError(f"weights name unknown class {label!r} "
                                 f"(have {list(self.classes)})")

    def serve_config(self) -> ServeConfig:
        """The per-fabric-worker serving policy."""
        return ServeConfig(max_batch=self.max_batch,
                           max_wait_us=self.max_wait_us,
                           queue_capacity=self.queue_capacity,
                           preempt_wait_us=self.preempt_wait_us,
                           us_per_cycle=self.us_per_cycle,
                           slo_p99_us=self.slo_p99_us)

    def digest(self) -> str:
        """Content digest of the whole config — frozen dataclass reprs
        are deterministic, so this names the replay identity."""
        return hashlib.sha1(repr(self).encode()).hexdigest()


def homogeneous(n: int, rows: int = 4, cols: int = 4,
                n_imns: Optional[int] = None, n_omns: Optional[int] = None,
                backend: str = "sim", **kw) -> FleetConfig:
    """``n`` identical fabrics (default 4x4) — the scale-out baseline the
    DSE-provisioned heterogeneous fleet is benchmarked against."""
    n_imns = cols if n_imns is None else n_imns
    n_omns = cols if n_omns is None else n_omns
    specs = tuple(FabricSpec(name=f"f{i}", rows=rows, cols=cols,
                             n_imns=n_imns, n_omns=n_omns, backend=backend)
                  for i in range(n))
    return FleetConfig(fabrics=specs, **kw)
