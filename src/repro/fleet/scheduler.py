"""Multi-fabric fleet scale-out: shard the serve stream across N engines.

:class:`FleetEngine` runs one :class:`~repro.serve.loop.ServeEngine` per
:class:`~repro.fleet.config.FabricSpec` — each fabric worker owns its own
engine, virtual clock, per-class FIFO state, and geometry-specific
compiled artifacts — and shards an arrival stream across them
(DESIGN.md §15):

  * **placement** — a class-affinity :class:`~repro.fleet.placement.Router`
    pins each config class to the fabric whose *measured* cost model says
    it is cheapest there (modeled cycles x ``us_per_cycle`` plus the
    amortized configuration share), and work-steals overflow onto the
    least-loaded feasible peer once the pinned queue is ``steal_depth``
    deep;
  * **fault-drain** — :meth:`fail_fabric` marks a fabric dead mid-soak and
    moves every queued and shot-paused request to surviving peers (rid
    order preserved, artifacts re-bound to the peer's geometry, no loss,
    no duplicates); heartbeat-driven failure goes through
    :meth:`check_health` over ``runtime/fault_tolerance``'s
    :class:`HealthMonitor`;
  * **determinism** — every fleet decision (route, steal, fail, drain,
    unroutable) lands in the fleet trace, each worker keeps its own PR 8
    serve trace, and :meth:`trace_digest` folds all of them together, so
    the digest is a pure function of ``(seed, FleetConfig)`` and replays
    bit-identically across processes.

The whole fleet is one discrete-event simulation: the global event list
(arrivals + scripted failures) is walked in time order, and between
events every live worker is *pumped* — dispatched while it has decisions
to make, then advanced to the event frontier. Values never depend on
which fabric served a request (the functional executor computes them),
which is what makes the fleet digest-comparable against a single-engine
oracle running the same request stream.
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.engine.cache import ArtifactCache
from repro.engine.scheduler import Engine
from repro.core.fabric import Fabric
from repro.serve.clock import VirtualClock
from repro.serve.loop import AdmissionError, ServeEngine, Ticket
from repro.fleet.config import FabricSpec, FleetConfig
from repro.fleet.placement import (ClassCost, Router, UnroutableError,
                                   measure_class_costs)


class _PhantomArtifact:
    """Stand-in artifact for a ticket the fleet rejected before any fabric
    could own it (unroutable class) — carries just what :class:`Ticket`
    and the rejection message read."""

    __slots__ = ("name", "config_class")

    def __init__(self, label: str):
        self.name = label
        self.config_class = label


class FabricWorker:
    """One fabric of the fleet: spec + engine + serving state machine."""

    __slots__ = ("spec", "engine", "serve", "artifacts", "costs", "alive",
                 "busy_us", "probe")

    def __init__(self, spec: FabricSpec, serve_cfg, cache,
                 costs: Dict[str, ClassCost], artifacts: Dict[str, object],
                 probe=None):
        self.spec = spec
        rows, cols, n_imns, n_omns = spec.geometry
        self.engine = Engine(Fabric(rows=rows, cols=cols, n_imns=n_imns,
                                    n_omns=n_omns),
                             backend=spec.backend, cache=cache)
        self.serve = ServeEngine(self.engine, serve_cfg,
                                 clock=VirtualClock(), probe=probe)
        self.artifacts = artifacts
        self.costs = costs
        self.alive = True
        self.busy_us = 0.0
        self.probe = probe

    @property
    def name(self) -> str:
        return self.spec.name


class FleetEngine:
    """Deterministic fleet scheduler over N fabric workers.

    ``hb_dir`` wires the fault-tolerance runtime's file heartbeats under
    the fleet: each worker publishes a beat per dispatch unit (through its
    ``LivenessProbe``) and :meth:`check_health` fails any fabric the
    :class:`HealthMonitor` flags as stalled. Scripted failures
    (``FleetConfig.fail_at``) need no heartbeat machinery and keep the
    soak fully virtual."""

    def __init__(self, config: FleetConfig,
                 cache: Optional[ArtifactCache] = None,
                 hb_dir: Optional[str] = None, timeout_s: float = 5.0):
        self.cfg = config
        self.cache = cache if cache is not None \
            else ArtifactCache(memory_only=True)
        self.monitor = None
        probes: List[Optional[object]] = [None] * len(config.fabrics)
        if hb_dir is not None:
            from repro.serve.health import LivenessProbe
            from repro.runtime.fault_tolerance import HealthMonitor
            probes = [LivenessProbe(hb_dir, timeout_s=timeout_s, host_id=i)
                      for i in range(len(config.fabrics))]
            self.monitor = HealthMonitor(hb_dir, timeout_s=timeout_s,
                                         step_lag=None)
        # one cost-model measurement per distinct (geometry, backend) —
        # a homogeneous fleet compiles its class mix exactly once, and
        # the throwaway probe engines never touch any worker's tally
        serve_cfg = config.serve_config()
        memo: Dict[tuple, tuple] = {}
        self.workers: List[FabricWorker] = []
        for spec, probe in zip(config.fabrics, probes):
            gk = (spec.geometry, spec.backend)
            if gk not in memo:
                memo[gk] = measure_class_costs(
                    spec.geometry, config.classes, config.length,
                    config.us_per_cycle, config.max_batch,
                    backend=spec.backend, cache=self.cache)
            costs, artifacts = memo[gk]
            self.workers.append(FabricWorker(spec, serve_cfg, self.cache,
                                             costs, artifacts, probe))
        self._by_name = {w.name: w for w in self.workers}
        # globally unique, arrival-ordered request ids: every worker's
        # ServeEngine draws from ONE shared counter
        shared_ids = itertools.count()
        for w in self.workers:
            w.serve._ids = shared_ids
        self.router = Router([w.name for w in self.workers],
                             {w.name: w.costs for w in self.workers},
                             config.steal_depth)
        infeasible = sorted(l for l in config.classes
                            if not self.router.feasible(l))
        if infeasible:
            raise ValueError(
                f"no fabric in the fleet can serve class(es) {infeasible} "
                f"— geometries {[s.geometry for s in config.fabrics]}")
        self.dead: set = set()
        self.trace: List[tuple] = []
        self.unroutable: List[Ticket] = []
        self._rid_label: Dict[int, str] = {}
        self._owner: Dict[int, str] = {}
        self.steals = 0
        self.drained = 0

    # -- helpers -----------------------------------------------------------
    def _trace(self, kind: str, t: float, *args) -> None:
        self.trace.append((kind, round(float(t), 6)) + args)

    def _live(self) -> List[FabricWorker]:
        return [w for w in self.workers if w.alive]

    def _depths(self) -> Dict[str, int]:
        return {w.name: w.serve._depth + len(w.serve._paused)
                for w in self._live()}

    def _load_us(self, w: FabricWorker, t: float) -> float:
        """Modeled backlog of one worker at global time ``t``: how far its
        clock already ran ahead plus the modeled service time of every
        queued / paused request (the steal tie-breaker)."""
        load = max(0.0, w.serve.clock.now() - t)
        for q in w.serve._queues.values():
            for tk in q:
                load += w.costs[self._rid_label[tk.rid]].service_us
        for ex in w.serve._paused.values():
            load += w.costs[self._rid_label[ex.ticket.rid]].service_us
        return load

    def _gauge(self, w: FabricWorker) -> None:
        obs.set_gauge(f"fleet.{w.name}.queue_depth",
                      w.serve._depth + len(w.serve._paused))

    # -- the fleet discrete-event loop -------------------------------------
    def drive(self, arrivals: Sequence[Tuple[float, str, Dict]]) -> Dict:
        """Serve a labeled arrival schedule ``[(t_us, label, inputs)...]``
        merged with the config's scripted failures; returns
        :meth:`report`."""
        for (a, _, _), (b, _, _) in zip(arrivals, arrivals[1:]):
            if b < a:
                raise ValueError("arrivals must be sorted by time")
        # kind 0 (failure) sorts before kind 1 (arrival) at equal t: a
        # request arriving the instant a fabric dies must not land on it
        events: List[tuple] = [(float(t), 0, i, ("fail", name))
                               for i, (name, t) in enumerate(self.cfg.fail_at)]
        events += [(float(t), 1, i, ("arrive", label, inputs))
                   for i, (t, label, inputs) in enumerate(arrivals)]
        events.sort(key=lambda e: e[:3])
        for t, _, _, ev in events:
            self._pump(t_limit=t, can_wait=True)
            if ev[0] == "fail":
                self.fail_fabric(ev[1], t=t)
            else:
                self._route(t, ev[1], ev[2])
        self._pump(t_limit=None, can_wait=False)
        return self.report()

    def _pump(self, t_limit: Optional[float], can_wait: bool) -> None:
        """Advance every live worker to the event frontier: dispatch while
        the worker's batcher has a decision, otherwise step its clock to
        the next batch deadline (never past ``t_limit``). Workers share no
        state mid-pump, so pumping them in fleet order is deterministic.

        After ``_pump(t)`` every live worker's clock is >= ... at least
        ``t`` (idle workers land exactly on it; a dispatch may overshoot),
        which keeps causality clean: a request routed at ``t`` is never
        served before it arrived."""
        for w in self._live():
            serve = w.serve
            while True:
                now = serve.clock.now()
                if t_limit is not None and now >= t_limit:
                    break
                pick = serve._pick(now, can_wait=can_wait)
                if pick is not None:
                    serve._dispatch(pick[0], pick[1])
                    w.busy_us += serve.clock.now() - now
                    self._gauge(w)
                    continue
                if t_limit is None:
                    break               # drained: no work, no more events
                nxt = t_limit
                dl = serve._next_deadline()
                if dl is not None:
                    nxt = min(nxt, dl)
                if nxt <= now:
                    # float plateau: ``head + max_wait_us`` rounds down to
                    # ``now`` while the expiry comparison still judges the
                    # head not-yet-due by one ulp — the clock cannot move
                    # and _pick never fires. The head IS at its deadline
                    # within float precision: serve it instead of spinning.
                    work = serve._work_classes()
                    heads = {c: serve._head_arrival(c) for c in work}
                    serve._dispatch(min(work, key=lambda c: (heads[c], c)),
                                    "deadline")
                    w.busy_us += serve.clock.now() - now
                    self._gauge(w)
                    continue
                serve.clock.advance_to(nxt)
                if nxt >= t_limit:
                    break

    def _route(self, t: float, label: str, inputs: Dict) -> Ticket:
        """Place one arrival on a fabric (or reject it by name)."""
        try:
            name, how = self.router.place(
                label, self._depths(),
                {w.name: self._load_us(w, t) for w in self._live()},
                frozenset(self.dead))
        except UnroutableError as e:
            # never entered any worker: fleet-level named rejection with
            # full accounting (offered == served+rejected+failed holds
            # fleet-wide including these)
            tk = Ticket(_PhantomArtifact(label), inputs)
            tk.t_arrival = t
            tk._reject(AdmissionError(str(e)), t)
            self.unroutable.append(tk)
            self._trace("unroutable", t, label)
            obs.inc("fleet.unroutable")
            return tk
        w = self._by_name[name]
        tk = w.serve.offer(w.artifacts[label], inputs, t=t)
        self._rid_label[tk.rid] = label
        self._owner[tk.rid] = name
        self._trace("route", t, tk.rid, label, name, how)
        if how == "steal":
            self.steals += 1
            obs.inc("fleet.steals")
        self._gauge(w)
        return tk

    # -- fault drain -------------------------------------------------------
    def fail_fabric(self, name: str, t: Optional[float] = None,
                    reason: str = "scripted failure") -> List[Ticket]:
        """Mark a fabric dead and drain its backlog to surviving peers.

        Idempotent (a second failure of the same fabric is a no-op).
        Queued and shot-paused requests move in rid order; each is
        re-bound to the target peer's geometry-specific artifact and
        re-inserted in rid order (``ServeEngine.requeue``), so class-FIFO
        completion order and the no-loss/no-duplicate invariant survive.
        A paused plan restarts from shot zero on the peer — re-execution
        is bit-exact, so no partial shot state needs to move. Requests
        with no surviving feasible fabric are rejected by name. Returns
        the moved tickets."""
        w = self._by_name[name]
        if not w.alive:
            return []
        w.alive = False
        self.dead.add(name)
        now = w.serve.clock.now() if t is None else float(t)
        if w.probe is not None:
            w.probe.retire()    # a dead fabric must stop tripping the
            #                     monitor as "stalled" forever
        moved: List[Ticket] = []
        for cls in list(w.serve._paused):
            ex = w.serve._paused.pop(cls)
            moved.append(ex.ticket)
        for q in w.serve._queues.values():
            while q:
                moved.append(q.popleft())
                w.serve._depth -= 1
        moved.sort(key=lambda tk: tk.rid)
        self._trace("fail", now, name, len(moved))
        obs.inc("fleet.failures")
        placed = []
        for tk in moved:
            label = self._rid_label[tk.rid]
            try:
                peer_name, how = self.router.place(
                    label, self._depths(),
                    {p.name: self._load_us(p, now) for p in self._live()},
                    frozenset(self.dead))
            except UnroutableError as e:
                tk._reject(AdmissionError(
                    f"fabric {name} failed ({reason}) and {e}"), now)
                w.serve.rejected.append(tk)
                self._trace("drain_reject", now, tk.rid, label)
                continue
            peer = self._by_name[peer_name]
            tk.artifact = peer.artifacts[label]
            tk.cls = tk.artifact.config_class
            peer.serve.requeue(tk)
            self._owner[tk.rid] = peer_name
            self._trace("drain", now, tk.rid, label, peer_name)
            self._gauge(peer)
            placed.append(tk)
        self.drained += len(placed)
        obs.inc("fleet.drains", len(placed))
        self._gauge(w)
        return moved

    def check_health(self, now: Optional[float] = None) -> List[str]:
        """Heartbeat-driven failure: consult the fault-tolerance
        ``HealthMonitor`` and fail every fabric it flags as stalled.
        Returns the names failed on this call."""
        if self.monitor is None:
            return []
        failed = []
        states = self.monitor.states(now)
        for i, w in enumerate(self.workers):
            if w.alive and states.get(i) == "stalled":
                self.fail_fabric(w.name, reason="heartbeat stalled")
                failed.append(w.name)
        return failed

    # -- observability / replay contract -----------------------------------
    def served_tickets(self) -> List[Ticket]:
        out = [tk for w in self.workers for tk in w.serve.served]
        out.sort(key=lambda tk: tk.rid)
        return out

    def trace_digest(self) -> str:
        """sha1 over (config digest, fleet decisions, every worker's serve
        trace) — the fleet half of the replay contract."""
        h = hashlib.sha1()
        h.update(self.cfg.digest().encode())
        for ev in self.trace:
            h.update(repr(ev).encode())
        for w in self.workers:
            h.update(w.name.encode())
            h.update(w.serve.trace_digest().encode())
        return h.hexdigest()

    def results_digest(self) -> str:
        """sha1 over every served request's outputs in global rid order,
        keyed by class *label* (labels are geometry-independent, unlike
        config classes) — this is the digest a single-engine oracle
        running the same request stream must reproduce bit-exactly."""
        h = hashlib.sha1()
        for tk in self.served_tickets():
            h.update(f"{tk.rid}|{self._rid_label[tk.rid]}".encode())
            for name in sorted(tk.outputs):
                h.update(name.encode())
                h.update(np.ascontiguousarray(
                    np.asarray(tk.outputs[name], dtype=np.int64)).tobytes())
        return h.hexdigest()

    def report(self) -> Dict:
        served = self.served_tickets()
        offered = sum(w.serve.offered for w in self.workers) \
            + len(self.unroutable)
        rejected = sum(len(w.serve.rejected) for w in self.workers) \
            + len(self.unroutable)
        failed = sum(len(w.serve.failed) for w in self.workers)
        now = max(w.serve.clock.now() for w in self.workers)
        steady = None
        if served:
            steady = max(tk.t_done for tk in served) \
                - min(tk.t_arrival for tk in served)
        lat = np.asarray([tk.latency_us for tk in served]) \
            if served else np.asarray([0.0])
        per_fabric = {}
        for w in self.workers:
            per_fabric[w.name] = {
                "geometry": list(w.spec.geometry),
                "alive": w.alive,
                "offered": w.serve.offered,
                "served": len(w.serve.served),
                "rejected": len(w.serve.rejected),
                "failed": len(w.serve.failed),
                "batches": w.serve.batches,
                "preemptions": w.serve.preemptions,
                "now_us": w.serve.clock.now(),
                "busy_us": w.busy_us,
                "utilization": w.busy_us / now if now > 0 else 0.0,
                "pinned": sorted(l for l in self.cfg.classes
                                 if self.router.pin(l) == w.name),
            }
            if obs.enabled():
                w.engine.stats.publish(prefix=f"fleet.{w.name}.engine.")
                obs.set_gauge(f"fleet.{w.name}.utilization",
                              per_fabric[w.name]["utilization"])
        return {
            "config_digest": self.cfg.digest(),
            "fabrics": len(self.workers),
            "offered": offered,
            "served": len(served),
            "rejected": rejected,
            "failed": failed,
            "unroutable": len(self.unroutable),
            "steals": self.steals,
            "drained": self.drained,
            "dead": sorted(self.dead),
            "now_us": now,
            "steady_window_us": steady,
            "throughput_rps": len(served) / now * 1e6 if now > 0 else 0.0,
            "steady_throughput_rps":
                len(served) / steady * 1e6 if steady else 0.0,
            "latency": {
                "count": len(served),
                "mean_us": float(np.mean(lat)),
                "p50_us": float(np.percentile(lat, 50)),
                "p95_us": float(np.percentile(lat, 95)),
                "p99_us": float(np.percentile(lat, 99)),
                "max_us": float(np.max(lat)),
            },
            "placements": {l: self.router.pin(l)
                           for l in sorted(self.cfg.classes)},
            "per_fabric": per_fabric,
            "trace_digest": self.trace_digest(),
        }


def fleet_workload(seed: int, config: FleetConfig, cache=None
                   ) -> List[Tuple[float, str, Dict]]:
    """The seeded labeled arrival stream a :class:`FleetEngine` soak
    serves — a pure function of ``(seed, config)``.

    Inputs are synthesized against reference 4x4 artifacts (input-stream
    shape depends only on the DFG, which is geometry-independent), so the
    identical stream can be replayed through a single-engine oracle for
    digest comparison."""
    from repro.serve.load import (bursty_arrival_times, compile_recipe,
                                  make_labeled_requests, mix_recipes,
                                  poisson_arrival_times)
    cache = cache if cache is not None else ArtifactCache(memory_only=True)
    ref = Engine(Fabric(), backend="sim", cache=cache)
    recipes = mix_recipes(config.length, "all")
    missing = [l for l in config.classes if l not in recipes]
    if missing:
        raise ValueError(f"unknown config class(es) {missing}")
    classes = {l: compile_recipe(ref, l, config.length, recipes)
               for l in config.classes}
    rng = np.random.default_rng(seed)
    if config.bursty:
        times = bursty_arrival_times(
            rng, config.n_requests, config.burst_size,
            gap_us=config.burst_size / config.rate_per_us)
    else:
        times = poisson_arrival_times(rng, config.n_requests,
                                      config.rate_per_us)
    weights = dict(config.weights) if config.weights else None
    return make_labeled_requests(classes, times, config.length, rng,
                                 weights)


def fleet_soak(seed: int, config: FleetConfig, cache=None,
               hb_dir: Optional[str] = None, timeout_s: float = 5.0
               ) -> Tuple["FleetEngine", Dict]:
    """One end-to-end deterministic fleet soak: build the fleet, generate
    the seeded workload, drive it (scripted failures included), return
    ``(fleet, report)``. The single entry point tests, benchmarks, and
    the cross-process replay harness share."""
    cache = cache if cache is not None else ArtifactCache(memory_only=True)
    fleet = FleetEngine(config, cache=cache, hb_dir=hb_dir,
                        timeout_s=timeout_s)
    report = fleet.drive(fleet_workload(seed, config, cache=cache))
    return fleet, report
