"""Design-space exploration over fabric geometry, per config class.

The paper's fabric is one fixed 4x4 mesh; a *fleet* gets to choose N
geometries. This module makes that choice measured instead of guessed
(DESIGN.md §15): :func:`sweep` compiles every serve class against every
candidate geometry (rows x cols x IMNs x OMNs) and replays one seeded
request through the fast timing simulation — reusing the artifact cache,
so a sweep re-run is nearly free — producing a ranked cost table per
class. :func:`provision` then turns that table into a concrete
heterogeneous :class:`FleetConfig` ("aligned provisioning"): fabric slots
are allocated to geometries in proportion to the weighted demand of the
classes that prefer them, with a feasibility repair pass guaranteeing
every class keeps at least one fabric it can map to (``div_loop`` does
not exist below 4x4).

Why this is a real lever on this fabric family: the configuration fetch
path scales with fabric rows, so small kernels are measurably cheaper on
small fabrics (relu: 125 cycles on 2x2 vs 135 on 4x4), while
column-hungry kernels invert hard (fft: 996 cycles on 2x2, 342 on 4x4).
A fleet that pins each class to its measured-best geometry beats the same
number of uniform 4x4 fabrics on tail latency for short-kernel-heavy
mixes — the claim ``benchmarks/bench_fleet.py`` pins.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.engine.cache import ArtifactCache
from repro.fleet.config import DEFAULT_CLASSES, FabricSpec, FleetConfig
from repro.fleet.placement import ClassCost, measure_class_costs

Geometry = Tuple[int, int, int, int]

#: the default candidate set: small/cheap-config, wide-but-shallow,
#: mid-square, and the paper's full 4x4
CANDIDATE_GEOMETRIES: Tuple[Geometry, ...] = (
    (2, 2, 2, 2), (2, 4, 4, 4), (3, 3, 3, 3), (4, 4, 4, 4))


def sweep(classes: Sequence[str] = DEFAULT_CLASSES, length: int = 64,
          us_per_cycle: float = 0.01, max_batch: int = 8,
          geometries: Sequence[Geometry] = CANDIDATE_GEOMETRIES,
          backend: str = "sim", cache: Optional[ArtifactCache] = None
          ) -> Dict[str, List[ClassCost]]:
    """Measure every class on every candidate geometry.

    Returns ``{label: [ClassCost, ...]}`` ranked cheapest-first
    (infeasible geometries sort last, carrying their named error). All
    compiles and timing traces land in ``cache``, so the fleet built from
    the result re-uses them."""
    cache = cache if cache is not None else ArtifactCache(memory_only=True)
    per_label: Dict[str, List[ClassCost]] = {l: [] for l in classes}
    for geo in geometries:
        costs, _ = measure_class_costs(geo, classes, length, us_per_cycle,
                                       max_batch, backend=backend,
                                       cache=cache)
        for label in classes:
            per_label[label].append(costs[label])
    for label in classes:
        per_label[label].sort(
            key=lambda c: (not c.feasible, c.service_us, c.geometry))
    return per_label


def table(ranked: Dict[str, List[ClassCost]]) -> List[Dict]:
    """The sweep as flat JSON-ready rows (benchmarks persist this)."""
    rows = []
    for label in sorted(ranked):
        for rank, c in enumerate(ranked[label]):
            rows.append({
                "class": label, "rank": rank,
                "geometry": list(c.geometry), "feasible": c.feasible,
                "service_us": None if not c.feasible
                else round(c.service_us, 4),
                "exec_cycles": c.exec_cycles,
                "config_cycles": c.config_cycles,
                "error": c.error,
            })
    return rows


def provision(ranked: Dict[str, List[ClassCost]], n_fabrics: int,
              weights: Optional[Dict[str, float]] = None,
              backend: str = "sim", **config_kw) -> FleetConfig:
    """Aligned provisioning: turn a sweep into a concrete N-fabric
    :class:`FleetConfig`.

    Fabric slots go to geometries in proportion to the weighted demand of
    the classes whose measured-best geometry they are (largest-remainder
    apportionment — deterministic). A repair pass then guarantees
    feasibility coverage: if some class has no feasible geometry among
    the provisioned slots, the slot of the least-demanded geometry is
    re-assigned to that class's best feasible geometry, so the resulting
    fleet can always serve the whole mix."""
    if n_fabrics < 1:
        raise ValueError(f"n_fabrics must be >= 1, got {n_fabrics}")
    labels = sorted(ranked)
    infeasible = [l for l in labels
                  if not any(c.feasible for c in ranked[l])]
    if infeasible:
        raise ValueError(f"class(es) {infeasible} infeasible on every "
                         f"swept geometry — widen the candidate set")
    demand: Dict[Geometry, float] = {}
    best: Dict[str, Geometry] = {}
    for l in labels:
        g = next(c.geometry for c in ranked[l] if c.feasible)
        best[l] = g
        demand[g] = demand.get(g, 0.0) + \
            (weights.get(l, 1.0) if weights else 1.0)
    total = sum(demand.values())
    # largest-remainder apportionment over the demanded geometries
    geos = sorted(demand, key=lambda g: (-demand[g], g))
    quota = {g: demand[g] / total * n_fabrics for g in geos}
    slots = {g: int(quota[g]) for g in geos}
    leftover = n_fabrics - sum(slots.values())
    for g in sorted(geos, key=lambda g: (-(quota[g] - slots[g]), g)):
        if leftover <= 0:
            break
        slots[g] += 1
        leftover -= 1
    # feasibility repair: every class needs >= 1 provisioned fabric it
    # can actually map to
    def provisioned() -> List[Geometry]:
        return [g for g in geos for _ in range(slots[g])]

    for l in labels:
        feas = {c.geometry for c in ranked[l] if c.feasible}
        if not feas.intersection(provisioned()):
            donor = min((g for g in geos if slots[g] > 0),
                        key=lambda g: (demand[g], g))
            slots[donor] -= 1
            g = best[l]
            if g not in slots:
                geos.append(g)
                demand.setdefault(g, 0.0)
                slots[g] = 0
            slots[g] += 1
    fabrics = tuple(
        FabricSpec(name=f"f{i}", rows=g[0], cols=g[1], n_imns=g[2],
                   n_omns=g[3], backend=backend)
        for i, g in enumerate(provisioned()))
    if weights and "weights" not in config_kw:
        config_kw["weights"] = tuple(sorted(weights.items()))
    return FleetConfig(fabrics=fabrics, classes=tuple(labels), **config_kw)
