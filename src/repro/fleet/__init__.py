"""Multi-fabric fleet scale-out for the serving engine (DESIGN.md §15).

``repro.fleet`` shards the PR 8 serve request stream across N independent
``Engine`` instances ("fabric workers"), each with its own geometry,
artifact cache namespace, and per-class FIFO state:

  * :class:`FleetConfig` / :class:`FabricSpec` — fleet shape + policy;
  * :class:`FleetEngine` — the deterministic fleet scheduler (class-
    affinity placement, work-stealing, fault-drain);
  * :func:`fleet_soak` — the shared seeded end-to-end soak entry point;
  * :mod:`repro.fleet.dse` — geometry design-space exploration + aligned
    provisioning.
"""
from repro.fleet.config import (DEFAULT_CLASSES, FabricSpec, FleetConfig,
                                homogeneous)
from repro.fleet.placement import (ClassCost, Router, UnroutableError,
                                   measure_class_costs)
from repro.fleet.scheduler import (FabricWorker, FleetEngine, fleet_soak,
                                   fleet_workload)

__all__ = [
    "DEFAULT_CLASSES", "FabricSpec", "FleetConfig", "homogeneous",
    "ClassCost", "Router", "UnroutableError", "measure_class_costs",
    "FabricWorker", "FleetEngine", "fleet_soak", "fleet_workload",
]
