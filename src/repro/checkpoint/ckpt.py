"""Mesh-agnostic checkpointing: zstd-compressed msgpack shards + manifest.

Design goals (fault tolerance at 1000+ nodes, DESIGN.md §5):
  * **mesh-agnostic**: tensors are written in global layout (gathered per
    host shard with a manifest describing the tree); any mesh/host count
    can restore — elastic re-scaling is a restore onto a different mesh;
  * **atomic**: writes go to ``step_XXXX.tmp`` then rename; a crashed save
    never corrupts the latest complete checkpoint;
  * **async**: ``save_async`` hands the host copy to a writer thread so the
    train loop only blocks for the device->host transfer;
  * **self-describing**: dtype/shape/tree structure in the manifest; no
    pickles.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard as zstd
    _Z = zstd.ZstdCompressor(level=3)
    _ZD = zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    _Z = _ZD = None

try:
    import msgpack
except Exception:  # pragma: no cover
    msgpack = None


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}"))
    elif tree is None:
        pass
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray],
                    prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if isinstance(template, tuple):
        return tuple(_unflatten_into(v, flat, f"{prefix}#{i}")
                     for i, v in enumerate(template))
    if isinstance(template, list):
        return [_unflatten_into(v, flat, f"{prefix}#{i}")
                for i, v in enumerate(template)]
    if template is None:
        return None
    arr = flat[prefix]
    want = np.dtype(jax.numpy.asarray(template).dtype
                    if not hasattr(template, "dtype") else template.dtype)
    return arr.astype(want)


_BF16_MARK = "<bf16>"


def _encode_array(a: np.ndarray) -> Tuple[bytes, str]:
    if str(a.dtype) == "bfloat16":
        return a.view(np.uint16).tobytes(), _BF16_MARK
    return a.tobytes(), str(a.dtype)


def _decode_array(buf: bytes, dtype: str, shape) -> np.ndarray:
    if dtype == _BF16_MARK:
        import ml_dtypes  # ships with jax
        return np.frombuffer(buf, np.uint16).reshape(shape).view(
            ml_dtypes.bfloat16)
    return np.frombuffer(buf, np.dtype(dtype)).reshape(shape)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: x is None)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: x is None)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: Dict) -> str:
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra,
                    "tensors": {k: {"shape": list(v.shape),
                                    "dtype": (_BF16_MARK
                                              if str(v.dtype) == "bfloat16"
                                              else str(v.dtype))}
                                for k, v in flat.items()}}
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        payload = {}
        for k, v in flat.items():
            buf, _ = _encode_array(v)
            payload[k] = buf
        blob = msgpack.packb(payload, use_bin_type=True)
        if _Z is not None:
            blob = _Z.compress(blob)
        with open(os.path.join(tmp, "data.msgpack.zst"), "wb") as f:
            f.write(blob)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int, Dict]:
        """Restore into ``template``'s structure/dtypes (mesh-agnostic:
        caller re-shards with device_put afterwards)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "data.msgpack.zst"), "rb") as f:
            blob = f.read()
        if _ZD is not None:
            blob = _ZD.decompress(blob)
        payload = msgpack.unpackb(blob, raw=False)
        flat = {}
        for k, meta in manifest["tensors"].items():
            flat[k] = _decode_array(payload[k], meta["dtype"],
                                    tuple(meta["shape"]))
        tree = _unflatten_into(template, flat)
        return tree, manifest["step"], manifest.get("extra", {})
