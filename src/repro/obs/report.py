"""Profiler CLI: per-kernel fabric utilization heat-tables + exports.

Runs the paper kernels through the real execution pipeline (one
``Engine`` with obs enabled: compile -> artifact cache -> P&R -> one
batched ``flush`` over every submitted request), then derives per-PE /
per-IMN / per-OMN occupancy from the recorded timing data — a
``TimingTrace`` when the artifact carries one (static-rate kernels), the
representative ``SimResult`` otherwise — and names each kernel's
bottleneck resource. Optionally exports the whole run's span tree as
Chrome-trace JSON plus the metrics registry in Prometheus text / JSONL.

    PYTHONPATH=src python -m repro.obs.report --kernel fft --kernel dither \
        --length 64 --chrome-trace obs_trace.json --metrics obs_metrics.prom

Load the trace JSON in chrome://tracing or https://ui.perfetto.dev to see
the compile/cache.lookup/pnr/schedule.flush/dispatch span hierarchy.
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import obs
from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.fabric import Fabric
from repro.obs.profiler import FabricProfile, profile_sim, profile_trace

# paper kernels runnable straight from kernels_lib (length-parametric)
KERNELS: Dict[str, Callable[[int], DFG]] = {
    "fft": lambda n: K.fft_butterfly(),
    "dither": lambda n: K.dither(),
    "find2min": lambda n: K.find2min(),
    "relu": lambda n: K.relu(),
    "vadd": lambda n: K.vadd(),
    "axpby": lambda n: K.axpby(3, 5),
    "mac1": lambda n: K.mac1(n),
    "div_loop": lambda n: K.div_loop(7),
}


def _inputs(g: DFG, length: int, rng) -> Dict[str, np.ndarray]:
    lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def profile_artifact(engine, art, length: int) -> List[FabricProfile]:
    """One profile per shot, preferring the artifact's recorded
    ``TimingTrace`` (bit-identical firing counts by construction) and
    falling back to the runner's representative ``SimResult`` for
    data-dependent kernels."""
    sims = engine.runner.rep_sims()
    mappings = engine.runner.mappings()
    profs: List[FabricProfile] = []
    for shot in art.plan.shots:
        cfg = art.config_class if art.n_shots == 1 else shot.key
        label = art.name if art.n_shots == 1 else f"{art.name}/{shot.key}"
        m = mappings.get(cfg, shot.mapping)
        tr = art.trace_for(cfg, length)
        if tr is not None:
            profs.append(profile_trace(m, tr, kernel=label))
            continue
        sim = None
        for (key, slen, layout), s in sims.items():
            if key == cfg and slen == length:
                sim = s
                break
        if sim is not None:
            profs.append(profile_sim(m, sim, kernel=label, length=length))
    return profs


def run_report(kernels: List[str], length: int = 64, requests: int = 4,
               rows: int = 4, cols: int = 4,
               chrome_trace: Optional[str] = None,
               metrics_path: Optional[str] = None,
               jsonl_path: Optional[str] = None,
               out=sys.stdout) -> List[FabricProfile]:
    """Compile + batch-dispatch the kernels, print utilization tables."""
    from repro.engine import ArtifactCache, Engine

    obs.enable(fresh=True)
    eng = Engine(fabric=Fabric(rows=rows, cols=cols),
                 cache=ArtifactCache(memory_only=True))
    rng = np.random.default_rng(0)

    arts = {}
    for name in kernels:
        if name not in KERNELS:
            raise SystemExit(f"unknown kernel {name!r}; choose from "
                             f"{sorted(KERNELS)}")
        arts[name] = eng.compile(KERNELS[name](length))
    handles = []
    for name, art in arts.items():
        for _ in range(requests):
            handles.append(eng.submit(art, _inputs(art.dfg, length, rng)))
    eng.flush()                      # one batched flush over all classes

    profiles: List[FabricProfile] = []
    for name, art in arts.items():
        for prof in profile_artifact(eng, art, length):
            profiles.append(prof)
            print(prof.table(), file=out)
            print(file=out)

    t = eng.tally
    print(f"flush: {len(handles)} requests / {len(arts)} config classes — "
          f"config={t.config} rearm={t.rearm} exec={t.exec} cycles "
          f"(saved {eng.stats.config_cycles_saved} vs naive)", file=out)

    if chrome_trace:
        obs.export_chrome(chrome_trace)
        print(f"wrote {chrome_trace} ({obs.ring_len()} spans)", file=out)
    reg = obs.registry()
    if metrics_path and reg is not None:
        with open(metrics_path, "w") as f:
            f.write(reg.to_prometheus())
        print(f"wrote {metrics_path}", file=out)
    if jsonl_path and reg is not None:
        reg.dump_jsonl(jsonl_path)
        print(f"wrote {jsonl_path}", file=out)
    return profiles


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__.splitlines()[0])
    ap.add_argument("--kernel", action="append", default=None,
                    metavar="NAME", help=f"kernel to profile (repeatable; "
                    f"default fft + dither; known: {sorted(KERNELS)})")
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per kernel in the batched flush")
    ap.add_argument("--geometry", default="4x4", metavar="RxC")
    ap.add_argument("--chrome-trace", default=None,
                    help="write the span tree as Chrome-trace JSON")
    ap.add_argument("--metrics", default=None,
                    help="write the metrics registry as Prometheus text")
    ap.add_argument("--jsonl", default=None,
                    help="write the metrics registry as JSONL")
    args = ap.parse_args(argv)
    r, c = (int(v) for v in args.geometry.lower().split("x"))
    run_report(args.kernel or ["fft", "dither"], length=args.length,
               requests=args.requests, rows=r, cols=c,
               chrome_trace=args.chrome_trace, metrics_path=args.metrics,
               jsonl_path=args.jsonl)
    return 0


if __name__ == "__main__":
    sys.exit(main())
