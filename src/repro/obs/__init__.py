"""``repro.obs`` — zero-overhead-when-disabled observability.

Three layers (DESIGN.md §12), one switch:

  * **tracing** (``obs.span``) — nested spans into a ring buffer,
    exportable as Chrome-trace/Perfetto JSON (``trace.py``);
  * **metrics** (``obs.inc`` / ``obs.observe`` / ``obs.set_gauge``) —
    counters, gauges, and numpy-exact-percentile histograms with
    Prometheus-text and JSONL exporters (``metrics.py``);
  * **fabric profiler** (``obs.profiler``) — per-PE/IMN/OMN firing counts,
    occupancy, bubbles, and steady-state II from recorded timing data
    (``profiler.py``; CLI in ``report.py``).

Enablement: ``STRELA_OBS=1`` in the environment at import, or
:func:`enable` programmatically. **Disabled is the default and costs
nothing measurable**: the tracer and registry slots are ``None``, every
instrumentation helper is a single ``None``-check, ``obs.span()`` returns
one shared no-op context manager, and not a byte is written to the ring
buffer (asserted by tests/test_obs.py and benchmarks/perf_smoke.py).

Instrumented call sites live in ``engine/{scheduler,compiler,cache}``,
``core/{multishot,elastic_sim}`` and ``frontend/offload`` — the whole
compile -> cache -> P&R -> schedule -> dispatch pipeline of one
``Engine.flush`` is visually inspectable from one export.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (NULL_SPAN, Span, Tracer,      # noqa: F401
                             spans_from_chrome, to_chrome, write_chrome)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
    "disable", "enable", "enabled", "export_chrome", "inc", "observe",
    "registry", "ring_len", "set_gauge", "span", "spans",
    "spans_from_chrome", "to_chrome", "tracer", "write_chrome",
]

# process-global slots: None <=> observability disabled (the default)
_tracer: Optional[Tracer] = None
_registry: Optional[MetricsRegistry] = None


def enabled() -> bool:
    return _tracer is not None


def enable(capacity: int = 65536, fresh: bool = True) -> None:
    """Install a tracer + metrics registry. ``fresh=False`` keeps any
    existing ring/metrics (re-enabling after a temporary disable)."""
    global _tracer, _registry
    if fresh or _tracer is None:
        _tracer = Tracer(capacity=capacity)
    if fresh or _registry is None:
        _registry = MetricsRegistry()


def disable() -> None:
    """Uninstall: every instrumentation site reverts to its no-op path."""
    global _tracer, _registry
    _tracer = None
    _registry = None


def tracer() -> Optional[Tracer]:
    return _tracer


def registry() -> Optional[MetricsRegistry]:
    return _registry


# -- instrumentation helpers (hot-path safe: one None-check when off) -------

def span(name: str, **attrs):
    """Timed region context manager; the shared no-op when disabled."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, attrs)


def inc(name: str, n: int = 1) -> None:
    r = _registry
    if r is not None:
        r.counter(name).inc(n)


def observe(name: str, value: float) -> None:
    r = _registry
    if r is not None:
        r.histogram(name).observe(value)


def set_gauge(name: str, value: float) -> None:
    r = _registry
    if r is not None:
        r.gauge(name).set(value)


# -- export ----------------------------------------------------------------

def spans() -> List[Span]:
    """Finished spans in completion order ([] when disabled)."""
    t = _tracer
    return t.spans() if t is not None else []


def ring_len() -> int:
    t = _tracer
    return len(t) if t is not None else 0


def export_chrome(path: Optional[str] = None) -> Dict[str, Any]:
    """Chrome-trace document of the current ring (optionally written)."""
    doc = to_chrome(spans())
    if path:
        import json
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
    return doc


# env opt-in: one read at import, so instrumented modules see a stable state
if os.environ.get("STRELA_OBS", "0").lower() not in ("0", "", "false"):
    enable()
