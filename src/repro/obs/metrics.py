"""Metrics registry: counters, gauges, histograms + text exporters.

Naming convention (DESIGN.md §12): dotted lowercase paths, layer first —
``engine.requests``, ``engine.request_latency_us``, ``artifact_cache.hit``,
``shot.trace_replays``, ``compile.cache_misses``. Units are spelled in the
name (``_us``, ``_cycles``) so exports need no unit metadata.

Exporters:
  * :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
    (dots become underscores, a ``strela_`` prefix namespaces the repo;
    histograms export summary-style quantile samples + ``_count``/``_sum``);
  * :meth:`MetricsRegistry.dump_jsonl` — one JSON object per metric, the
    machine-readable sink benchmarks and CI artifacts consume.

Histogram percentiles use linear interpolation on the recorded samples —
bit-identical to ``numpy.percentile`` (asserted by tests/test_obs.py), so
latency p50/p90/p99 lines agree with any offline numpy analysis of the
same JSONL dump.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> Dict[str, Any]:
        return {"type": "counter", "name": self.name, "value": self.value}


class Gauge:
    """Last-written value (queue depth, cycles saved, ...)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def to_dict(self) -> Dict[str, Any]:
        return {"type": "gauge", "name": self.name, "value": self.value}


class Histogram:
    """Sample distribution with numpy-exact percentiles.

    Samples are kept verbatim up to ``max_samples`` (default 200k — a full
    bench run records a few thousand); past the cap only count/sum update,
    and ``saturated`` flags that percentiles describe the prefix.
    """

    __slots__ = ("name", "help", "max_samples", "count", "sum", "_samples")

    def __init__(self, name: str, help: str = "", max_samples: int = 200_000):
        self.name = name
        self.help = help
        self.max_samples = max_samples
        self.count = 0
        self.sum = 0.0
        self._samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if len(self._samples) < self.max_samples:
            self._samples.append(v)

    @property
    def saturated(self) -> bool:
        return self.count > len(self._samples)

    def samples(self) -> List[float]:
        return list(self._samples)

    def percentile(self, p: float) -> float:
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples), p))

    def percentiles(self, ps: Sequence[float] = (50, 90, 99)
                    ) -> Dict[float, float]:
        return {p: self.percentile(p) for p in ps}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def to_dict(self) -> Dict[str, Any]:
        d = {"type": "histogram", "name": self.name, "count": self.count,
             "sum": self.sum, "mean": self.mean}
        for p in (50, 90, 99):
            d[f"p{p}"] = self.percentile(p)
        return d


Metric = Any     # Counter | Gauge | Histogram


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.

    A name is bound to one metric type forever; asking for the same name
    with a different type raises instead of silently shadowing.
    """

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  max_samples: int = 200_000) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, help,
                                                max_samples=max_samples)
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not Histogram")
        return m

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def metrics(self) -> List[Metric]:
        return list(self._metrics.values())

    # -- exporters ---------------------------------------------------------
    @staticmethod
    def _prom_name(name: str) -> str:
        clean = name.replace(".", "_").replace("-", "_")
        return f"strela_{clean}"

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        for m in self._metrics.values():
            pn = self._prom_name(m.name)
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pn} counter")
                lines.append(f"{pn} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pn} gauge")
                lines.append(f"{pn} {m.value}")
            else:
                lines.append(f"# TYPE {pn} summary")
                for q in (0.5, 0.9, 0.99):
                    v = m.percentile(q * 100)
                    lines.append(f'{pn}{{quantile="{q}"}} {v}')
                lines.append(f"{pn}_sum {m.sum}")
                lines.append(f"{pn}_count {m.count}")
        return "\n".join(lines) + "\n"

    def to_dicts(self) -> List[Dict[str, Any]]:
        return [m.to_dict() for m in self._metrics.values()]

    def dump_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for d in self.to_dicts():
                f.write(json.dumps(d) + "\n")
        return path
