"""Fabric profiler: per-PE / per-IMN / per-OMN utilization from timing data.

The paper's headline quantities (OPs/cycle, MOPs/mW, config-overhead
breakdowns, Table I/II) are all *attribution* statements — which resource
the cycles went to. This module derives that attribution from data the
pipeline already records, with no extra simulation:

  * a recorded ``TimingTrace`` (static-rate kernels: firing counts, OMN
    arrival schedules, bank beats are value-independent — PR 4), or
  * a live ``SimResult`` (recirculating / data-dependent kernels, whose
    firing counts exist only per execution),

joined against the shot's ``Mapping`` for placement. Per resource it
reports firing counts, occupancy % (firings / elapsed cycles), bubble
cycles (elapsed − firings: cycles the station sat idle or stalled), and
the kernel's steady-state II; :meth:`FabricProfile.bottleneck` names the
busiest resource — the one a mapper or scheduler would have to relieve
first ("Aligned Compute and Communication Provisioning"'s compute-vs-
routing split, PAPERS.md).

The same counts feed ``core.energy.features_from_sim`` (activity factors
of the power model), so utilization reports and energy reports share one
source of truth; ``python -m repro.obs.report`` renders the heat-table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.elastic_sim import SimResult, TimingTrace
from repro.core.mapper import Mapping

# node kinds the power model bills as control activity
_CTRL_KINDS = (D.CMP, D.MUX, D.BRANCH, D.MERGE)


@dataclasses.dataclass
class ResourceUtil:
    """Utilization of one fabric resource over one kernel execution."""

    kind: str                 # "pe" | "imn" | "omn"
    name: str                 # DFG node name
    pos: str                  # "PE[r,c]" | "IMN[c]" | "OMN[c]"
    role: str                 # alu:add, cmp:gt, route, stream-in, ...
    firings: int              # FU firings / stream beats delivered
    cycles: int               # elapsed kernel cycles

    @property
    def occupancy(self) -> float:
        """Fraction of elapsed cycles this resource did work."""
        return self.firings / self.cycles if self.cycles else 0.0

    @property
    def bubbles(self) -> int:
        """Idle/stalled cycles (elapsed − firings), the paper's 'bubble'
        cycles an elastic handshake absorbs."""
        return max(self.cycles - self.firings, 0)

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "name": self.name, "pos": self.pos,
                "role": self.role, "firings": self.firings,
                "occupancy": self.occupancy, "bubbles": self.bubbles}


@dataclasses.dataclass
class FabricProfile:
    """Utilization of every mapped resource for one kernel execution."""

    kernel: str
    cycles: int
    length: Optional[int]            # stream extent (None if unknown)
    bank_beats: int
    n_banks: int
    steady_ii: float
    route_pes: int                   # active PEs carrying only routed traffic
    rows: List[ResourceUtil]
    from_trace: bool = False         # derived from a recorded TimingTrace

    # -- aggregates (the energy model's activity features) -----------------
    def _pe_rows(self) -> List[ResourceUtil]:
        return [r for r in self.rows if r.kind == "pe"]

    @property
    def pe_firings(self) -> int:
        """Total FU firings — bit-identical to the source trace/sim sum."""
        return sum(r.firings for r in self._pe_rows())

    @property
    def arith_firings(self) -> int:
        return sum(r.firings for r in self._pe_rows()
                   if r.role.startswith(D.ALU))

    @property
    def ctrl_firings(self) -> int:
        return sum(r.firings for r in self._pe_rows()
                   if not r.role.startswith(D.ALU))

    @property
    def mem_rate(self) -> float:
        """Bus beats per cycle (the power model's memory-node feature)."""
        return self.bank_beats / self.cycles if self.cycles else 0.0

    @property
    def ops_per_cycle(self) -> float:
        return self.pe_firings / self.cycles if self.cycles else 0.0

    @property
    def bus_occupancy(self) -> float:
        """Fraction of the interleaved-bank bandwidth actually used."""
        cap = self.cycles * self.n_banks
        return self.bank_beats / cap if cap else 0.0

    def bottleneck(self) -> Tuple[str, float]:
        """(resource label, occupancy) of the saturating resource.

        The memory bus competes as one aggregate resource at its full
        ``n_banks`` beats/cycle bandwidth; ties go to the earlier row
        (stable, so reports are deterministic)."""
        best, occ = "memory-bus", self.bus_occupancy
        for r in self.rows:
            if r.occupancy > occ:
                best, occ = f"{r.pos} {r.name}", r.occupancy
        return best, occ

    def to_dict(self) -> Dict:
        label, occ = self.bottleneck()
        return {"kernel": self.kernel, "cycles": self.cycles,
                "length": self.length, "steady_ii": self.steady_ii,
                "ops_per_cycle": self.ops_per_cycle,
                "pe_firings": self.pe_firings,
                "bank_beats": self.bank_beats,
                "bus_occupancy": self.bus_occupancy,
                "route_pes": self.route_pes, "from_trace": self.from_trace,
                "bottleneck": label, "bottleneck_occupancy": occ,
                "rows": [r.to_dict() for r in self.rows]}

    # -- rendering ---------------------------------------------------------
    def table(self, width: int = 24) -> str:
        """Per-resource utilization heat-table (monospace)."""
        ii = "inf" if self.steady_ii == float("inf") \
            else f"{self.steady_ii:.1f}"
        head = (f"{self.kernel}: {self.cycles} cycles"
                + (f", {self.length} elements" if self.length else "")
                + f", II={ii}, {self.ops_per_cycle:.2f} ops/cycle"
                + (" [trace]" if self.from_trace else " [sim]"))
        lines = [head,
                 f"  {'resource':<22s} {'role':<12s} {'firings':>8s} "
                 f"{'occ%':>6s} {'bubbles':>8s}  heat"]
        for r in self.rows:
            bar = "#" * int(round(r.occupancy * width))
            lines.append(f"  {r.pos + ' ' + r.name:<22s} {r.role:<12s} "
                         f"{r.firings:>8d} {r.occupancy * 100:>5.1f}% "
                         f"{r.bubbles:>8d}  {bar}")
        if self.route_pes:
            lines.append(f"  {'(route-through PEs)':<22s} {'route':<12s} "
                         f"{'-':>8s} {'-':>6s} {'-':>8s}  x{self.route_pes}")
        lines.append(f"  {'memory bus':<22s} {'banks x' + str(self.n_banks):<12s} "
                     f"{self.bank_beats:>8d} {self.bus_occupancy * 100:>5.1f}%")
        label, occ = self.bottleneck()
        lines.append(f"  bottleneck: {label} at {occ * 100:.1f}% occupancy")
        return "\n".join(lines)


def _role(n: D.Node) -> str:
    op = getattr(n.op, "name", None)
    return f"{n.kind}:{op.lower()}" if op else n.kind


def _steady_ii(arrival_cycles: Dict[str, Sequence[int]]) -> float:
    """Median positive inter-arrival gap at the OMNs (same statistic as
    ``SimResult.steady_ii``)."""
    gaps: List[int] = []
    for arr in arrival_cycles.values():
        if len(arr) > 1:
            d = np.diff(np.asarray(arr))
            gaps.extend(int(x) for x in d[d > 0])
    return float(np.median(gaps)) if gaps else float("inf")


def _profile(m: Mapping, kernel: str, cycles: int,
             arrival_cycles: Dict[str, Sequence[int]],
             fu_firings: Dict[str, int], bank_beats: int,
             length: Optional[int], n_banks: int,
             from_trace: bool) -> FabricProfile:
    g = m.dfg
    rows: List[ResourceUtil] = []
    for name in sorted(m.place, key=lambda n: m.place[n]):
        r, c = m.place[name]
        rows.append(ResourceUtil("pe", name, f"PE[{r},{c}]",
                                 _role(g.nodes[name]),
                                 int(fu_firings.get(name, 0)), cycles))
    for name, col in sorted(m.imn_of.items(), key=lambda kv: kv[1]):
        # an IMN delivers exactly one beat per stream element
        rows.append(ResourceUtil("imn", name, f"IMN[{col}]", "stream-in",
                                 int(length) if length else 0, cycles))
    for name, col in sorted(m.omn_of.items(), key=lambda kv: kv[1]):
        rows.append(ResourceUtil("omn", name, f"OMN[{col}]", "stream-out",
                                 len(arrival_cycles.get(name, ())), cycles))
    return FabricProfile(
        kernel=kernel, cycles=cycles, length=length, bank_beats=bank_beats,
        n_banks=n_banks, steady_ii=_steady_ii(arrival_cycles),
        route_pes=m.n_active_pes() - len(m.place), rows=rows,
        from_trace=from_trace)


def profile_sim(m: Mapping, sim: SimResult, kernel: Optional[str] = None,
                length: Optional[int] = None,
                n_banks: int = 4) -> FabricProfile:
    """Profile from a live ``SimResult`` (works for recirculating graphs,
    whose firing counts are data-dependent and exist only per run)."""
    return _profile(m, kernel or m.dfg.name, sim.cycles, sim.arrival_cycles,
                    sim.fu_firings, sim.bank_beats, length, n_banks,
                    from_trace=sim.replayed)


def profile_trace(m: Mapping, trace: TimingTrace,
                  kernel: Optional[str] = None) -> FabricProfile:
    """Profile from a recorded ``TimingTrace`` — zero re-simulation; counts
    are bit-identical to the trace's recorded firings by construction."""
    return _profile(m, kernel or m.dfg.name, trace.cycles,
                    trace.arrival_cycles, trace.fu_firings, trace.bank_beats,
                    trace.length, trace.n_banks, from_trace=True)
