"""Structured tracing: nested spans in a bounded ring buffer.

A *span* is one timed region of the pipeline (``compile``, ``pnr``,
``cache.lookup``, ``schedule.flush``, ``dispatch.sim``, per-shot ``shot``
spans, ...). Spans nest lexically via context managers; each records its
parent span id and depth, so the recorded stream reconstructs the full
call tree of e.g. one ``Engine.flush`` without any runtime bookkeeping
beyond a per-thread stack.

Finished spans land in a ``deque(maxlen=capacity)`` ring buffer —
recording never allocates unboundedly and never blocks the traced code.
The buffer exports as Chrome-trace / Perfetto JSON (``to_chrome``):
complete ("ph": "X") events with microsecond timestamps, loadable in
``chrome://tracing`` or https://ui.perfetto.dev. ``spans_from_chrome``
round-trips the export back into ``Span`` records (schema test anchor).

Overhead contract: this module never installs itself. ``repro.obs`` holds
the process-global tracer slot; when it is ``None`` (the default),
``obs.span()`` returns a shared no-op context manager and *nothing* here
runs — zero ring-buffer writes, no clock reads, no allocation.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    """One finished (or in-flight) timed region."""

    sid: int                       # unique per tracer, 1-based
    name: str
    t0_us: float                   # start, microseconds since tracer epoch
    dur_us: float                  # 0.0 while in flight
    parent: int                    # enclosing span's sid (0 = root)
    depth: int                     # nesting depth (0 = root)
    tid: int                       # OS thread id
    attrs: Dict[str, Any]


class _NullSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager recording one span into its tracer's ring."""

    __slots__ = ("_tracer", "_name", "_attrs", "span")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self.span: Optional[Span] = None

    def __enter__(self) -> "_SpanCtx":
        t = self._tracer
        stack = t._stack()
        parent = stack[-1].sid if stack else 0
        self.span = Span(sid=next(t._ids), name=self._name,
                         t0_us=(time.perf_counter() - t._epoch) * 1e6,
                         dur_us=0.0, parent=parent, depth=len(stack),
                         tid=threading.get_ident(), attrs=dict(self._attrs))
        stack.append(self.span)
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        s = self.span
        s.dur_us = (time.perf_counter() - t._epoch) * 1e6 - s.t0_us
        stack = t._stack()
        if stack and stack[-1] is s:
            stack.pop()
        t._finish(s)
        return False

    def set(self, **attrs) -> "_SpanCtx":
        """Attach attributes to the live span (e.g. measured cycles)."""
        if self.span is not None:
            self.span.attrs.update(attrs)
        else:
            self._attrs.update(attrs)
        return self


class Tracer:
    """Span recorder: per-thread nesting stacks over one shared ring."""

    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._epoch = time.perf_counter()
        self.dropped = 0

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _finish(self, span: Span) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(span)

    def span(self, name: str, attrs: Dict[str, Any]) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def spans(self) -> List[Span]:
        """Finished spans in completion order (children before parents)."""
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# ---------------------------------------------------------------------------

def to_chrome(spans: List[Span]) -> Dict[str, Any]:
    """Chrome-trace JSON document (complete 'X' events, ts/dur in µs).

    ``span_id`` / ``parent_id`` args make the recorded tree explicit —
    viewers infer nesting from timestamps, ``spans_from_chrome`` uses the
    ids for an exact round trip.
    """
    events = []
    for s in spans:
        events.append({
            "name": s.name, "cat": "strela", "ph": "X",
            "ts": s.t0_us, "dur": s.dur_us, "pid": 0, "tid": s.tid,
            "args": {**s.attrs, "span_id": s.sid, "parent_id": s.parent,
                     "depth": s.depth},
        })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs"}}


def spans_from_chrome(doc: Dict[str, Any]) -> List[Span]:
    """Inverse of :func:`to_chrome` (ordered by span id)."""
    spans = []
    for e in doc["traceEvents"]:
        if e.get("ph") != "X":
            continue
        args = dict(e.get("args", {}))
        sid = args.pop("span_id")
        parent = args.pop("parent_id")
        depth = args.pop("depth")
        spans.append(Span(sid=sid, name=e["name"], t0_us=e["ts"],
                          dur_us=e["dur"], parent=parent, depth=depth,
                          tid=e["tid"], attrs=args))
    spans.sort(key=lambda s: s.sid)
    return spans


def write_chrome(spans: List[Span], path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(spans), f, indent=1)
        f.write("\n")
    return path
