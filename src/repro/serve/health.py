"""Liveness probing for the serving loop.

Reuses the seed runtime's fault-tolerance primitives
(``repro.runtime.fault_tolerance``): the service loop publishes a
file-based :class:`Heartbeat` after every completed dispatch unit (batch
or shot), and a :class:`HealthMonitor` flags the worker as stalled when
the heartbeat goes quiet for longer than ``timeout_s``. On a stall the
serving engine drains the stalled class's queue with **named rejections**
(``AdmissionError`` spelling out the stall) instead of letting callers
block forever — DESIGN.md §14's liveness rule.
"""
from __future__ import annotations

from typing import List, Optional


class LivenessProbe:
    """Heartbeat publisher + stall detector over one serve worker.

    ``beat()`` is called by the service loop at every dispatch boundary;
    ``stalled(now)`` answers from the on-disk heartbeats (pass an explicit
    ``now`` for deterministic tests). Imports of the fault-tolerance
    runtime are lazy — it pulls in jax, which the serve hot path must not.
    """

    def __init__(self, directory: str, timeout_s: float = 5.0,
                 host_id: int = 0):
        from repro.runtime.fault_tolerance import Heartbeat, HealthMonitor
        self.directory = directory
        self.timeout_s = timeout_s
        self.host_id = host_id
        self._hb = Heartbeat(directory, host_id)
        # wall silence is the liveness signal; step lag is disabled —
        # serve workers (and fleet fabric workers even more so)
        # legitimately diverge in dispatch count
        self._monitor = HealthMonitor(directory, timeout_s=timeout_s,
                                      step_lag=None)
        self._step = 0

    def beat(self) -> int:
        """Publish one liveness step (monotonic)."""
        self._step += 1
        self._hb.beat(self._step)
        return self._step

    def retire(self) -> None:
        """Remove this worker's heartbeat: a deliberately-drained fabric
        must stop tripping the monitor."""
        self._hb.clear()

    @property
    def step(self) -> int:
        return self._step

    def stalled(self, now: Optional[float] = None) -> List[int]:
        """Host ids whose heartbeat lags; non-empty means the worker (or a
        peer) is stalled. ``now`` is Unix time (``time.time`` domain)."""
        return self._monitor.stalled(now)
