"""Seeded load generation: arrival processes + mixed-kernel workloads.

Everything here is a pure function of a ``numpy.random.default_rng`` seed,
so a generated workload — arrival times, class choices, stream contents —
is bit-identical across processes. That is half of the replay contract
(the other half is the virtual clock in ``serve/clock.py``).

Arrival processes:
  * :func:`poisson_arrival_times` — open-loop Poisson (exponential gaps at
    a fixed offered rate), the classic independent-users model;
  * :func:`bursty_arrival_times` — clustered arrivals (bursts of near-
    simultaneous requests separated by exponential quiet gaps), the
    adversarial case for admission control and batch-close deadlines.

Workload construction: :func:`serve_classes` compiles a request-class mix
on a caller's engine — the paper mix (short streaming kernels, a
reduction, a multi-shot plan, an irregular loop), the model-layer mix
(``mix="model"``, the transformer/SSM/MoE classes of ``repro.workloads``),
or both (``mix="all"``); :func:`make_requests` assigns a seeded class
choice + input streams to each arrival time.

Backend eligibility has ONE source of truth: every mix flows through
:func:`mix_recipes` + :func:`recipe_skip_reason` (which defers to
``engine.capabilities.backend_skip_reason``), so a class that a backend
cannot lower is dropped with a *named* reason everywhere — serve soaks,
fleet placement, and the benchmarks can never silently disagree about
which classes a backend serves.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core import kernels_lib as K


def poisson_arrival_times(rng: np.random.Generator, n: int,
                          rate_per_us: float, t0: float = 0.0
                          ) -> np.ndarray:
    """``n`` open-loop Poisson arrival times (us) at ``rate_per_us``."""
    if rate_per_us <= 0:
        raise ValueError(f"rate_per_us must be positive, got {rate_per_us}")
    gaps = rng.exponential(1.0 / rate_per_us, n)
    return t0 + np.cumsum(gaps)


def bursty_arrival_times(rng: np.random.Generator, n: int, burst_size: int,
                         gap_us: float, intra_us: float = 0.5,
                         t0: float = 0.0) -> np.ndarray:
    """``n`` arrivals in bursts of ``burst_size``: requests inside a burst
    land ``intra_us`` apart, bursts are separated by exponential quiet
    periods with mean ``gap_us``."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    times: List[float] = []
    t = float(t0)
    while len(times) < n:
        t += float(rng.exponential(gap_us))
        for i in range(min(burst_size, n - len(times))):
            times.append(t + i * intra_us)
        t = times[-1]
    return np.asarray(times[:n])


def class_recipes(length: int, include_loops: bool = True,
                  include_multishot: bool = True) -> Dict[str, tuple]:
    """The standard serve workload mix as uncompiled recipes:
    ``{label: (dfg_builder, compile_kwargs)}``.

    The indirection exists for the multi-fabric fleet (``repro.fleet``):
    each fabric worker compiles the same recipes against its *own*
    geometry, and a recipe whose compile fails on a small fabric (e.g.
    ``div_loop`` needs a 4x4) marks the class infeasible there instead of
    killing the whole mix."""
    recipes: Dict[str, tuple] = {
        "relu": (K.relu, {}),
        "vadd": (K.vadd, {}),
        "fft": (K.fft_butterfly, {}),
        "mac1": (lambda: K.mac1(length), {}),
    }
    if include_multishot:
        recipes["axpby_ms"] = (lambda: K.axpby(3, 5), {"pe_limit": 1})
    if include_loops:
        recipes["div_loop"] = (lambda: K.div_loop(7), {})
    return recipes


def mix_recipes(length: int, mix: str = "paper",
                include_loops: bool = True,
                include_multishot: bool = True) -> Dict[str, tuple]:
    """Uncompiled recipes of a named class mix — ``"paper"`` (the 6
    standard classes above), ``"model"`` (the transformer/SSM/MoE layer
    classes of ``repro.workloads``), or ``"all"`` (both; the namespace the
    fleet resolves arbitrary ``FleetConfig.classes`` against).

    A recipe factory returns either a ready :class:`~repro.core.dfg.DFG`
    (paper classes) or a Python function for ``repro.frontend`` to trace
    (model classes) — :func:`compile_recipe` dispatches on the result.
    Lazy import: ``repro.workloads`` pulls in the jax tracer."""
    if mix == "paper":
        return class_recipes(length, include_loops=include_loops,
                             include_multishot=include_multishot)
    from repro.workloads import model_recipes
    if mix == "model":
        return model_recipes(length)
    if mix == "all":
        merged = class_recipes(length, include_loops=include_loops,
                               include_multishot=include_multishot)
        models = model_recipes(length)
        clash = sorted(set(merged) & set(models))
        if clash:
            raise ValueError(f"model class labels collide with the paper "
                             f"mix: {clash}")
        merged.update(models)
        return merged
    raise ValueError(f"unknown mix {mix!r}; expected 'paper', 'model' "
                     f"or 'all'")


def compile_recipe(engine, label: str, length: int,
                   recipes: Dict[str, tuple]):
    """Compile one recipe on ``engine`` — a DFG-returning factory compiles
    directly, a traced-function factory gets the stream ``length``."""
    fn, kw = recipes[label]
    obj = fn()
    if isinstance(obj, D.DFG):
        return engine.compile(obj, **kw)
    return engine.compile(obj, length, **kw)


# (mix, label, length, backend) -> named skip reason or None; tracing a
# recipe to probe eligibility is cheap but not free, and soaks re-probe
# the same mixes at every load point
_SKIP_MEMO: Dict[tuple, Optional[str]] = {}


def recipe_skip_reason(label: str, length: int, backend: str,
                       recipes: Dict[str, tuple]) -> Optional[str]:
    """The named reason ``backend`` cannot serve class ``label`` at
    ``length`` (capability features joined with '+', per
    ``engine.capabilities.backend_skip_reason``), or None when it must.
    Probed on the uncompiled recipe — trace only, no place & route."""
    if backend == "sim":
        return None                 # the semantic reference takes the IR
    key = (label, length, backend)
    if key not in _SKIP_MEMO:
        from repro.engine.capabilities import backend_skip_reason
        fn, _ = recipes[label]
        obj = fn()
        if not isinstance(obj, D.DFG):
            from repro.frontend import trace
            obj = trace(obj, length)
        _SKIP_MEMO[key] = backend_skip_reason(obj, length, backend)
    return _SKIP_MEMO[key]


def artifact_skip_reason(artifact, length: int,
                         backend: str) -> Optional[str]:
    """Post-compile twin of :func:`recipe_skip_reason`: the named reason
    ``backend`` cannot run a compiled artifact (plan-level features, so
    multi-shot partitioning is included), or None."""
    from repro.engine.capabilities import (CapabilityError,
                                           check_stream_length,
                                           missing_features)
    missing = missing_features(artifact.features, backend)
    if missing:
        return "+".join(missing)
    if backend != "sim":
        try:
            for shot in artifact.plan.shots:
                check_stream_length(shot.dfg, length, backend)
        except CapabilityError:
            return "segmented-reduction"
    return None


def serve_classes(engine, length: int,
                  include_loops: Optional[bool] = None,
                  include_multishot: bool = True, mix: str = "paper",
                  skipped: Optional[Dict[str, str]] = None
                  ) -> Dict[str, object]:
    """Compile a workload mix on ``engine``; returns
    ``{label: CompiledArtifact}``.

    The paper mix covers the scheduling shapes the traffic story needs:
    short streaming kernels (relu/vadd/fft — the latency-sensitive class),
    a reduction (mac1), a multi-shot plan (axpby under ``pe_limit=1`` —
    the preemptible long request), and an irregular loop (div_loop,
    data-dependent trip count). ``mix="model"`` compiles the
    transformer/SSM/MoE layer classes of ``repro.workloads`` instead.

    Classes the engine's backend cannot lower are dropped with a *named*
    reason (collected into ``skipped`` when given) via
    :func:`recipe_skip_reason` — capability routing lives here, once, so
    callers never hand-maintain per-backend class lists.
    ``include_loops`` remains as an explicit mix-narrowing override
    (default None: keep every loop class the backend can serve)."""
    recipes = mix_recipes(length, mix,
                          include_loops=include_loops in (None, True),
                          include_multishot=include_multishot)
    classes: Dict[str, object] = {}
    for label in recipes:
        reason = recipe_skip_reason(label, length, engine.backend, recipes)
        if reason is not None:
            if skipped is not None:
                skipped[label] = reason
            continue
        classes[label] = compile_recipe(engine, label, length, recipes)
    return classes


def model_classes(engine, length: int,
                  skipped: Optional[Dict[str, str]] = None
                  ) -> Dict[str, object]:
    """Compile the model-layer workload mix (``repro.workloads``) on
    ``engine`` — the realistic-traffic sibling of :func:`serve_classes`.
    Backend-ineligible classes are dropped with named reasons into
    ``skipped`` (e.g. the SSM recurrences on pallas)."""
    return serve_classes(engine, length, mix="model", skipped=skipped)


def request_inputs(artifact, length: int, rng: np.random.Generator,
                   label: Optional[str] = None) -> Dict[str, np.ndarray]:
    """Seeded input streams for one request.

    A model-layer class (``label`` in the ``repro.workloads`` registry)
    draws from its registered per-stream ranges — fixed-point kernels need
    operands inside their Q-format envelope for the int32-exact oracle
    contract. Otherwise the generic convention applies (recirculating
    kernels get the positive operand range the loop semantics require —
    same as benchmarks/bench_engine.py)."""
    if label is not None:
        from repro.workloads import workload_input_gen
        gen = workload_input_gen(label)
        if gen is not None:
            return gen(length, rng)
    g = artifact.dfg
    lo, hi = (1, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def make_labeled_requests(classes: Dict[str, object],
                          times: Sequence[float], length: int,
                          rng: np.random.Generator,
                          weights: Optional[Dict[str, float]] = None
                          ) -> List[Tuple[float, str,
                                          Dict[str, np.ndarray]]]:
    """Assign each arrival time a seeded class choice + input streams,
    keyed by class *label* instead of a compiled artifact.

    Returns ``[(t_us, label, inputs), ...]`` sorted by time — the shape
    :meth:`repro.fleet.FleetEngine.drive` ingests (the fleet re-binds
    each label to the target fabric's geometry-specific artifact).
    Consumes the rng identically to :func:`make_requests`, so the same
    seed yields the same request stream either way — that is what lets a
    fleet soak be digest-compared against a single-engine oracle."""
    labels = sorted(classes)
    if weights is None:
        p = np.full(len(labels), 1.0 / len(labels))
    else:
        w = np.asarray([float(weights.get(l, 1.0)) for l in labels])
        p = w / w.sum()
    picks = rng.choice(len(labels), size=len(times), p=p)
    reqs = []
    for t, k in zip(times, picks):
        label = labels[int(k)]
        reqs.append((float(t), label,
                     request_inputs(classes[label], length, rng,
                                    label=label)))
    reqs.sort(key=lambda r: r[0])
    return reqs


def make_requests(classes: Dict[str, object], times: Sequence[float],
                  length: int, rng: np.random.Generator,
                  weights: Optional[Dict[str, float]] = None
                  ) -> List[Tuple[float, object, Dict[str, np.ndarray]]]:
    """Assign each arrival time a seeded class choice + input streams.

    Returns ``[(t_us, artifact, inputs), ...]`` sorted by time — exactly
    the shape :meth:`repro.serve.ServeEngine.drive` ingests. ``weights``
    biases the class mix (default uniform)."""
    return [(t, classes[label], ins)
            for t, label, ins in make_labeled_requests(
                classes, times, length, rng, weights)]
