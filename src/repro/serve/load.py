"""Seeded load generation: arrival processes + mixed-kernel workloads.

Everything here is a pure function of a ``numpy.random.default_rng`` seed,
so a generated workload — arrival times, class choices, stream contents —
is bit-identical across processes. That is half of the replay contract
(the other half is the virtual clock in ``serve/clock.py``).

Arrival processes:
  * :func:`poisson_arrival_times` — open-loop Poisson (exponential gaps at
    a fixed offered rate), the classic independent-users model;
  * :func:`bursty_arrival_times` — clustered arrivals (bursts of near-
    simultaneous requests separated by exponential quiet gaps), the
    adversarial case for admission control and batch-close deadlines.

Workload construction: :func:`serve_classes` compiles the standard mixed
request classes (short streaming kernels, a reduction, a multi-shot plan,
an irregular loop) on a caller's engine; :func:`make_requests` assigns a
seeded class choice + input streams to each arrival time.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import kernels_lib as K


def poisson_arrival_times(rng: np.random.Generator, n: int,
                          rate_per_us: float, t0: float = 0.0
                          ) -> np.ndarray:
    """``n`` open-loop Poisson arrival times (us) at ``rate_per_us``."""
    if rate_per_us <= 0:
        raise ValueError(f"rate_per_us must be positive, got {rate_per_us}")
    gaps = rng.exponential(1.0 / rate_per_us, n)
    return t0 + np.cumsum(gaps)


def bursty_arrival_times(rng: np.random.Generator, n: int, burst_size: int,
                         gap_us: float, intra_us: float = 0.5,
                         t0: float = 0.0) -> np.ndarray:
    """``n`` arrivals in bursts of ``burst_size``: requests inside a burst
    land ``intra_us`` apart, bursts are separated by exponential quiet
    periods with mean ``gap_us``."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    times: List[float] = []
    t = float(t0)
    while len(times) < n:
        t += float(rng.exponential(gap_us))
        for i in range(min(burst_size, n - len(times))):
            times.append(t + i * intra_us)
        t = times[-1]
    return np.asarray(times[:n])


def class_recipes(length: int, include_loops: bool = True,
                  include_multishot: bool = True) -> Dict[str, tuple]:
    """The standard serve workload mix as uncompiled recipes:
    ``{label: (dfg_builder, compile_kwargs)}``.

    The indirection exists for the multi-fabric fleet (``repro.fleet``):
    each fabric worker compiles the same recipes against its *own*
    geometry, and a recipe whose compile fails on a small fabric (e.g.
    ``div_loop`` needs a 4x4) marks the class infeasible there instead of
    killing the whole mix."""
    recipes: Dict[str, tuple] = {
        "relu": (K.relu, {}),
        "vadd": (K.vadd, {}),
        "fft": (K.fft_butterfly, {}),
        "mac1": (lambda: K.mac1(length), {}),
    }
    if include_multishot:
        recipes["axpby_ms"] = (lambda: K.axpby(3, 5), {"pe_limit": 1})
    if include_loops:
        recipes["div_loop"] = (lambda: K.div_loop(7), {})
    return recipes


def serve_classes(engine, length: int, include_loops: bool = True,
                  include_multishot: bool = True) -> Dict[str, object]:
    """Compile the standard serve workload mix on ``engine``; returns
    ``{label: CompiledArtifact}``.

    The mix covers the scheduling shapes the paper's traffic story needs:
    short streaming kernels (relu/vadd/fft — the latency-sensitive class),
    a reduction (mac1), a multi-shot plan (axpby under ``pe_limit=1`` —
    the preemptible long request), and an irregular loop (div_loop,
    data-dependent trip count). ``include_loops=False`` keeps the mix
    inside the pallas capability set (loop state is sim-only)."""
    return {label: engine.compile(fn(), **kw)
            for label, (fn, kw) in class_recipes(
                length, include_loops=include_loops,
                include_multishot=include_multishot).items()}


def request_inputs(artifact, length: int,
                   rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Seeded input streams for one request (recirculating kernels get the
    positive operand range the loop semantics require — same convention as
    benchmarks/bench_engine.py)."""
    g = artifact.dfg
    lo, hi = (1, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def make_labeled_requests(classes: Dict[str, object],
                          times: Sequence[float], length: int,
                          rng: np.random.Generator,
                          weights: Optional[Dict[str, float]] = None
                          ) -> List[Tuple[float, str,
                                          Dict[str, np.ndarray]]]:
    """Assign each arrival time a seeded class choice + input streams,
    keyed by class *label* instead of a compiled artifact.

    Returns ``[(t_us, label, inputs), ...]`` sorted by time — the shape
    :meth:`repro.fleet.FleetEngine.drive` ingests (the fleet re-binds
    each label to the target fabric's geometry-specific artifact).
    Consumes the rng identically to :func:`make_requests`, so the same
    seed yields the same request stream either way — that is what lets a
    fleet soak be digest-compared against a single-engine oracle."""
    labels = sorted(classes)
    if weights is None:
        p = np.full(len(labels), 1.0 / len(labels))
    else:
        w = np.asarray([float(weights.get(l, 1.0)) for l in labels])
        p = w / w.sum()
    picks = rng.choice(len(labels), size=len(times), p=p)
    reqs = []
    for t, k in zip(times, picks):
        label = labels[int(k)]
        reqs.append((float(t), label,
                     request_inputs(classes[label], length, rng)))
    reqs.sort(key=lambda r: r[0])
    return reqs


def make_requests(classes: Dict[str, object], times: Sequence[float],
                  length: int, rng: np.random.Generator,
                  weights: Optional[Dict[str, float]] = None
                  ) -> List[Tuple[float, object, Dict[str, np.ndarray]]]:
    """Assign each arrival time a seeded class choice + input streams.

    Returns ``[(t_us, artifact, inputs), ...]`` sorted by time — exactly
    the shape :meth:`repro.serve.ServeEngine.drive` ingests. ``weights``
    biases the class mix (default uniform)."""
    return [(t, classes[label], ins)
            for t, label, ins in make_labeled_requests(
                classes, times, length, rng, weights)]
