"""SLO tracking: per-class latency percentiles for the serving loop.

Latencies are recorded in serve-clock microseconds (virtual under
``VirtualClock`` — deterministic; wall under ``WallClock``). Percentiles
are ``numpy.percentile`` on the raw samples, the same definition the
``repro.obs`` histograms use, so an SLO report agrees bit-for-bit with any
offline analysis of the mirrored ``serve.request_latency_us`` metric.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro import obs


class SLOTracker:
    """Collects served-request latencies and judges them against an
    optional p99 budget."""

    def __init__(self, p99_budget_us: Optional[float] = None):
        self.p99_budget_us = p99_budget_us
        self._all: List[float] = []
        self._by_class: Dict[str, List[float]] = {}

    def record(self, config_class: str, latency_us: float) -> None:
        latency_us = float(latency_us)
        self._all.append(latency_us)
        self._by_class.setdefault(config_class, []).append(latency_us)
        obs.observe("serve.request_latency_us", latency_us)

    @property
    def count(self) -> int:
        return len(self._all)

    def percentile(self, p: float,
                   config_class: Optional[str] = None) -> float:
        samples = self._all if config_class is None \
            else self._by_class.get(config_class, [])
        if not samples:
            return float("nan")
        return float(np.percentile(np.asarray(samples), p))

    def _stats(self, samples: List[float]) -> Dict[str, float]:
        a = np.asarray(samples)
        return {"count": len(samples),
                "mean_us": float(a.mean()),
                "p50_us": float(np.percentile(a, 50)),
                "p99_us": float(np.percentile(a, 99)),
                "max_us": float(a.max())}

    def report(self) -> Dict:
        if not self._all:
            return {"count": 0, "p99_budget_us": self.p99_budget_us,
                    "met": None, "per_class": {}}
        out = self._stats(self._all)
        out["per_class"] = {c: self._stats(s)
                            for c, s in sorted(self._by_class.items())}
        out["p99_budget_us"] = self.p99_budget_us
        out["met"] = None if self.p99_budget_us is None \
            else bool(out["p99_us"] <= self.p99_budget_us)
        return out
