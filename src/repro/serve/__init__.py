"""repro.serve — always-on serving on top of the batching Engine.

Turns the synchronous ``Engine.submit/flush`` library call into a
service: asynchronously arriving request streams, continuous config-class
batching (size / deadline / class-switch close), shot-boundary preemption
of long multi-shot plans, bounded-queue admission control with named
``AdmissionError`` rejections, and SLO tracking — all replayable
bit-exactly under a :class:`VirtualClock` (DESIGN.md §14).

Two front ends over one deterministic state machine:

  * :class:`ServeEngine.drive` — discrete-event loop under a virtual
    clock (tests, benchmarks, trace replay);
  * :class:`Server` — worker thread + thread-safe ingress queue under a
    wall clock (real always-on operation).

Not to be confused with ``repro.launch.serve_lm`` (the LM
prefill/decode launch driver) — this package serves CGRA kernel
requests.
"""
from repro.serve.clock import VirtualClock, WallClock
from repro.serve.health import LivenessProbe
from repro.serve.load import (artifact_skip_reason, bursty_arrival_times,
                              compile_recipe, make_labeled_requests,
                              make_requests, mix_recipes, model_classes,
                              poisson_arrival_times, recipe_skip_reason,
                              request_inputs, serve_classes)
from repro.serve.loop import (AdmissionError, ServeConfig, ServeEngine,
                              Server, Ticket)
from repro.serve.slo import SLOTracker

__all__ = [
    "AdmissionError", "LivenessProbe", "Server", "ServeConfig",
    "ServeEngine", "SLOTracker", "Ticket", "VirtualClock", "WallClock",
    "artifact_skip_reason", "bursty_arrival_times", "compile_recipe",
    "make_labeled_requests", "make_requests", "mix_recipes",
    "model_classes", "poisson_arrival_times", "recipe_skip_reason",
    "request_inputs", "serve_classes",
]
