"""The always-on serving engine: async ingestion, continuous config-class
batching, shot-boundary preemption, admission control (DESIGN.md §14).

``Engine.submit/flush`` amortizes reconfiguration only *within one
synchronous flush* — the library-call shape. :class:`ServeEngine` turns
that into a service: requests arrive asynchronously, are admitted against
a bounded queue (named ``AdmissionError`` rejections = backpressure),
grouped **continuously** by config class in per-class FIFO queues, and
dispatched by a rolling batcher that closes a batch on

  * **size**     — the class accumulated ``max_batch`` requests;
  * **deadline** — the class's oldest request waited ``max_wait_us``;
  * **switch**   — other classes have work too (work-conserving under a
                   mixed backlog; the open batch never holds the fabric
                   hostage);
  * **drain**    — no further arrivals can come (shutdown flush).

Long requests (multi-shot plans) execute through
``Engine.iter_shots`` and are **preempted at shot boundaries** whenever
another class's head request has waited ``preempt_wait_us`` — protecting
short-kernel latency; the preempted plan resumes later (paying the
reconfiguration preemption really costs) with bit-exact results.

Determinism: under a :class:`~repro.serve.clock.VirtualClock` the loop is
a discrete-event simulation — service time is the engine's modeled cycle
count times ``us_per_cycle``, and every decision lands in ``self.trace``,
whose sha1 (:meth:`ServeEngine.trace_digest`) replays identically across
processes for the same seed. :class:`Server` wraps the same state machine
in a worker thread + ingress queue for real wall-clock operation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import queue as _queue
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.serve.clock import VirtualClock, WallClock
from repro.serve.slo import SLOTracker


class AdmissionError(RuntimeError):
    """A request the service refused to take on — bounded-queue
    backpressure or a drained (stalled) class. The message names the
    class, the reason, and the capacity involved, mirroring the
    ``CapabilityError`` style of naming every offending condition."""


# ticket lifecycle states
QUEUED, RUNNING, DONE, REJECTED, FAILED = (
    "queued", "running", "done", "rejected", "failed")


class Ticket:
    """One request's journey through the service. Thread-safe completion:
    ``result()`` blocks on an event in wall-clock mode and returns
    immediately in virtual mode (completion is synchronous there)."""

    __slots__ = ("rid", "artifact", "inputs", "cls", "t_arrival", "t_done",
                 "status", "outputs", "error", "_ev")

    def __init__(self, artifact, inputs: Dict[str, np.ndarray]):
        self.rid: Optional[int] = None          # assigned at offer()
        self.artifact = artifact
        self.inputs = inputs
        self.cls: str = artifact.config_class
        self.t_arrival: Optional[float] = None
        self.t_done: Optional[float] = None
        self.status = QUEUED
        self.outputs: Optional[Dict[str, np.ndarray]] = None
        self.error: Optional[BaseException] = None
        self._ev = threading.Event()

    @property
    def latency_us(self) -> Optional[float]:
        if self.t_done is None or self.t_arrival is None:
            return None
        return self.t_done - self.t_arrival

    def _complete(self, outputs: Dict[str, np.ndarray], t: float) -> None:
        self.outputs, self.t_done, self.status = outputs, t, DONE
        self._ev.set()

    def _reject(self, err: BaseException, t: float) -> None:
        self.error, self.t_done, self.status = err, t, REJECTED
        self._ev.set()

    def _fail(self, err: BaseException, t: float) -> None:
        self.error, self.t_done, self.status = err, t, FAILED
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> Dict[str, np.ndarray]:
        if not self._ev.wait(timeout):
            raise TimeoutError(f"request {self.rid} ({self.cls}) still "
                               f"pending after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.outputs


@dataclasses.dataclass
class ServeConfig:
    """Serving policy knobs. Times are serve-clock microseconds."""

    max_batch: int = 8              # batch-close on size
    max_wait_us: float = 400.0      # batch-close deadline (head-of-line age)
    queue_capacity: int = 64        # admission bound across all classes
    preempt_wait_us: float = 150.0  # waiting head age that preempts a plan
    us_per_cycle: float = 0.01      # modeled fabric clock (100 MHz)
    slo_p99_us: Optional[float] = None   # report-only budget


class _Exec:
    """A preemptible in-flight execution (one multi-shot request)."""

    __slots__ = ("ticket", "handle", "gen", "shot_i", "n_shots")

    def __init__(self, ticket: Ticket, handle, gen):
        self.ticket = ticket
        self.handle = handle
        self.gen = gen
        self.shot_i = -1
        self.n_shots = ticket.artifact.n_shots


def _noop_ingest(now: float) -> None:
    return None


class ServeEngine:
    """Deterministic single-worker serving state machine over an
    :class:`repro.engine.Engine`.

    Drive it one of two ways: :meth:`drive` (discrete-event loop under a
    ``VirtualClock`` — tests, benchmarks, replay) or via :class:`Server`
    (worker thread + ingress queue under a ``WallClock``). The engine
    passed in is owned exclusively by this service — nothing else may
    submit to it."""

    def __init__(self, engine, config: Optional[ServeConfig] = None,
                 clock=None, probe=None):
        self.engine = engine
        self.cfg = config or ServeConfig()
        self.clock = clock or VirtualClock()
        self.probe = probe
        self.slo = SLOTracker(self.cfg.slo_p99_us)
        self._queues: Dict[str, Deque[Ticket]] = {}
        self._paused: Dict[str, _Exec] = {}
        self._stalled: set = set()
        self._depth = 0
        self._ids = itertools.count()
        self._last_class: Optional[str] = None
        self.trace: List[tuple] = []
        self.served: List[Ticket] = []
        self.rejected: List[Ticket] = []
        self.failed: List[Ticket] = []
        self.offered = 0
        self.preemptions = 0
        self.batches = 0
        self.close_reasons: Dict[str, int] = {}

    # -- ingestion ---------------------------------------------------------
    def offer(self, artifact, inputs: Dict[str, np.ndarray],
              t: Optional[float] = None,
              ticket: Optional[Ticket] = None) -> Ticket:
        """Admit (or reject) one arriving request. ``t`` is the arrival
        time (defaults to the clock); rejection is synchronous and named.
        """
        tk = ticket if ticket is not None else Ticket(artifact, inputs)
        tk.rid = next(self._ids)
        now = self.clock.now() if t is None else float(t)
        tk.t_arrival = now
        self.offered += 1
        self._trace("arrive", now, tk.rid, tk.cls)
        if tk.cls in self._stalled:
            return self._refuse(tk, now, AdmissionError(
                f"{tk.artifact.name}: class {tk.cls} is drained (stalled "
                f"backend) — request {tk.rid} rejected"))
        if self._depth >= self.cfg.queue_capacity:
            return self._refuse(tk, now, AdmissionError(
                f"{tk.artifact.name}: queue full "
                f"({self._depth}/{self.cfg.queue_capacity}) — request "
                f"{tk.rid} rejected (class {tk.cls})"))
        self._queues.setdefault(tk.cls, deque()).append(tk)
        self._depth += 1
        obs.set_gauge("serve.queue_depth", self._depth)
        return tk

    def requeue(self, tk: Ticket) -> None:
        """Re-admit an already-admitted ticket drained from a failed peer
        (fleet fault-drain, DESIGN.md §15) WITHOUT re-counting it as
        offered or re-assigning its rid.

        The ticket keeps its original arrival stamp (queue age keeps
        counting toward deadlines and preemption), and it is inserted
        into its class FIFO in rid order, so class-FIFO completion order
        survives a drain. A paused multi-shot ticket restarts from shot
        zero here — re-execution is bit-exact, so no partial state needs
        to move."""
        now = self.clock.now()
        tk.status = QUEUED
        q = self._queues.setdefault(tk.cls, deque())
        pos = len(q)
        while pos > 0 and q[pos - 1].rid > tk.rid:
            pos -= 1
        q.insert(pos, tk)
        self._depth += 1
        self._trace("requeue", now, tk.rid, tk.cls)
        obs.set_gauge("serve.queue_depth", self._depth)

    def _refuse(self, tk: Ticket, now: float,
                err: AdmissionError) -> Ticket:
        tk._reject(err, now)
        self.rejected.append(tk)
        self._trace("reject", now, tk.rid, tk.cls)
        obs.inc("serve.rejections")
        return tk

    # -- scheduling --------------------------------------------------------
    def _head_arrival(self, cls: str) -> float:
        ex = self._paused.get(cls)
        if ex is not None:
            return ex.ticket.t_arrival
        return self._queues[cls][0].t_arrival

    def _work_classes(self) -> List[str]:
        return sorted(c for c in set(self._queues) | set(self._paused)
                      if self._paused.get(c) is not None
                      or self._queues.get(c))

    def _pick(self, now: float, can_wait: bool
              ) -> Optional[Tuple[str, str]]:
        """Choose the next (config class, batch-close reason) to dispatch,
        or None to keep accumulating. Deterministic: ties break on
        (head arrival, class name)."""
        work = self._work_classes()
        if not work:
            return None
        heads = {c: self._head_arrival(c) for c in work}
        expired = [c for c in work if now - heads[c] >= self.cfg.max_wait_us]
        if expired:
            return min(expired, key=lambda c: (heads[c], c)), "deadline"
        # sticky: keep the fabric on its current class while it has work
        cls = self._last_class if self._last_class in heads \
            else min(work, key=lambda c: (heads[c], c))
        if self._paused.get(cls) is not None:
            # a paused plan must not resume past the very backlog that
            # earned its preemption — yield to the waiting class first
            if len(work) > 1 and self._preempt_due(cls, now):
                other = min((c for c in work if c != cls),
                            key=lambda c: (heads[c], c))
                if self._paused.get(other) is not None:
                    return other, "resume"
                return other, "switch"
            return cls, "resume"
        if len(self._queues.get(cls, ())) >= self.cfg.max_batch:
            return cls, "size"
        if len(work) > 1:
            return cls, "switch"       # mixed backlog: work-conserving
        if not can_wait:
            return cls, "drain"        # nothing else will ever arrive
        return None                    # lone small batch: accumulate

    def _next_deadline(self) -> Optional[float]:
        work = self._work_classes()
        if not work:
            return None
        return min(self._head_arrival(c) for c in work) + \
            self.cfg.max_wait_us

    def _preempt_due(self, running_cls: str, now: float) -> bool:
        for c in self._work_classes():
            if c != running_cls and \
                    now - self._head_arrival(c) >= self.cfg.preempt_wait_us:
                return True
        return False

    # -- execution ---------------------------------------------------------
    def _dispatch(self, cls: str, reason: str,
                  ingest: Callable[[float], None] = _noop_ingest) -> None:
        now = self.clock.now()
        if reason == "resume" or self._paused.get(cls) is not None:
            ex = self._paused.pop(cls)
            self._trace("resume", now, ex.ticket.rid, ex.shot_i + 1)
            self._run_exec(ex, ingest)
        else:
            q = self._queues[cls]
            if q[0].artifact.n_shots > 1:
                # preemptible unit: one plan at a time through iter_shots
                tk = q.popleft()
                self._depth -= 1
                self._close(now, cls, reason, [tk])
                self._start_exec(tk, ingest)
            else:
                batch = []
                while q and len(batch) < self.cfg.max_batch \
                        and q[0].artifact.n_shots == 1:
                    # a queued multi-shot plan ends the sweep: it must go
                    # through iter_shots to stay preemptible
                    batch.append(q.popleft())
                self._depth -= len(batch)
                self._close(now, cls, reason, batch)
                self._run_batch(batch)
        self._last_class = cls
        obs.set_gauge("serve.queue_depth", self._depth)

    def _close(self, now: float, cls: str, reason: str,
               batch: Sequence[Ticket]) -> None:
        self.batches += 1
        self.close_reasons[reason] = self.close_reasons.get(reason, 0) + 1
        self._trace("close", now, cls, reason,
                    tuple(tk.rid for tk in batch))
        obs.inc("serve.batches_closed")
        obs.inc(f"serve.batch_close.{reason}")
        obs.observe("serve.batch_size", len(batch))

    def _run_batch(self, batch: List[Ticket]) -> None:
        """One continuous-batcher unit: same-class single-shot requests
        through ``Engine.submit``/``flush`` (pallas additionally lane-
        batches them into one grid). Service time = modeled cycles."""
        before = self.engine.tally.total
        handles = []
        for tk in batch:
            tk.status = RUNNING
            try:
                handles.append(self.engine.submit(tk.artifact, tk.inputs))
            except Exception as e:              # named capability/validation
                handles.append(None)
                self._fail(tk, e)
        try:
            self.engine.flush()
        except Exception as e:
            for tk, h in zip(batch, handles):
                if h is not None and not h._done:
                    self.engine.cancel(h)
                    self._fail(tk, e)
        self.clock.advance(
            (self.engine.tally.total - before) * self.cfg.us_per_cycle)
        done_t = self.clock.now()
        completed = []
        for tk, h in zip(batch, handles):
            if h is not None and h._done:
                tk._complete(h.result(), done_t)
                self.served.append(tk)
                self.slo.record(tk.cls, tk.latency_us)
                completed.append(tk.rid)
        if completed:
            self._trace("complete", done_t, tuple(completed))
        if self.probe is not None:
            self.probe.beat()

    def _start_exec(self, tk: Ticket,
                    ingest: Callable[[float], None]) -> None:
        tk.status = RUNNING
        try:
            h = self.engine.prepare(tk.artifact, tk.inputs)
        except Exception as e:
            self._fail(tk, e)
            return
        self._run_exec(_Exec(tk, h, self.engine.iter_shots(h)), ingest)

    def _run_exec(self, ex: _Exec,
                  ingest: Callable[[float], None]) -> None:
        """Advance a preemptible execution shot by shot until it finishes
        or a waiting class earns a preemption."""
        tk = ex.ticket
        while True:
            before = self.engine.tally.total
            try:
                i, n = next(ex.gen)
            except StopIteration:
                now = self.clock.now()
                tk._complete(ex.handle.result(), now)
                self.served.append(tk)
                self.slo.record(tk.cls, tk.latency_us)
                self._trace("complete", now, (tk.rid,))
                return
            except Exception as e:
                self._fail(tk, e)
                return
            ex.shot_i = i
            self.clock.advance(
                (self.engine.tally.total - before) * self.cfg.us_per_cycle)
            now = self.clock.now()
            self._trace("shot", now, tk.rid, i)
            if self.probe is not None:
                self.probe.beat()
            ingest(now)       # arrivals that landed during this shot
            if i + 1 < n and self._preempt_due(tk.cls, now):
                self._paused[tk.cls] = ex
                self.preemptions += 1
                self._trace("preempt", now, tk.rid, i + 1)
                obs.inc("serve.preemptions")
                return

    def _fail(self, tk: Ticket, err: BaseException) -> None:
        now = self.clock.now()
        tk._fail(err, now)
        self.failed.append(tk)
        self._trace("fail", now, tk.rid, type(err).__name__)
        obs.inc("serve.failures")

    # -- liveness ----------------------------------------------------------
    def check_liveness(self, now: Optional[float] = None) -> List[Ticket]:
        """Consult the probe; on a stall, drain the stalled (= last
        dispatched) class's queue with named rejections. Returns the
        drained tickets."""
        if self.probe is None or not self.probe.stalled(now):
            return []
        cls = self._last_class
        if cls is None:
            return []
        return self.drain_class(
            cls, f"backend stalled (no heartbeat for "
                 f">{self.probe.timeout_s}s)")

    def drain_class(self, cls: str, reason: str) -> List[Ticket]:
        """Reject every queued (and paused) request of ``cls`` with a
        named ``AdmissionError``; future arrivals of the class are
        refused until :meth:`reopen_class`."""
        now = self.clock.now()
        drained: List[Ticket] = []
        ex = self._paused.pop(cls, None)
        if ex is not None:
            drained.append(ex.ticket)
        q = self._queues.get(cls)
        while q:
            drained.append(q.popleft())
            self._depth -= 1
        self._stalled.add(cls)
        for tk in drained:
            self._refuse(tk, now, AdmissionError(
                f"class {cls} drained: {reason} — request {tk.rid} "
                f"rejected"))
        self._trace("drain", now, cls, len(drained))
        obs.inc("serve.drains")
        obs.set_gauge("serve.queue_depth", self._depth)
        return drained

    def reopen_class(self, cls: str) -> None:
        self._stalled.discard(cls)

    # -- the deterministic discrete-event loop -----------------------------
    def drive(self, arrivals: Sequence[Tuple[float, object, Dict]]) -> Dict:
        """Serve a whole arrival schedule ``[(t_us, artifact, inputs)...]``
        under the virtual clock; returns :meth:`report`.

        This is the replayable mode: with the same arrivals (same seed)
        the scheduling trace and every output are bit-identical across
        processes."""
        if not self.clock.virtual:
            raise ValueError("drive() requires a VirtualClock; use Server "
                             "for wall-clock operation")
        pending = list(arrivals)
        for (a, _, _), (b, _, _) in zip(pending, pending[1:]):
            if b < a:
                raise ValueError("arrivals must be sorted by time")
        i = 0

        def ingest(now: float) -> None:
            nonlocal i
            while i < len(pending) and pending[i][0] <= now:
                t, art, ins = pending[i]
                i += 1
                self.offer(art, ins, t=t)

        while True:
            now = self.clock.now()
            ingest(now)
            pick = self._pick(now, can_wait=i < len(pending))
            if pick is not None:
                self._dispatch(pick[0], pick[1], ingest)
                continue
            if i < len(pending):            # idle: jump to the next event
                nxt = pending[i][0]
                dl = self._next_deadline()
                if dl is not None:
                    nxt = min(nxt, dl)
                if nxt <= now:
                    # float plateau: ``head + max_wait_us`` rounds down to
                    # ``now`` while _pick's expiry comparison still judges
                    # the head not-yet-due by one ulp — advance_to cannot
                    # move the clock and the loop would spin forever. The
                    # head IS at its deadline within float precision:
                    # dispatch it. (Every arrival <= now was already
                    # ingested, so nxt <= now implies the deadline side.)
                    work = self._work_classes()
                    heads = {c: self._head_arrival(c) for c in work}
                    self._dispatch(min(work, key=lambda c: (heads[c], c)),
                                   "deadline", ingest)
                    continue
                self.clock.advance_to(nxt)
                continue
            break                           # no work, no future arrivals
        return self.report()

    # -- observability -----------------------------------------------------
    def _trace(self, kind: str, t: float, *args) -> None:
        self.trace.append((kind, round(float(t), 6)) + args)

    def trace_digest(self) -> str:
        h = hashlib.sha1()
        for ev in self.trace:
            h.update(repr(ev).encode())
        return h.hexdigest()

    def results_digest(self) -> str:
        """sha1 over every served request's outputs in rid order — the
        value half of the replay contract."""
        h = hashlib.sha1()
        for tk in sorted(self.served, key=lambda t: t.rid):
            h.update(f"{tk.rid}|{tk.cls}".encode())
            for name in sorted(tk.outputs):
                h.update(name.encode())
                h.update(np.ascontiguousarray(
                    np.asarray(tk.outputs[name], dtype=np.int64)).tobytes())
        return h.hexdigest()

    def steady_window_us(self) -> Optional[float]:
        """Width of the steady-state service window: first arrival of any
        *served* (admitted-and-completed) request to the last completion.
        The wall figure (``now_us``) additionally counts the pre-traffic
        lead-in and the drain tail after the final admission, so
        ``served / now_us`` understates sustained throughput — under
        light load most of the wall duration is drain (ISSUE 9 satellite:
        throughput_rps below offered_rps with zero rejections). ``None``
        until something was served."""
        if not self.served:
            return None
        t0 = min(tk.t_arrival for tk in self.served)
        t1 = max(tk.t_done for tk in self.served)
        return t1 - t0

    def report(self) -> Dict:
        st = self.engine.stats
        return {
            "offered": self.offered,
            "served": len(self.served),
            "rejected": len(self.rejected),
            "failed": len(self.failed),
            "in_flight": self._depth + len(self._paused),
            "preemptions": self.preemptions,
            "batches": self.batches,
            "close_reasons": dict(sorted(self.close_reasons.items())),
            "config_cycles_paid": st.config_cycles_paid,
            "config_cycles_naive": st.config_cycles_naive,
            "config_cycles_saved": st.config_cycles_saved,
            "now_us": self.clock.now(),
            "steady_window_us": self.steady_window_us(),
            "latency": self.slo.report(),
            "trace_digest": self.trace_digest(),
        }


_STOP = object()


class Server:
    """Always-on wall-clock front end: a worker thread drains a thread-safe
    ingress queue into a :class:`ServeEngine` under a ``WallClock``.

    ``submit()`` never blocks the caller on execution — it enqueues and
    returns a :class:`Ticket` whose ``result(timeout)`` waits for
    completion; admission control (bounded queue, named rejections)
    happens on the worker, and the rejection surfaces through the same
    ticket. Use as a context manager; exit stops the worker after a final
    drain flush, so no accepted request is ever lost."""

    def __init__(self, engine, config: Optional[ServeConfig] = None,
                 probe=None, poll_s: float = 0.002):
        self.core = ServeEngine(engine, config, clock=WallClock(),
                                probe=probe)
        self._ingress: _queue.Queue = _queue.Queue()
        self._poll = poll_s
        self._stopping = False
        self._thread = threading.Thread(target=self._run,
                                        name="strela-serve", daemon=True)
        self._thread.start()

    # -- client side -------------------------------------------------------
    def submit(self, artifact, inputs: Dict[str, np.ndarray]) -> Ticket:
        if self._stopping:
            raise AdmissionError(
                f"{artifact.name}: server is stopping — request refused")
        tk = Ticket(artifact, inputs)
        # stamp arrival client-side so ingress-queue wait counts toward
        # latency and max_wait_us/preempt_wait_us aging
        tk.t_arrival = self.core.clock.now()
        self._ingress.put(tk)
        return tk

    def stop(self, timeout: Optional[float] = 30.0) -> Dict:
        """Drain-and-stop: everything already accepted (or sitting in the
        ingress queue) is served before the worker exits."""
        self._stopping = True
        self._ingress.put(_STOP)
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("serve worker failed to drain and stop")
        # a submit() that raced past the _stopping check may have enqueued
        # after the worker's final drain — reject it by name, don't strand it
        now = self.core.clock.now()
        while True:
            try:
                item = self._ingress.get_nowait()
            except _queue.Empty:
                break
            if item is not _STOP:
                self.core._refuse(item, now, AdmissionError(
                    f"{item.artifact.name}: server stopped — request "
                    f"refused"))
        return self.core.report()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        if self._thread.is_alive():
            self.stop()

    # -- worker side -------------------------------------------------------
    def _drain_ingress(self, block: bool) -> bool:
        """Move ingress items into the core; returns whether _STOP was
        seen."""
        stop = False
        try:
            item = self._ingress.get(timeout=self._poll) if block \
                else self._ingress.get_nowait()
        except _queue.Empty:
            return False
        while True:
            if item is _STOP:
                stop = True
            else:
                self.core.offer(item.artifact, item.inputs,
                                t=item.t_arrival, ticket=item)
            try:
                item = self._ingress.get_nowait()
            except _queue.Empty:
                return stop

    def _ingest_cb(self, now: float) -> None:
        if self._drain_ingress(block=False):
            self._stopping = True

    def _run(self) -> None:
        stopping = False
        while True:
            if self._drain_ingress(block=not stopping):
                stopping = True
            # _ingest_cb may have consumed _STOP mid-plan and recorded it
            # only on the shared flag — fold it in or the drain never ends
            stopping = stopping or self._stopping
            now = self.core.clock.now()
            self.core.check_liveness()
            pick = self.core._pick(now, can_wait=not stopping)
            if pick is not None:
                self.core._dispatch(pick[0], pick[1], self._ingest_cb)
                continue
            if stopping and self._ingress.empty() and \
                    not self.core._work_classes():
                return
