"""Clocks for the serving loop: virtual (deterministic replay) and wall.

All serve-layer time is in **microseconds**. The virtual clock is the
testing contract of DESIGN.md §14: under a :class:`VirtualClock` every
scheduling decision of :class:`repro.serve.ServeEngine` is a pure function
of (workload seed, config) — service time advances by the engine's modeled
cycle counts (``us_per_cycle``), never by host wall time, so a soak run
replays bit-identically across processes and machines.
"""
from __future__ import annotations

import time


class VirtualClock:
    """Deterministic discrete-event clock. Time only moves when the
    service loop advances it (arrival gaps, modeled service time)."""

    __slots__ = ("_now",)

    def __init__(self, t0: float = 0.0):
        self._now = float(t0)

    def now(self) -> float:
        return self._now

    def advance(self, dt_us: float) -> float:
        if dt_us < 0:
            raise ValueError(f"virtual clock cannot run backwards "
                             f"(dt={dt_us})")
        self._now += dt_us
        return self._now

    def advance_to(self, t_us: float) -> float:
        self._now = max(self._now, float(t_us))
        return self._now

    @property
    def virtual(self) -> bool:
        return True


class WallClock:
    """Real time (``time.perf_counter`` in microseconds). ``advance*`` are
    no-ops: wall time flows on its own while the engine executes."""

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def advance(self, dt_us: float) -> float:
        return self.now()

    def advance_to(self, t_us: float) -> float:
        return self.now()

    @property
    def virtual(self) -> bool:
        return False
