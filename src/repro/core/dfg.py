"""Data-Flow Graph IR for STRELA kernels.

A DFG is the unit the paper offloads to the fabric (Sec. IV, Fig. 5): a graph
of arithmetic nodes (ALU), comparators, and elastic control nodes (Branch /
Merge / if-else Mux), with INPUT nodes fed by Input Memory Nodes and OUTPUT
nodes drained by Output Memory Nodes. Reductions use a feedback accumulator
inside the ALU (``acc_init`` + ``emit_every``), matching the immediate
feedback loop + delayed-valid mechanism of the microarchitecture.

Token semantics (static dataflow):
  * INPUT produces one token per stream element.
  * elementwise nodes (ALU/CMP/MUX) fire once per joined input token set.
  * ALU with ``acc_init is not None`` accumulates; with ``emit_every=k`` it
    emits one token every k firings (dot products / reductions) — k=0 means
    "emit only the final value".
  * BRANCH forwards its data token to port ``t`` when ctrl!=0 else ``f``.
  * MERGE forwards whichever input holds a token (producers alternate under
    complementary predicates, the only pattern the fabric supports).
  * SCAN nodes capture loop-carried recurrences (dither error, find2min
    running minima): ``y_t, s_t = f(x_t, s_{t-1})`` expressed with the same
    ALU/CMP/MUX vocabulary in an inner sub-graph.

The IR deliberately stays at the granularity a PE can implement: each node
maps to exactly one PE (comparisons must sit in their own PE — Sec. IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.isa import AluOp, CmpOp

# Node kinds
INPUT = "input"
OUTPUT = "output"
CONST = "const"
ALU = "alu"
CMP = "cmp"
MUX = "mux"           # if/else datapath multiplexer (JOIN_CTRL + OutMux.MUX)
BRANCH = "branch"     # valid-signal demux (JOIN_CTRL + branch valids)
MERGE = "merge"       # confluence of two complementary paths

KINDS = (INPUT, OUTPUT, CONST, ALU, CMP, MUX, BRANCH, MERGE)


@dataclasses.dataclass
class Node:
    name: str
    kind: str
    op: Optional[AluOp | CmpOp] = None
    value: Optional[int] = None          # CONST: the constant
    acc_init: Optional[int] = None       # ALU: immediate-feedback accumulator
    emit_every: int = 1                  # ALU reduction: tokens per emission
                                         #   (0 = emit once at end of stream)
    # port names for readability; data ports are positional ("a","b","ctrl")

    def is_reduction(self) -> bool:
        return self.kind == ALU and self.acc_init is not None


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str                 # node name
    src_port: str            # "out" | "t" | "f"  (branch has two outs)
    dst: str
    dst_port: str            # "a" | "b" | "ctrl"
    back: bool = False       # loop-carried (non-immediate feedback loop):
                             #   consumer sees the producer's *previous* token
    init: Optional[int] = 0  # initial token on a back edge (register init);
                             #   None = *recirculation* edge of a
                             #   data-dependent loop: no initial token, the
                             #   consumer waits for the first real one


@dataclasses.dataclass
class DFG:
    """A validated dataflow graph plus its I/O ordering."""

    name: str
    nodes: Dict[str, Node]
    edges: List[Edge]
    inputs: List[str]        # INPUT node names, in IMN order (north border)
    outputs: List[str]       # OUTPUT node names, in OMN order (south border)

    def __getstate__(self):
        # drop analysis memos (e.g. the executor's gated-loop plan) so
        # pickled artifacts stay lean and deterministic
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_")}

    # -- construction helpers ----------------------------------------------
    @classmethod
    def build(cls, name: str) -> "DFGBuilder":
        return DFGBuilder(name)

    # -- queries -------------------------------------------------------------
    def in_edges(self, node: str) -> List[Edge]:
        return [e for e in self.edges if e.dst == node]

    def out_edges(self, node: str) -> List[Edge]:
        return [e for e in self.edges if e.src == node]

    def operand(self, node: str, port: str) -> Optional[Edge]:
        for e in self.edges:
            if e.dst == node and e.dst_port == port:
                return e
        return None

    def n_ops(self) -> int:
        """Arithmetic-operation count per stream element (paper Sec. VII-B:
        'only arithmetic operations are considered'; for control-driven
        kernels 'all the enabled FUs are counted')."""
        arith = sum(1 for n in self.nodes.values() if n.kind == ALU)
        ctrl = sum(1 for n in self.nodes.values() if n.kind in (CMP, MUX, BRANCH, MERGE))
        return arith if arith and not ctrl else arith + ctrl

    def has_feedback(self) -> bool:
        """True if any loop-carried dependency (accumulator or back edge)."""
        return (any(n.is_reduction() for n in self.nodes.values())
                or any(e.back for e in self.edges))

    def back_edges(self) -> List[Edge]:
        return [e for e in self.edges if e.back]

    def is_static_rate(self) -> bool:
        """True when the token *schedule* is independent of input values:
        no Branch (value-steered leg selection) and no Merge (occupancy-
        steered confluence) anywhere. Elementwise chains, MUX conditionals,
        reductions, and loop-carried state cells all qualify — every node
        fires on a fixed count schedule — so one cycle-accurate simulation
        per (mapping, length, layout, bus) is valid for *all* input values
        (the ``TimingTrace`` cache, ISSUE 4). Recirculating graphs always
        contain a Merge, hence never qualify."""
        return not any(n.kind in (BRANCH, MERGE) for n in self.nodes.values())

    def has_recirculation(self) -> bool:
        """True if the graph contains a data-dependent loop: a back edge with
        no initial token (``init is None``), i.e. a token recirculates through
        Branch/Merge until its loop predicate releases it. Such graphs have
        data-dependent firing counts and need token-driven execution."""
        return any(e.back and e.init is None for e in self.edges)

    def recirculation_nodes(self) -> set:
        """Functional nodes inside any data-dependent loop body: everything
        on a forward path consumer ->* producer of a recirculation edge."""
        fwd: Dict[str, List[str]] = {n: [] for n in self.nodes}
        rev: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for e in self.edges:
            if not e.back:
                fwd[e.src].append(e.dst)
                rev[e.dst].append(e.src)

        def _reach(start: str, adj: Dict[str, List[str]]) -> set:
            seen, stack = {start}, [start]
            while stack:
                for nxt in adj[stack.pop()]:
                    if nxt not in seen:
                        seen.add(nxt)
                        stack.append(nxt)
            return seen

        body: set = set()
        for e in self.edges:
            if e.back and e.init is None:
                members = _reach(e.dst, fwd) & _reach(e.src, rev)
                members.update((e.src, e.dst))
                body |= members
        return body

    def topo_order(self) -> List[str]:
        """Topological order ignoring back edges (loop-carried state) and
        ALU-internal feedback."""
        indeg = {n: 0 for n in self.nodes}
        for e in self.edges:
            if not e.back:
                indeg[e.dst] += 1
        ready = sorted([n for n, d in indeg.items() if d == 0])
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for e in self.out_edges(n):
                if e.back:
                    continue
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
            ready.sort()
        if len(order) != len(self.nodes):
            raise ValueError(f"DFG {self.name} has a combinational cycle "
                             f"(only ALU-internal feedback is allowed)")
        return order

    def validate(self) -> None:
        names = set(self.nodes)
        for e in self.edges:
            if e.src not in names or e.dst not in names:
                raise ValueError(f"edge {e} references unknown node")
        for n in self.nodes.values():
            if n.kind in KINDS:
                pass
            else:
                raise ValueError(f"unknown node kind {n.kind}")
            ins = {e.dst_port for e in self.in_edges(n.name)}
            if n.kind == ALU:
                if "a" not in ins:
                    raise ValueError(f"ALU {n.name} missing operand a")
                # operand b may be a const (node.value), an accumulator
                # (acc_init), or an edge (possibly a back edge)
            elif n.kind == CMP and "a" not in ins:
                raise ValueError(f"CMP {n.name} missing operand a")
            elif n.kind == MUX:
                if "a" not in ins or "ctrl" not in ins:
                    raise ValueError(f"MUX {n.name} needs a and ctrl (got {ins})")
                if "b" not in ins and n.value is None:
                    raise ValueError(f"MUX {n.name} needs operand b or a const")
            elif n.kind == BRANCH and ins != {"a", "ctrl"}:
                raise ValueError(f"BRANCH {n.name} needs a, ctrl (got {ins})")
            elif n.kind == MERGE and ins != {"a", "b"}:
                raise ValueError(f"MERGE {n.name} needs a, b (got {ins})")
            elif n.kind == OUTPUT and "a" not in ins:
                raise ValueError(f"OUTPUT {n.name} missing operand")
            elif n.kind in (INPUT, CONST) and ins:
                raise ValueError(f"{n.kind} {n.name} cannot have inputs")
        # comparisons must be isolated PEs: a CMP may not also drive control
        # logic in the same node — structurally guaranteed by one-node-one-PE.
        self.topo_order()  # raises on combinational cycles

    def n_pes_used(self) -> int:
        """PEs needed before routing (mapper may add route-through PEs)."""
        return sum(1 for n in self.nodes.values()
                   if n.kind in (ALU, CMP, MUX, BRANCH, MERGE))

    def canonical_signature(self, rounds: int = 4) -> Tuple[str, ...]:
        """Structural fingerprint invariant under node renaming.

        Weisfeiler-Lehman-style refinement: each node starts from its local
        descriptor (kind, op, folded constant, accumulator parameters) and
        repeatedly absorbs the sorted labels of its port-annotated neighbors.
        Two DFGs built independently (hand-written vs traced) compare equal
        iff they have the same node/edge structure — the frontend golden
        tests rely on this.
        """
        label: Dict[str, str] = {}
        for n in self.nodes.values():
            op = int(n.op) if n.op is not None else -1
            label[n.name] = (f"{n.kind}/{op}/{n.value}/{n.acc_init}/"
                             f"{n.emit_every}")
        for _ in range(rounds):
            nxt: Dict[str, str] = {}
            for name in self.nodes:
                # e.init discriminates: recirculation (None) vs register
                # init value — different machines, different fingerprints
                ins = sorted(f"i:{e.dst_port}<{e.src_port}:{int(e.back)}:"
                             f"{e.init if e.back else ''}:"
                             f"{label[e.src]}" for e in self.in_edges(name))
                outs = sorted(f"o:{e.src_port}>{e.dst_port}:{int(e.back)}:"
                              f"{e.init if e.back else ''}:"
                              f"{label[e.dst]}" for e in self.out_edges(name))
                nxt[name] = label[name] + "|" + ";".join(ins + outs)
            label = nxt
        import hashlib
        return tuple(sorted(hashlib.sha1(l.encode()).hexdigest()[:16]
                            for l in label.values()))


class DFGBuilder:
    """Tiny fluent builder so kernels_lib reads like the paper's figures."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.edges: List[Edge] = []
        self.inputs: List[str] = []
        self.outputs: List[str] = []

    def _add(self, node: Node) -> str:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        return node.name

    def inp(self, name: str) -> str:
        self.inputs.append(name)
        return self._add(Node(name, INPUT))

    def out(self, name: str, src: str, src_port: str = "out") -> str:
        self.outputs.append(name)
        self._add(Node(name, OUTPUT))
        self.edge(src, name, "a", src_port)
        return name

    def const(self, name: str, value: int) -> str:
        return self._add(Node(name, CONST, value=value))

    def alu(self, name: str, op: AluOp, a: Optional[str], b: Optional[str] = None,
            const_b: Optional[int] = None, acc_init: Optional[int] = None,
            emit_every: int = 1, a_port: str = "out", b_port: str = "out") -> str:
        self._add(Node(name, ALU, op=op, value=const_b,
                       acc_init=acc_init, emit_every=emit_every))
        if a is not None:
            self.edge(a, name, "a", a_port)
        if b is not None:
            self.edge(b, name, "b", b_port)
        return name

    def cmp(self, name: str, op: CmpOp, a: Optional[str], b: Optional[str] = None,
            const_b: Optional[int] = None, a_port: str = "out",
            b_port: str = "out") -> str:
        self._add(Node(name, CMP, op=op, value=const_b))
        if a is not None:
            self.edge(a, name, "a", a_port)
        if b is not None:
            self.edge(b, name, "b", b_port)
        return name

    def mux(self, name: str, a: Optional[str], b: Optional[str],
            ctrl: Optional[str], a_port: str = "out", b_port: str = "out",
            ctrl_port: str = "out") -> str:
        self._add(Node(name, MUX))
        if a is not None:
            self.edge(a, name, "a", a_port)
        if b is not None:
            self.edge(b, name, "b", b_port)
        if ctrl is not None:
            self.edge(ctrl, name, "ctrl", ctrl_port)
        return name

    def branch(self, name: str, a: Optional[str], ctrl: Optional[str],
               a_port: str = "out", ctrl_port: str = "out") -> str:
        self._add(Node(name, BRANCH))
        if a is not None:
            self.edge(a, name, "a", a_port)
        if ctrl is not None:
            self.edge(ctrl, name, "ctrl", ctrl_port)
        return name

    def merge(self, name: str, a: Optional[str], b: Optional[str],
              a_port: str = "out", b_port: str = "out") -> str:
        self._add(Node(name, MERGE))
        if a is not None:
            self.edge(a, name, "a", a_port)
        if b is not None:
            self.edge(b, name, "b", b_port)
        return name

    def edge(self, src: str, dst: str, dst_port: str, src_port: str = "out",
             back: bool = False, init: int = 0) -> None:
        self.edges.append(Edge(src, src_port, dst, dst_port, back, init))

    def back_edge(self, src: str, dst: str, dst_port: str,
                  init: Optional[int] = 0, src_port: str = "out") -> None:
        """Loop-carried edge: dst consumes src's previous-iteration token.
        ``init=None`` makes it a recirculation edge (no initial token)."""
        self.edges.append(Edge(src, src_port, dst, dst_port, True, init))

    def done(self) -> DFG:
        g = DFG(self.name, self.nodes, self.edges, self.inputs, self.outputs)
        g.validate()
        return g


def unroll(dfg: DFG, factor: int) -> DFG:
    """Replicate a DFG ``factor`` times (paper mapping strategy 2).

    Replicas are independent lanes; IMN/OMN streams are interleaved round-robin
    by the memory nodes, so replica i processes elements i, i+factor, ...
    """
    if factor <= 1:
        return dfg
    nodes: Dict[str, Node] = {}
    edges: List[Edge] = []
    inputs: List[str] = []
    outputs: List[str] = []
    for k in range(factor):
        sfx = f"@{k}"
        for n in dfg.nodes.values():
            nodes[n.name + sfx] = dataclasses.replace(n, name=n.name + sfx)
        for e in dfg.edges:
            edges.append(Edge(e.src + sfx, e.src_port, e.dst + sfx, e.dst_port,
                              e.back, e.init))
        inputs.extend(i + sfx for i in dfg.inputs)
        outputs.extend(o + sfx for o in dfg.outputs)
    g = DFG(f"{dfg.name}_x{factor}", nodes, edges, inputs, outputs)
    g.validate()
    return g


def unroll_chained(dfg: DFG, factor: int) -> DFG:
    """Unroll a loop-carried kernel with cross-lane state chaining.

    For stateful kernels (e.g. dither's error diffusion) replicas are *not*
    independent: lane k processes elements k, k+factor, ... and the carried
    state flows lane 0 -> 1 -> ... -> factor-1 -> (back to) 0. Every back
    edge of the original DFG becomes a forward edge between consecutive
    lanes, with only the last->first link remaining loop-carried. This is
    the software-pipelined unroll the paper applies to dither (x2).
    """
    if factor <= 1:
        return dfg
    if dfg.has_recirculation():
        # a recirculation edge is not per-element state: chaining it across
        # lanes would feed lane k's mid-iteration tokens into lane k+1's
        # entry merge. Gated loops unroll as independent lanes instead.
        raise ValueError(
            f"{dfg.name}: cross-lane state chaining is undefined for "
            f"data-dependent loops (recirculation back edges); use unroll()")
    backs = dfg.back_edges()
    nodes: Dict[str, Node] = {}
    edges: List[Edge] = []
    inputs: List[str] = []
    outputs: List[str] = []
    for k in range(factor):
        sfx = f"@{k}"
        for n in dfg.nodes.values():
            nodes[n.name + sfx] = dataclasses.replace(n, name=n.name + sfx)
        for e in dfg.edges:
            if e.back:
                continue
            edges.append(Edge(e.src + sfx, e.src_port, e.dst + sfx, e.dst_port))
        inputs.extend(i + sfx for i in dfg.inputs)
        outputs.extend(o + sfx for o in dfg.outputs)
    for e in backs:
        for k in range(factor):
            nk = (k + 1) % factor
            # producer in lane k feeds consumer in lane k+1; the wrap link
            # (last lane -> lane 0) is the only remaining loop carry.
            edges.append(Edge(e.src + f"@{k}", e.src_port,
                              e.dst + f"@{nk}", e.dst_port,
                              back=(nk == 0), init=e.init))
    g = DFG(f"{dfg.name}_c{factor}", nodes, edges, inputs, outputs)
    g.validate()
    return g
