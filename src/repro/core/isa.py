"""STRELA ISA: operation sets and per-PE configuration words.

The paper (Sec. III-C / V-B / V-C) specifies:
  * integer ALU ops: add, sub, mult, shift, AND, OR, XOR
  * comparator ops: "equal to zero" and "greater than zero"
  * Join/Merge modes: Join-without-control / Join-with-control / Merge
  * datapath output mux: ALU | comparator | if-else multiplexer
  * an immediate-feedback-loop mux on one ALU operand (data reductions)
  * initial values for the FU data register and the three valid registers
  * Fork-Sender masks, a programmable delay for the unprocessed valid
  * per-PE configuration of 158 bits total, streamed as five 32-bit words
    (Sec. V-B: the deserializer forms a "152-bit configuration word" = 146
    functional + 6 PE-id; Sec. V-C adds 6 clock-gating bits -> 158). Note
    the paper's Sec. V-C text says "144 bits for reconfigurable elements",
    which is inconsistent with its own 152/158 totals; we follow the totals
    (146 + 6 + 6 = 158).

The paper publishes only field totals, not the internal split; the concrete
layout below is our reconstruction, asserted to sum to exactly 146
functional / 158 total bits in ``tests/test_isa.py``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Tuple

# ---------------------------------------------------------------------------
# Operation sets
# ---------------------------------------------------------------------------


class AluOp(enum.IntEnum):
    """Integer ALU operations supported by every FU (homogeneous fabric)."""

    NOP = 0        # route-through / disabled
    ADD = 1
    SUB = 2
    MUL = 3
    SHL = 4        # "shift" — left
    SHR = 5        # "shift" — arithmetic right
    AND = 6
    OR = 7
    XOR = 8


class CmpOp(enum.IntEnum):
    """Comparator operations (generate 1-bit control tokens)."""

    NONE = 0
    EQZ = 1        # (a - b) == 0  (b defaults to const 0)
    GTZ = 2        # (a - b) >  0


class JoinMergeMode(enum.IntEnum):
    """Modes of the Join/Merge module at the FU front-end (Sec. III-C)."""

    JOIN = 0         # two operand inputs, no control
    JOIN_CTRL = 1    # two operands + control (Branch or if/else mux)
    MERGE = 2        # two operands, internally generated control


class OutMux(enum.IntEnum):
    """Final datapath multiplexer: which unit drives the FU output register."""

    ALU = 0
    CMP = 1
    MUX = 2          # if/else datapath multiplexer


class OperandSel(enum.IntEnum):
    """FU data-input multiplexer sources (Fig. 3)."""

    PORT_N = 0
    PORT_E = 1
    PORT_S = 2
    PORT_W = 3
    CONST = 4
    FEEDBACK = 5     # non-immediate feedback from dout_FU


class CtrlSel(enum.IntEnum):
    """FU control-input sources — PE input ports only (Fig. 3)."""

    PORT_N = 0
    PORT_E = 1
    PORT_S = 2
    PORT_W = 3


# Cardinal order used across the whole code base.
CARDINALS: Tuple[str, ...] = ("N", "E", "S", "W")

# Fork-sender destination order for a *PE input port*:
#   FU operand a, FU operand b, FU control, and the three other PE outputs.
PE_IN_DESTS: Tuple[str, ...] = ("FU_A", "FU_B", "FU_C", "OUT_0", "OUT_1", "OUT_2")

# Fork-sender destination order for the *FU output*:
#   two non-immediate feedback loops + the four cardinal PE outputs.
FU_OUT_DESTS: Tuple[str, ...] = ("FB1", "FB2", "OUT_N", "OUT_E", "OUT_S", "OUT_W")


# ---------------------------------------------------------------------------
# Configuration word
# ---------------------------------------------------------------------------

# (field name, bit width) — functional part; must total 144 bits.
_FUNC_FIELDS: List[Tuple[str, int]] = [
    ("alu_op", 4),             # AluOp
    ("alu_fb_imm", 1),         # immediate feedback mux on ALU operand b
    ("cmp_op", 2),             # CmpOp
    ("jm_mode", 2),            # JoinMergeMode
    ("out_mux", 2),            # OutMux
    ("data_reg_init", 32),     # initial value of the FU data register
    ("valid_reg_init", 3),     # initial values of the three valid registers
    ("fu_fork_mask", 6),       # FU-output Fork-Sender mask (FU_OUT_DESTS)
    ("valid_delay", 6),        # delay of the unprocessed valid (loop exits)
    ("in_a_sel", 3),           # OperandSel
    ("in_b_sel", 3),           # OperandSel
    ("ctrl_sel", 2),           # CtrlSel
    ("const_val", 32),         # per-PE constant operand
    ("in_fork_mask_n", 6),     # PE input-port Fork-Sender masks (PE_IN_DESTS)
    ("in_fork_mask_e", 6),
    ("in_fork_mask_s", 6),
    ("in_fork_mask_w", 6),
    ("out_sel_n", 3),          # PE output-port muxes: 0..3 -> input N/E/S/W,
    ("out_sel_e", 3),          #   4 -> FU out, 5 -> FU out delayed, 6 -> off
    ("out_sel_s", 3),
    ("out_sel_w", 3),
    ("branch_swap", 1),        # swap Branch taken/not-taken valid outputs
    ("reserved", 11),          # reconstruction slack (paper gives totals only)
]

FUNC_BITS = sum(w for _, w in _FUNC_FIELDS)
ID_BITS = 6
GATE_BITS = 6
TOTAL_BITS = FUNC_BITS + ID_BITS + GATE_BITS          # 158 per the paper
WORDS_PER_PE = 5                                      # five 32-bit words


class OutSel(enum.IntEnum):
    """PE output-port mux sources."""

    IN_N = 0
    IN_E = 1
    IN_S = 2
    IN_W = 3
    FU = 4
    FU_DELAYED = 5
    OFF = 6


@dataclasses.dataclass
class PEConfig:
    """Decoded configuration of one PE. Field names mirror ``_FUNC_FIELDS``."""

    alu_op: AluOp = AluOp.NOP
    alu_fb_imm: int = 0
    cmp_op: CmpOp = CmpOp.NONE
    jm_mode: JoinMergeMode = JoinMergeMode.JOIN
    out_mux: OutMux = OutMux.ALU
    data_reg_init: int = 0
    valid_reg_init: int = 0
    fu_fork_mask: int = 0
    valid_delay: int = 0
    in_a_sel: OperandSel = OperandSel.PORT_N
    in_b_sel: OperandSel = OperandSel.PORT_N
    ctrl_sel: CtrlSel = CtrlSel.PORT_N
    const_val: int = 0
    in_fork_mask_n: int = 0
    in_fork_mask_e: int = 0
    in_fork_mask_s: int = 0
    in_fork_mask_w: int = 0
    out_sel_n: OutSel = OutSel.OFF
    out_sel_e: OutSel = OutSel.OFF
    out_sel_s: OutSel = OutSel.OFF
    out_sel_w: OutSel = OutSel.OFF
    branch_swap: int = 0
    reserved: int = 0
    # non-functional fields
    pe_id: int = 0
    gate_mask: int = 0    # per-Elastic-Buffer clock gating (6 EB groups)

    # -- encoding ----------------------------------------------------------
    def encode(self) -> int:
        """Pack into a 158-bit integer (functional | id | gating)."""
        value = 0
        shift = 0
        for name, width in _FUNC_FIELDS:
            field = int(getattr(self, name)) & ((1 << width) - 1)
            value |= field << shift
            shift += width
        value |= (self.pe_id & ((1 << ID_BITS) - 1)) << shift
        shift += ID_BITS
        value |= (self.gate_mask & ((1 << GATE_BITS) - 1)) << shift
        return value

    def to_words(self) -> List[int]:
        """Serialize as five 32-bit configuration words (bus format)."""
        packed = self.encode()
        return [(packed >> (32 * i)) & 0xFFFFFFFF for i in range(WORDS_PER_PE)]

    @classmethod
    def decode(cls, value: int) -> "PEConfig":
        kwargs = {}
        shift = 0
        for name, width in _FUNC_FIELDS:
            raw = (value >> shift) & ((1 << width) - 1)
            shift += width
            kwargs[name] = raw
        pe_id = (value >> shift) & ((1 << ID_BITS) - 1)
        shift += ID_BITS
        gate = (value >> shift) & ((1 << GATE_BITS) - 1)
        cfg = cls(**kwargs)  # type: ignore[arg-type]
        cfg.alu_op = AluOp(cfg.alu_op)
        cfg.cmp_op = CmpOp(cfg.cmp_op)
        cfg.jm_mode = JoinMergeMode(cfg.jm_mode)
        cfg.out_mux = OutMux(cfg.out_mux)
        cfg.in_a_sel = OperandSel(cfg.in_a_sel)
        cfg.in_b_sel = OperandSel(cfg.in_b_sel)
        cfg.ctrl_sel = CtrlSel(cfg.ctrl_sel)
        cfg.out_sel_n = OutSel(cfg.out_sel_n)
        cfg.out_sel_e = OutSel(cfg.out_sel_e)
        cfg.out_sel_s = OutSel(cfg.out_sel_s)
        cfg.out_sel_w = OutSel(cfg.out_sel_w)
        cfg.pe_id = pe_id
        cfg.gate_mask = gate
        return cfg

    @classmethod
    def from_words(cls, words: List[int]) -> "PEConfig":
        assert len(words) == WORDS_PER_PE
        value = 0
        for i, w in enumerate(words):
            value |= (w & 0xFFFFFFFF) << (32 * i)
        return cls.decode(value)


def config_stream(configs: List[PEConfig]) -> List[int]:
    """Flatten PE configs into the 32-bit word stream fetched by IMN-0.

    Mirrors Sec. V-B: each PE's five words are tagged by the 6-bit PE id that
    is part of the encoded word itself, enabling variable-size kernel
    configurations (only active PEs are streamed).
    """
    words: List[int] = []
    for cfg in configs:
        words.extend(cfg.to_words())
    return words


def config_cycles(n_pes: int, n_imns_for_config: int = 1) -> int:
    """Clock cycles to fetch a kernel configuration.

    One IMN fetches ``WORDS_PER_PE`` words per PE, one word/cycle (32-bit bus
    beat), plus a small fixed deserializer/launch overhead. Calibrated against
    Table I: fft uses 16 PEs -> 84 cycles, relu/dither use 14 PEs -> 74.
    With overhead=4: 16*5+4 = 84, 14*5+4 = 74.  (find2min: 16 PEs -> 84.)
    """
    return n_pes * WORDS_PER_PE // n_imns_for_config + 4
