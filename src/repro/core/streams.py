"""Streaming memory nodes (IMN/OMN) and the interleaved multi-bank bus.

Sec. V-B: memory nodes are independent bus masters whose address units
generate affine streams from three CPU-written parameters (initial address,
size, stride); FIFOs between the units and the fabric damp stalls. The
X-HEEP interleaved bus maps word address -> bank ``addr % n_banks``; each
bank serves one beat per cycle, so with 4 interleaved banks the fabric sees
up to 128 bits/cycle (Sec. VI-A).

These descriptors drive (a) the cycle-level elastic simulator's bank
arbiter and (b) the TPU performance path, where each ``StreamSpec`` lowers
to a Pallas ``BlockSpec`` index map (see ``repro/kernels/fabric_stream.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One affine address stream: word addresses base + k*stride, k<size."""

    base: int
    size: int
    stride: int = 1

    def addr(self, k: int) -> int:
        return self.base + k * self.stride

    def bank(self, k: int, n_banks: int) -> int:
        return self.addr(k) % n_banks


@dataclasses.dataclass
class BusConfig:
    """Interleaved-bus model (Sec. V-A): ``n_banks`` single-ported banks."""

    n_banks: int = 4

    def word_bits(self) -> int:
        return 32

    def peak_bits_per_cycle(self) -> int:
        return self.n_banks * self.word_bits()


class BankArbiter:
    """Per-bank round-robin arbitration: one grant per bank per cycle.

    Each bank remembers its last-granted master and serves the next
    requester in cyclic order — the standard interconnect policy, and what
    makes fft's 8 simultaneous memory nodes on 4 banks settle at ~2 cycles
    per element set (Sec. VII-B: 'ideally two clock cycles').
    """

    def __init__(self, bus: BusConfig):
        self.bus = bus
        self._last: Dict[int, int] = {}

    def grant(self, requests: List[int]) -> List[bool]:
        """requests[i] = bank wanted by node i (-1 = no request)."""
        n = len(requests)
        granted = [False] * n
        by_bank: Dict[int, List[int]] = {}
        for i, b in enumerate(requests):
            if b >= 0:
                by_bank.setdefault(b, []).append(i)
        for b, nodes in by_bank.items():
            start = self._last.get(b, -1)
            # pick the first requester strictly after `start` in cyclic order
            pick = min(nodes, key=lambda i: ((i - start - 1) % n))
            granted[pick] = True
            self._last[b] = pick
        return granted


def default_streams(names: List[str], size: int,
                    spread_banks: bool = True,
                    n_banks: int = 4) -> Dict[str, StreamSpec]:
    """Driver-chosen stream placement: consecutive vectors whose bases land
    on different banks (the software convention that minimizes conflicts)."""
    specs = {}
    for i, name in enumerate(names):
        base = i if spread_banks else i * size
        specs[name] = StreamSpec(base=base * (1 if spread_banks else 1),
                                 size=size, stride=n_banks if spread_banks else 1)
    # spread mode: node i walks bank i only (stride = n_banks) — conflict-free
    # when #nodes <= n_banks; beyond that nodes share banks round-robin.
    if spread_banks:
        specs = {name: StreamSpec(base=i % n_banks + (i // n_banks) * n_banks * size,
                                  size=size, stride=n_banks)
                 for i, name in enumerate(names)}
    return specs


def contiguous_streams(names: List[str], size: int) -> Dict[str, StreamSpec]:
    """Naive layout: vectors packed back-to-back, stride-1 (bank rotation)."""
    return {name: StreamSpec(base=i * size, size=size, stride=1)
            for i, name in enumerate(names)}
