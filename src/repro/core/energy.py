"""Power/energy model of the accelerator and SoC, fitted to the paper.

We cannot measure silicon power, so we reproduce the paper's *model
structure* (Sec. V-C / VII-B): hierarchical clock gating means power scales
with (a) how many PEs are configured and of which kind, (b) their switching
activity, (c) active memory nodes and bus traffic, and (d) the duty cycle of
the PE matrix (multi-shot kernels gate the fabric while the CPU re-arms
streams — why Table II's mm consumes 3.99 mW vs fft's 16.84 mW).

    P_cgra = b0*duty + b1*(arith-PE activity) + b2*(ctrl-PE activity)
           + b3*(route-PE count)*duty + b4*(memory-node beat rate) + b5

    P_soc  = g0 + g1*P_cgra + g2*(bus beats/cycle)      [+ CPU term]

Coefficients are least-squares fitted against the 12 published (CGRA mW,
SoC mW) pairs of Tables I/II; the benchmarks report the fit residuals as a
calibration artifact. The per-EB figure the paper gives (~80 uW when used)
is used as a sanity bound on b1..b3.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


def _nnls(A: np.ndarray, y: np.ndarray, iters: int = 20000,
          lr: Optional[float] = None) -> np.ndarray:
    """Non-negative least squares by projected gradient (tiny problems)."""
    A = np.asarray(A, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    x = np.full(A.shape[1], 0.1)
    if lr is None:
        lip = np.linalg.norm(A.T @ A, 2)
        lr = 1.0 / max(lip, 1e-12)
    for _ in range(iters):
        g = A.T @ (A @ x - y)
        x = np.clip(x - lr * g, 0.0, None)
    return x


@dataclasses.dataclass(frozen=True)
class PowerFeatures:
    """Activity features of one offloaded kernel execution."""

    duty: float            # fraction of cycles the PE matrix is unga ted
    arith_act: float       # sum over ALU FUs of firings/cycle (while active)
    ctrl_act: float        # same for cmp/mux/branch/merge FUs
    route_pes: float       # active route-through PEs
    mem_rate: float        # bus beats per active cycle
    cgra_mw_paper: Optional[float] = None
    soc_mw_paper: Optional[float] = None

    def row(self) -> List[float]:
        return [self.duty, self.arith_act, self.ctrl_act,
                self.route_pes * self.duty, self.mem_rate, 1.0]


class PowerModel:
    """CGRA + SoC power predictors, fitted on Table I/II samples."""

    def __init__(self):
        self.beta: Optional[np.ndarray] = None     # CGRA coefficients
        self.gamma: Optional[np.ndarray] = None    # SoC coefficients
        self._samples: List[PowerFeatures] = []

    # -- fitting -----------------------------------------------------------
    def fit(self, samples: Sequence[PowerFeatures]) -> None:
        self._samples = list(samples)
        A = np.array([s.row() for s in samples], dtype=np.float64)
        y = np.array([s.cgra_mw_paper for s in samples], dtype=np.float64)
        # relative weighting (small multi-shot powers matter as much as fft)
        # + non-negativity: power coefficients are physical.
        self.beta = _nnls(A / y[:, None], np.ones_like(y))

        pc = A @ self.beta
        soc = np.array([s.soc_mw_paper for s in samples], dtype=np.float64)
        B = np.stack([np.ones_like(pc), pc,
                      np.array([s.mem_rate for s in samples])], axis=1)
        self.gamma = _nnls(B / soc[:, None], np.ones_like(soc))

    # -- prediction ----------------------------------------------------------
    def cgra_mw(self, f: PowerFeatures) -> float:
        assert self.beta is not None, "fit() first"
        return float(np.array(f.row()) @ self.beta)

    def soc_mw(self, f: PowerFeatures) -> float:
        assert self.gamma is not None, "fit() first"
        pc = self.cgra_mw(f)
        return float(self.gamma[0] + self.gamma[1] * pc
                     + self.gamma[2] * f.mem_rate)

    def report(self) -> List[dict]:
        out = []
        for s in self._samples:
            pc, ps = self.cgra_mw(s), self.soc_mw(s)
            out.append({
                "cgra_mw_model": pc, "cgra_mw_paper": s.cgra_mw_paper,
                "cgra_rel_err": (pc - s.cgra_mw_paper) / s.cgra_mw_paper,
                "soc_mw_model": ps, "soc_mw_paper": s.soc_mw_paper,
                "soc_rel_err": (ps - s.soc_mw_paper) / s.soc_mw_paper,
            })
        return out


# CPU-side power (Tables I/II): near-constant in-order core at 250 MHz
CPU_MW = 3.7
SOC_CPU_MW = 27.2      # mean of the published SoC-CPU column


def features_from_profile(profile, duty: float = 1.0, cgra_mw_paper=None,
                          soc_mw_paper=None) -> PowerFeatures:
    """Build PowerFeatures from a fabric profile
    (``repro.obs.profiler.FabricProfile``): the profiler's per-PE firing
    counts ARE the power model's activity factors, so utilization reports
    and energy reports can never disagree."""
    cycles = max(profile.cycles, 1)
    return PowerFeatures(duty=duty,
                         arith_act=profile.arith_firings / cycles * duty,
                         ctrl_act=profile.ctrl_firings / cycles * duty,
                         route_pes=profile.route_pes,
                         mem_rate=profile.bank_beats / cycles * duty,
                         cgra_mw_paper=cgra_mw_paper,
                         soc_mw_paper=soc_mw_paper)


def features_from_sim(mapping, sim, duty: float = 1.0,
                      cgra_mw_paper=None, soc_mw_paper=None) -> PowerFeatures:
    """Build PowerFeatures from a Mapping + SimResult.

    Delegates through the fabric profiler (``repro.obs.profiler``), the
    single source of truth for per-resource firing attribution."""
    from repro.obs.profiler import profile_sim
    return features_from_profile(profile_sim(mapping, sim), duty=duty,
                                 cgra_mw_paper=cgra_mw_paper,
                                 soc_mw_paper=soc_mw_paper)


def energy_uj(power_mw: float, cycles: int, clock_mhz: float = 250.0) -> float:
    """Energy in microjoules for `cycles` at `clock_mhz`."""
    return power_mw * (cycles / (clock_mhz * 1e6)) * 1e3
