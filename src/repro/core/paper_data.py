"""Published numbers from the paper's tables — used for calibration targets
and side-by-side comparison in benchmarks (never as our own results).

Table I  — one-shot kernels (1024 total input elements).
Table II — multi-shot kernels (sizes in Sec. VII-B).
Table IV — state-of-the-art comparison points.
Hardware: TSMC 65 nm LP, 250 MHz, 4x4 CGRA, 8x32 KB banks (4 interleaved).
"""

CLOCK_MHZ = 250.0

TABLE_I = {
    # kernel: (config_cycles, exec_cycles, n_ops, outputs_per_cycle,
    #          perf_mops, cgra_mw, eff_mops_mw, cpu_cycles, cpu_mw,
    #          speedup, esave_cpu, soc_cgra_mw, soc_cpu_mw, esave_soc)
    "fft":      (84, 523, 2560, 1.95, 1223.71, 16.84, 72.68, 9218, 4.04,
                 17.63, 4.23, 53.84, 27.59, 9.03),
    "relu":     (74, 697, 2048, 1.47, 734.58, 11.51, 63.80, 10759, 3.44,
                 15.44, 4.62, 45.34, 26.59, 9.05),
    "dither":   (74, 4617, 5120, 0.222, 277.24, 9.01, 30.76, 14342, 3.54,
                 3.11, 1.22, 28.84, 26.09, 2.81),
    "find2min": (84, 7175, 9216, 5.57e-4, 321.11, 9.64, 33.31, 14381, 3.37,
                 2.00, 0.70, 28.84, 26.59, 1.85),
}

TABLE_II = {
    # kernel: (total_cycles, n_ops, outputs_per_cycle, perf_mops, cgra_mw,
    #          eff_mops_mw, cpu_cycles, cpu_mw, speedup, esave_cpu,
    #          soc_cgra_mw, soc_cpu_mw, esave_soc)
    "mm16":    (12105, 7936, 2.11e-2, 163.90, 3.99, 41.08, 42181, 3.59,
                3.48, 3.14, 28.34, 27.34, 3.36),
    "mm64":    (297050, 520192, 1.38e-2, 437.80, 7.46, 58.66, 3965254, 3.59,
                13.35, 6.43, 33.84, 27.34, 10.79),
    "conv2d":  (13931, 65348, 2.58e-1, 1172.71, 10.11, 115.96, 259234, 4.09,
                18.61, 7.53, 47.09, 28.09, 11.10),
    "gemm":    (320284, 681000, 1.31e-2, 531.56, 9.91, 53.62, 3438372, 3.54,
                10.74, 3.84, 38.09, 26.59, 7.49),
    "gemver":  (39825, 144120, 3.68e-1, 904.71, 10.36, 87.30, 522364, 3.74,
                13.12, 4.74, 40.34, 27.59, 8.97),
    "gesummv": (12091, 32670, 7.44e-3, 675.50, 8.99, 75.16, 111080, 3.67,
                9.19, 3.75, 38.09, 28.34, 6.84),
    "2mm":     (347446, 603200, 9.21e-3, 434.02, 8.66, 50.10, 3370417, 3.74,
                9.70, 4.19, 36.34, 27.59, 7.37),
    "3mm":     (579309, 1071700, 4.83e-3, 462.49, 8.29, 55.80, 5390990, 3.72,
                9.31, 4.18, 35.84, 27.84, 7.23),
}

# PolyBench 4.2.1 SMALL_DATASET problem sizes (Sec. VI-B)
POLYBENCH_SMALL = {
    "gemm":    {"NI": 60, "NJ": 70, "NK": 80},
    "gemver":  {"N": 120},
    "gesummv": {"N": 90},
    "2mm":     {"NI": 40, "NJ": 50, "NK": 70, "NL": 80},
    "3mm":     {"NI": 40, "NJ": 50, "NK": 60, "NL": 70, "NM": 80},
}

TABLE_IV = {
    # work: {bench: (perf_mops, power_mw, eff)} — post-synthesis except UE-CGRA
    "IPA":      {"mm16": (65.98, 0.49, 134.65)},
    "UE-CGRA":  {"fft": (625.00, 14.01, 44.61)},
    "RipTide":  {"fft": (62.0, 0.24, 258.33)},   # RipTide fft at 50 MHz
    "STRELA":   {"fft": (1223.71, 16.84, 72.68),
                 "mm16": (163.90, 3.99, 41.08),
                 "mm64": (437.80, 7.46, 58.66)},
}

# Area results (Sec. VII-A), for the comparison table
AREA = {
    "pe_um2": 13936.0,
    "cgra_um2": 253442.0,
    "soc_mm2": 2.38,
}
