"""Reference cycle-level simulator (the ``STRELA_SIM=reference`` oracle).

This is the original token-by-token implementation of the elastic-fabric
cycle model, kept verbatim as the differential-checking oracle for the
vectorized simulator in ``elastic_sim.py`` (ISSUE 4): the fast core must
reproduce this module's cycle counts, arrival schedules, and outputs
bit-exactly, and the conformance suite asserts that it does. Do not
optimize this module — its value is that it stays simple and unchanged.

Timing model (Sec. III-C microarchitecture):
  * every PE input port and FU input holds a 2-slot Elastic Buffer with
    **fall-through** forwarding: 0-cycle latency when empty (data/valid
    bypass), full backpressure via the registered ready path. This is the
    only timing consistent with the paper's published IIs — dither's 4-FU
    feedback loop has II=4, i.e. exactly one cycle per FU stage and zero
    per routing hop;
  * PE output ports are combinational (the valid/ready FF was removed);
  * the FU datapath (ALU/comparator/mux) is registered — 1 cycle — into an
    output register + Fork Sender;
  * IMNs/OMNs have damping FIFOs and arbitrate for interleaved banks
    (one beat per bank per cycle, per-bank round-robin).

Each cycle: (A) bank grants fill IMN FIFOs / drain OMN FIFOs; (B) tokens
fall through EB chains to a combinational fixpoint; (C) FUs fire on the
settled state, registering results (visible next cycle).

The simulator executes the *mapped* netlist token-by-token, so measured
initiation intervals include real routing hops and bank conflicts — this is
what reproduces Table I's outputs/cycle (fft 1.95, dither II=4) rather than
assuming them.

Termination: kernels with static token counts finish when every OMN received
its expected stream. Data-dependent loops (Branch/Merge recirculation, back
edges with ``init=None``) have no static expectation — they finish by *token
exhaustion*: the IMN streams drain and the elastic network quiesces, the
condition on which the real hardware raises its end-of-kernel interrupt.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.executor import alu_eval, cmp_eval
from repro.core.fabric import FU_INS, FU_OUT, Res
from repro.core.isa import AluOp
from repro.core.mapper import FU_PORT_OF, Mapping, Signal
from repro.core.streams import BankArbiter, BusConfig, StreamSpec

EB_CAP = 2          # 2-slot elastic buffers
FIFO_CAP = 4        # IMN/OMN damping FIFOs
FUOUT_CAP = 2       # FU output register + delayed-valid slot


class _Station:
    __slots__ = ("sid", "kind", "cap", "q", "succs", "leg", "node", "port")

    def __init__(self, sid, kind, cap, leg="out", node=None, port=None):
        self.sid = sid
        self.kind = kind          # IMN | EB | FUOUT | OMN
        self.cap = cap
        self.q: deque = deque()
        self.succs: List[int] = []
        self.leg = leg            # which branch leg this chain belongs to
        self.node = node          # owning DFG node (FUOUT) / stream (IMN/OMN)
        self.port = port


def simulate_reference(m: Mapping, inputs: Dict[str, np.ndarray],
                       streams_in: Optional[Dict[str, StreamSpec]] = None,
                       streams_out: Optional[Dict[str, StreamSpec]] = None,
                       bus: Optional[BusConfig] = None,
                       max_cycles: int = 2_000_000) -> "SimResult":
    from repro.core.elastic_sim import SimResult
    g = m.dfg
    bus = bus or BusConfig()
    arb = BankArbiter(bus)
    arrays = {k: np.asarray(v, dtype=np.int64) for k, v in inputs.items()}
    (length,) = {v.shape[0] for v in arrays.values()}
    if streams_in is None:
        streams_in = {name: StreamSpec(base=i % bus.n_banks, size=length,
                                       stride=bus.n_banks)
                      for i, name in enumerate(g.inputs)}
    if streams_out is None:
        streams_out = {name: StreamSpec(base=(len(g.inputs) + i) % bus.n_banks,
                                        size=length, stride=bus.n_banks)
                       for i, name in enumerate(g.outputs)}

    # ------------------------------------------------------------------
    # build the station graph from the mapping's route trees
    # ------------------------------------------------------------------
    stations: List[_Station] = []

    def new_station(kind, cap, leg="out", node=None, port=None) -> int:
        st = _Station(len(stations), kind, cap, leg, node, port)
        stations.append(st)
        return st.sid

    imn_station: Dict[str, int] = {}
    omn_station: Dict[str, int] = {}
    fuout_station: Dict[str, int] = {}
    fu_in_station: Dict[Tuple[str, str], int] = {}   # (node, FU port) -> sid

    for name in g.inputs:
        imn_station[name] = new_station("IMN", FIFO_CAP, node=name)
    for name in g.outputs:
        omn_station[name] = new_station("OMN", FIFO_CAP, node=name)
    for n in m.place:
        fuout_station[n] = new_station("FUOUT", FUOUT_CAP, node=n)

    def registered(res: Res) -> bool:
        return res.port.startswith("IN_") or res.port in FU_INS or \
            res.port in ("IMN", "OMN")

    res_station: Dict[Tuple[Signal, Res], int] = {}
    for sig, route in m.routes.items():
        src_node, src_port = sig
        for res, par in route.parent.items():
            if par is None or not registered(res):
                continue
            if res.port == "OMN":
                continue    # OMN stations pre-made; wired below
            if res.port in FU_INS:
                # FU input EB: find owning node
                owner = None
                for nn, pos in m.place.items():
                    if pos == (res.r, res.c):
                        owner = nn
                        break
                sid = new_station("EB", EB_CAP, leg=src_port, node=owner,
                                  port=res.port)
                fu_in_station[(owner, res.port)] = sid
            else:
                sid = new_station("EB", EB_CAP, leg=src_port)
            res_station[(sig, res)] = sid

    def station_of(sig: Signal, res: Res) -> int:
        """Station for a tree resource: nearest registered self-or-ancestor."""
        route = m.routes[sig]
        cur: Optional[Res] = res
        while cur is not None:
            if cur.port == "IMN":
                return imn_station[sig[0]]
            if cur.port == "OMN":
                # find which OUTPUT node this OMN belongs to
                for oname, col in m.omn_of.items():
                    if col == cur.c:
                        return omn_station[oname]
            if (sig, cur) in res_station:
                return res_station[(sig, cur)]
            if cur.port == FU_OUT and route.parent[cur] is None:
                return fuout_station[sig[0]]
            cur = route.parent[cur]
        raise AssertionError("unrooted resource")

    # wire successor lists
    for sig, route in m.routes.items():
        for res, par in route.parent.items():
            if par is None:
                continue
            if registered(res):
                child = (omn_station[_omn_owner(m, res.c)]
                         if res.port == "OMN" else res_station.get((sig, res)))
                parent_sid = station_of(sig, par)
                if child is not None and child not in stations[parent_sid].succs:
                    if stations[parent_sid].kind == "FUOUT":
                        # the Branch leg filter applies at the FU output
                        # register: a child fed *directly* by it (e.g. an
                        # OMN in the producer's own column) must carry the
                        # signal's leg, not the station-creation default
                        stations[child].leg = sig[1]
                    stations[parent_sid].succs.append(child)

    # FU semantics tables
    fu_nodes = {n: g.nodes[n] for n in m.place}
    fu_inputs: Dict[str, Dict[str, Optional[int]]] = {}
    back_keys = {(e.dst, e.dst_port) for e in g.back_edges()}
    for n in fu_nodes:
        fu_inputs[n] = {p: fu_in_station.get((n, fp))
                        for p, fp in (("a", "FU_A"), ("b", "FU_B"),
                                      ("ctrl", "FU_C"))}

    # initial tokens for loop-carried signals (register init values, Sec.
    # III-C). The init lives at the *consumer's* FU input (data_reg_init +
    # valid_reg_init of that PE), so it must not fork to the producer's
    # other consumers — e.g. a scan carry that is also a kernel output.
    # Recirculation edges (init=None) start empty: the first token to
    # circulate is a real stream element admitted by the loop's gate.
    for e in g.back_edges():
        if e.init is None:
            continue
        sid = fu_in_station[(e.dst, FU_PORT_OF[e.dst_port])]
        stations[sid].q.append((np.int64(e.init), frozenset(("out",))))

    # reduction accumulators
    accs = {n: np.int64(nd.acc_init) for n, nd in fu_nodes.items()
            if nd.is_reduction()}
    acc_count = {n: 0 for n in accs}

    # IMN/OMN progress
    imn_sent = {name: 0 for name in g.inputs}
    omn_recv: Dict[str, List[Tuple[int, int]]] = {name: [] for name in g.outputs}
    # Token-exhaustion termination (data-dependent loops): a recirculating
    # graph's output token counts depend on runtime predicates (an exit leg
    # may fire once per element, a discarded leg never), so no static
    # expectation exists. Completion is instead declared when the input
    # streams are exhausted AND the elastic network quiesces — exactly when
    # real hardware raises its end-of-kernel interrupt (Sec. V-B).
    data_dependent = g.has_recirculation()
    expected: Dict[str, int] = {}
    for name in g.outputs:
        producer = g.operand(name, "a").src
        nd = g.nodes[producer]
        if data_dependent or g.nodes[name].emit_every == 0:
            # last-value OMN: token count equals producer emissions (+ any
            # init token that reaches it); completion is tracked by IMN drain.
            expected[name] = -1
        elif nd.is_reduction() and nd.emit_every:
            expected[name] = length // nd.emit_every
        else:
            expected[name] = length
    fu_firings = {n: 0 for n in fu_nodes}
    bank_beats = 0

    def succs_ready(st: _Station, legs: frozenset) -> bool:
        # Leg selection (the Branch valid demux) applies at the FU output
        # register; mid-route EB chains forward to all their children.
        for s in st.succs:
            child = stations[s]
            if st.kind == "FUOUT" and child.leg not in legs:
                continue
            if len(child.q) >= child.cap:
                return False
        return True

    def push_succs(st: _Station, value, legs: frozenset):
        for s in st.succs:
            child = stations[s]
            if st.kind == "FUOUT" and child.leg not in legs:
                continue
            child.q.append((value, frozenset(("out",))))

    # ------------------------------------------------------------------
    # main loop — two-phase: plan on cycle-start state, then commit
    # ------------------------------------------------------------------
    cycle = 0
    while cycle < max_cycles:
        cycle += 1
        progress = False

        # --- phase A: bank arbitration (IMN fetches + OMN stores) ---
        reqs: List[int] = []
        for name in g.inputs:
            st = stations[imn_station[name]]
            want = imn_sent[name] < length and len(st.q) < st.cap
            reqs.append(streams_in[name].bank(imn_sent[name], bus.n_banks)
                        if want else -1)
        for name in g.outputs:
            st = stations[omn_station[name]]
            want = len(st.q) > 0
            reqs.append(streams_out[name].bank(len(omn_recv[name]), bus.n_banks)
                        if want else -1)
        grants = arb.grant(reqs)
        for i, name in enumerate(g.inputs):
            if grants[i]:
                st = stations[imn_station[name]]
                st.q.append((arrays[name][imn_sent[name]], frozenset(("out",))))
                imn_sent[name] += 1
                bank_beats += 1
                progress = True
        for j, name in enumerate(g.outputs):
            if grants[len(g.inputs) + j]:
                st = stations[omn_station[name]]
                value, _ = st.q.popleft()
                omn_recv[name].append((int(value), cycle))
                bank_beats += 1
                progress = True

        # --- phase B: combinational settle (fall-through EB chains) ---
        settled = False
        while not settled:
            settled = True
            for st in stations:
                if st.kind in ("EB", "IMN", "FUOUT") and st.q:
                    if not st.succs:
                        if st.kind == "FUOUT":
                            # empty Fork-Sender mask: the FU result is
                            # deliberately discarded (find2min drops its
                            # loser this way, Sec. VI-B) — never backpressure
                            st.q.popleft()
                            settled = False
                            progress = True
                        continue
                    value, legs = st.q[0]
                    if succs_ready(st, legs):
                        st.q.popleft()
                        push_succs(st, value, legs)
                        settled = False
                        progress = True

        # --- phase C: FU firings on the settled state (registered) ---
        fires: List[str] = []
        for n, nd in fu_nodes.items():
            ins = fu_inputs[n]
            a_sid, b_sid, c_sid = ins["a"], ins["b"], ins["ctrl"]
            have = lambda sid: sid is not None and len(stations[sid].q) > 0
            out_st = stations[fuout_station[n]]
            if nd.kind == D.MERGE:
                if not (have(a_sid) or have(b_sid)):
                    continue      # priority-a confluence (Sec. III-C Merge)
            else:
                if a_sid is not None and not have(a_sid):
                    continue
                if b_sid is not None and not have(b_sid):
                    continue
                if c_sid is not None and not have(c_sid):
                    continue
            if nd.is_reduction():
                # non-emitting firings don't need downstream space
                will_emit = _emits(nd, acc_count[n] + 1, length)
                if will_emit and len(out_st.q) >= out_st.cap:
                    continue
            elif len(out_st.q) >= out_st.cap:
                continue
            fires.append(n)

        for n in fires:
            nd = fu_nodes[n]
            ins = fu_inputs[n]
            out_st = stations[fuout_station[n]]
            aq = stations[ins["a"]].q if ins["a"] is not None else None
            bq = stations[ins["b"]].q if ins["b"] is not None else None
            cq = stations[ins["ctrl"]].q if ins["ctrl"] is not None else None
            fu_firings[n] += 1
            progress = True
            if nd.kind == D.MERGE:
                src = aq if aq and len(aq) else bq
                value, _ = src.popleft()
                out_st.q.append((value, frozenset(("out",))))
                continue
            a = aq.popleft()[0] if aq is not None else None
            b = bq.popleft()[0] if bq is not None else None
            c = cq.popleft()[0] if cq is not None else None
            if nd.kind == D.ALU:
                if nd.is_reduction():
                    x = np.int64(nd.value) if nd.value is not None else a
                    accs[n] = np.int64(alu_eval(nd.op, accs[n], x))
                    acc_count[n] += 1
                    if _emits(nd, acc_count[n], length):
                        out_st.q.append((accs[n], frozenset(("out",))))
                        if nd.emit_every > 1:
                            accs[n] = np.int64(nd.acc_init)
                else:
                    bb = b if b is not None else np.int64(nd.value)
                    out_st.q.append((np.int64(alu_eval(nd.op, a, bb)),
                                     frozenset(("out",))))
            elif nd.kind == D.CMP:
                av = a
                if b is not None:
                    av = np.int64(alu_eval(AluOp.SUB, a, b))
                elif nd.value is not None:
                    av = np.int64(alu_eval(AluOp.SUB, a, np.int64(nd.value)))
                out_st.q.append((np.int64(cmp_eval(nd.op, av)),
                                 frozenset(("out",))))
            elif nd.kind == D.MUX:
                bb = b if b is not None else np.int64(nd.value)
                out_st.q.append((a if c != 0 else bb, frozenset(("out",))))
            elif nd.kind == D.BRANCH:
                leg = "t" if c != 0 else "f"
                out_st.q.append((a, frozenset((leg,))))

        if not progress:
            # quiescent: either done (only loop-carried leftovers remain in
            # their EBs, as in real hardware) or a true deadlock.
            cycle -= 1
            drained = all(imn_sent[i] >= length for i in g.inputs)
            met = all(expected[name] < 0 or len(omn_recv[name]) >= expected[name]
                      for name in g.outputs)
            if drained and met:
                break
            raise RuntimeError(
                f"deadlock in kernel {g.name} at cycle {cycle}: "
                f"imn_sent={imn_sent}, received="
                f"{ {k: len(v) for k, v in omn_recv.items()} }, "
                f"expected={expected}")
    else:
        raise RuntimeError(f"simulation did not converge in {max_cycles} cycles "
                           f"(kernel {g.name}; likely routing deadlock)")

    outputs = {name: np.array([v for v, _ in omn_recv[name]], dtype=np.int32)
               for name in g.outputs}
    arrivals = {name: [cyc for _, cyc in omn_recv[name]] for name in g.outputs}
    # last-value OMNs (stride 0): every token overwrote one word
    for name in g.outputs:
        if g.nodes[name].emit_every == 0 and len(outputs[name]):
            outputs[name] = outputs[name][-1:]
    return SimResult(cycle, outputs, arrivals, fu_firings, bank_beats)


def _emits(nd: D.Node, count: int, length: int) -> bool:
    if nd.emit_every == 1:
        return True
    if nd.emit_every == 0:
        return count == length
    return count % nd.emit_every == 0


def _omn_owner(m: Mapping, col: int) -> str:
    for oname, c in m.omn_of.items():
        if c == col:
            return oname
    raise KeyError(col)
