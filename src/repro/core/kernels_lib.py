"""Benchmark DFGs from the paper (Sec. VI-B, Figs. 5 and 7).

One-shot kernels:
  * ``fft``       — radix-2 butterfly (data-driven, 10 arith ops / 4 inputs)
  * ``relu``      — max(x, 0) via comparator + if/else mux (Fig. 5 right)
  * ``dither``    — 1-D threshold dither with error feedback (loop-carried)
  * ``find2min``  — two running minima + indices (irregular loop, 4 scalars out)

Irregular-loop kernels (data-dependent trip counts, gated Branch/Merge):
  * ``div_loop``  — hand-built divmod-by-repeated-subtraction (10 FUs)
  * ``TRACED_LOOPS`` — plain-Python ``lax.while_loop``/``lax.scan`` kernels
    (div_iter / isqrt / clip_scan / gemv_early) lowered by ``repro.frontend``

Multi-shot building blocks:
  * ``mac3``      — three dot-products at a time (Fig. 7c: 4 input vectors)
  * ``conv2d_row``— one 3-wide filter-row partial accumulation (3 shots total)
  * ``axpby``     — out = alpha*x + beta*y elementwise (gemm/gemver epilogues)
  * ``scale_add`` — out = alpha*x + y
  * ``mac1``      — single dot-product (gemver/gesummv matvec rows)
  * ``outer_row`` — a_row + u1_i*v1 + u2_i*v2 (gemver phase-1 row update)

All integer 32-bit, matching the embedded-domain datapath of Sec. III-C.
"""
from __future__ import annotations

from repro.core.dfg import DFG
from repro.core.isa import AluOp, CmpOp

Q = 15  # fixed-point fraction bits used by the fft twiddles


def fft_butterfly(wr: int = 23170, wi: int = -23170) -> DFG:
    """Radix-2 DIT butterfly: (a, b) -> (a + w*b, a - w*b), complex.

    4 inputs (ar, ai, br, bi), 4 outputs, 10 arithmetic ops — the paper's
    op-count example ('ten arithmetic operations ... every four inputs').
    Twiddle (wr, wi) is Q15 fixed-point, folded as PE constants.
    """
    b = DFG.build("fft")
    ar, ai = b.inp("ar"), b.inp("ai")
    br, bi = b.inp("br"), b.inp("bi")
    t1 = b.alu("t1", AluOp.MUL, br, const_b=wr)
    t2 = b.alu("t2", AluOp.MUL, bi, const_b=wi)
    t3 = b.alu("t3", AluOp.MUL, br, const_b=wi)
    t4 = b.alu("t4", AluOp.MUL, bi, const_b=wr)
    tr = b.alu("tr", AluOp.SUB, t1, t2)
    ti = b.alu("ti", AluOp.ADD, t3, t4)
    or0 = b.alu("or0", AluOp.ADD, ar, tr)
    oi0 = b.alu("oi0", AluOp.ADD, ai, ti)
    or1 = b.alu("or1", AluOp.SUB, ar, tr)
    oi1 = b.alu("oi1", AluOp.SUB, ai, ti)
    b.out("out_or0", or0)
    b.out("out_oi0", oi0)
    b.out("out_or1", or1)
    b.out("out_oi1", oi1)
    return b.done()


def relu() -> DFG:
    """ReLU (Fig. 5 right): c = x > 0; out = c ? x : 0."""
    b = DFG.build("relu")
    x = b.inp("x")
    c = b.cmp("c", CmpOp.GTZ, x)
    o = b.mux("o", x, None, c)               # b-operand is the PE const 0
    b.nodes["o"].value = 0
    b.out("out", o)
    return b.done()


def dither(threshold: int = 127, white: int = 255) -> DFG:
    """1-D threshold dither with full error diffusion (one-shot, control).

    v = x + err ; c = (v - T) > 0 ; out = c * WHITE ; err' = v - out.
    The err' -> v edge is a loop-carried (non-immediate) feedback loop —
    exactly the irregular-loop pattern Sec. III-C adds Branch/Merge logic
    for. The 4-FU feedback loop gives the paper's II = 4 (Sec. VII-B).
    """
    b = DFG.build("dither")
    x = b.inp("x")
    v = b.alu("v", AluOp.ADD, x, None)               # b comes from back edge
    c = b.cmp("c", CmpOp.GTZ, v, const_b=threshold)  # (v - T) > 0
    o = b.alu("o", AluOp.MUL, c, const_b=white)
    e = b.alu("e", AluOp.SUB, v, o)
    b.back_edge(e, v, "b", init=0)
    b.out("out", o)
    return b.done()


INT_MAX = (1 << 31) - 1


def find2min() -> DFG:
    """Two smallest values and their indices (irregular loop, Sec. VI-B).

    Loop-carried state: m1, m2 (running minima), i1, i2 (indices), idx
    (position counter, an immediate-feedback accumulator). Four scalar
    outputs drained once at the end of the stream (OMN stride-0 'last value'
    mode). 9 enabled FUs per element — Table I's 9216 ops / 1024 elements.
    """
    b = DFG.build("find2min")
    x = b.inp("x")
    # position counter: idx = idx_prev + 1, starting at 0.  Accumulators with
    # a const operand step by the const, paced by (but ignoring) operand a —
    # the hardware's data-register-init + immediate-feedback counter idiom.
    idx = b.alu("idx", AluOp.ADD, x, const_b=1, acc_init=-1, emit_every=1)
    c1 = b.cmp("c1", CmpOp.GTZ, None, x)             # m1_prev - x > 0
    m1 = b.mux("m1", x, None, c1)                    # new m1
    cand = b.mux("cand", None, x, c1)                # displaced candidate
    c2 = b.cmp("c2", CmpOp.GTZ, None, cand)          # m2_prev - cand > 0
    m2 = b.mux("m2", cand, None, c2)                 # new m2
    i1 = b.mux("i1", idx, None, c1)
    iold = b.mux("iold", None, idx, c1)              # index of cand
    i2 = b.mux("i2", iold, None, c2)
    b.back_edge(m1, c1, "a", init=INT_MAX)
    b.back_edge(m1, m1, "b", init=INT_MAX)
    b.back_edge(m1, cand, "a", init=INT_MAX)
    b.back_edge(m2, c2, "a", init=INT_MAX)
    b.back_edge(m2, m2, "b", init=INT_MAX)
    b.back_edge(i1, i1, "b", init=-1)
    b.back_edge(i1, iold, "a", init=-1)
    b.back_edge(i2, i2, "b", init=-1)
    b.out("out_m1", m1)
    b.out("out_i1", i1)
    b.out("out_m2", m2)
    b.out("out_i2", i2)
    for o in ("out_m1", "out_i1", "out_m2", "out_i2"):
        b.nodes[o].emit_every = 0                    # OMN stores last value
    return b.done()


def find2min_brmg() -> DFG:
    """Paper-faithful find2min via Branch/Merge recirculation (Fig. 5 BR/MG).

    Two cascaded dataflow-min stages, 9 enabled FUs — matching Table I's
    9 ops/element exactly: per stage, the running min recirculates through
    a Merge; a Branch pair steers the loser to the next stage.

      c1 = m1 - x > 0 ; br_x(x, c1): t -> new m1, f -> cand
      br_m(m1, c1):     t -> cand   , f -> m1 kept
      m1' = Merge(br_x.t, br_m.f) ; cand = Merge(br_m.t, br_x.f)
      (same again for m2 over cand; cand2 is discarded — empty fork mask)
    """
    b = DFG.build("find2min_brmg")
    x = b.inp("x")
    c1 = b.cmp("c1", CmpOp.GTZ, None, x)              # m1_prev - x > 0
    brx = b.branch("brx", x, c1)
    brm = b.branch("brm", None, c1)
    m1 = b.merge("m1", brx, brm, a_port="t", b_port="f")
    cand = b.merge("cand", brm, brx, a_port="t", b_port="f")
    c2 = b.cmp("c2", CmpOp.GTZ, None, cand)           # m2_prev - cand > 0
    brc = b.branch("brc", cand, c2)
    brm2 = b.branch("brm2", None, c2)
    m2 = b.merge("m2", brc, brm2, a_port="t", b_port="f")
    # brc.f / brm2.t (the overall loser) are discarded: empty fork mask.
    b.back_edge(m1, c1, "a", init=INT_MAX)
    b.back_edge(m1, brm, "a", init=INT_MAX)
    b.back_edge(m2, c2, "a", init=INT_MAX)
    b.back_edge(m2, brm2, "a", init=INT_MAX)
    b.out("out_m1", m1)
    b.out("out_m2", m2)
    for o in ("out_m1", "out_m2"):
        b.nodes[o].emit_every = 0                     # OMN stores last value
    return b.done()


def div_loop(divisor: int = 7) -> DFG:
    """Iterative division by repeated subtraction — the paper's "irregular
    loop" pattern on the gated Branch/Merge schema (Fig. 4 elastic feedback).

    Per element x (x >= 0): circulate (q, r) with r -= divisor, q += 1 while
    r >= divisor; the exit legs release (q, r) = divmod(x, divisor). The
    *gate* joins each fresh element with a demand token minted by the
    previous element's exit (initial demand token present), so exactly one
    element is in flight and OMN order is preserved. Recirculation back
    edges carry no initial token (``init=None``); the simulator terminates
    by token exhaustion since trip counts are data-dependent.
    """
    b = DFG.build("div_loop")
    x = b.inp("x")
    gate = b.alu("gate", AluOp.ADD, x, None)          # b <- demand back edge
    q0 = b.alu("q0", AluOp.MUL, gate, const_b=0)      # paced constant q=0
    mr = b.merge("mr", None, gate)                    # a <- recirculated r
    mq = b.merge("mq", None, q0)                      # a <- recirculated q
    c = b.cmp("c", CmpOp.GTZ, mr, const_b=divisor - 1)   # r >= divisor
    brr = b.branch("brr", mr, c)
    brq = b.branch("brq", mq, c)
    rn = b.alu("rn", AluOp.SUB, brr, const_b=divisor, a_port="t")
    qn = b.alu("qn", AluOp.ADD, brq, const_b=1, a_port="t")
    b.back_edge(rn, mr, "a", init=None)
    b.back_edge(qn, mq, "a", init=None)
    dem = b.alu("dem", AluOp.MUL, brq, const_b=0, a_port="f")
    b.back_edge(dem, gate, "b", init=0)
    b.out("out_q", brq, src_port="f")
    b.out("out_r", brr, src_port="f")
    return b.done()


# ---------------------------------------------------------------------------
# traced irregular-loop kernels (plain Python/JAX, lowered by the frontend)
# ---------------------------------------------------------------------------

def loop_div_fn(divisor: int = 7):
    """q, r = divmod(x, divisor) for x >= 0 via ``lax.while_loop`` repeated
    subtraction — a data-dependent trip count per element."""
    from jax import lax

    def div_iter(x):
        def cond(c):
            q, r = c
            return r > divisor - 1

        def body(c):
            q, r = c
            return q + 1, r - divisor

        return lax.while_loop(cond, body, (0, x))
    return div_iter


def loop_isqrt_fn():
    """Integer square root: smallest s with (s+1)^2 > x (x >= 0) — the
    stream element rides the loop as a cond-closure invariant."""
    from jax import lax

    def isqrt(x):
        def cond(s):
            return (s + 1) * (s + 1) <= x
        return lax.while_loop(cond, lambda s: s + 1, 0)
    return isqrt


def clip_scan_fn(lo: int = -128, hi: int = 127):
    """Data-dependent clipping integrator: acc' = clip(acc + x, lo, hi) —
    a ``lax.scan`` recurrence (loop-carried back edge, like dither)."""
    import jax.numpy as jnp
    from jax import lax

    def clip_scan(x):
        def f(acc, xi):
            a2 = jnp.clip(acc + xi, lo, hi)
            return a2, a2
        _, ys = lax.scan(f, 0, x)
        return ys
    return clip_scan


def gemv_early_fn(threshold: int = 1 << 20):
    """Dot-product row with an early-exit threshold: accumulation freezes
    once the partial sum exceeds ``threshold`` (branchy GEMV row); the final
    carry drains through a last-value OMN."""
    import jax.numpy as jnp
    from jax import lax

    def gemv_early(a, b):
        def f(c, ab):
            acc, done = c
            ai, bi = ab
            acc2 = jnp.where(done != 0, acc, acc + ai * bi)
            done2 = done | (acc2 > threshold).astype(jnp.int32)
            return (acc2, done2), None
        (acc, _), _ = lax.scan(f, (0, 0), (a, b))
        return acc
    return gemv_early


# name -> (python-function factory, number of input streams)
TRACED_LOOPS = {
    "div_iter": (loop_div_fn, 1),
    "isqrt": (loop_isqrt_fn, 1),
    "clip_scan": (clip_scan_fn, 1),
    "gemv_early": (gemv_early_fn, 2),
}


def mac1(vec_len: int) -> DFG:
    """Single dot-product lane: acc += a*b, emit after ``vec_len`` tokens."""
    b = DFG.build("mac1")
    a, x = b.inp("a"), b.inp("b0")
    m = b.alu("m", AluOp.MUL, a, x)
    s = b.alu("s", AluOp.ADD, m, acc_init=0, emit_every=vec_len)
    b.out("out0", s)
    return b.done()


def mac3(vec_len: int) -> DFG:
    """Fig. 7c: three simultaneous dot-products sharing the ``a`` stream.

    4 input vectors (a row + 3 B columns), 3 scalar outputs per shot.
    """
    b = DFG.build("mac3")
    a = b.inp("a")
    outs = []
    for k in range(3):
        xk = b.inp(f"b{k}")
        m = b.alu(f"m{k}", AluOp.MUL, a, xk)
        s = b.alu(f"s{k}", AluOp.ADD, m, acc_init=0, emit_every=vec_len)
        outs.append(s)
    for k, s in enumerate(outs):
        b.out(f"out{k}", s)
    return b.done()


def mac2x(vec_len: int) -> DFG:
    """gesummv row kernel: two dot-products sharing the x stream:
    d1 = sum(a*x), d2 = sum(b*x)."""
    b = DFG.build("mac2x")
    a, bb, x = b.inp("a"), b.inp("b"), b.inp("x")
    m1 = b.alu("m1", AluOp.MUL, a, x)
    s1 = b.alu("s1", AluOp.ADD, m1, acc_init=0, emit_every=vec_len)
    m2 = b.alu("m2", AluOp.MUL, bb, x)
    s2 = b.alu("s2", AluOp.ADD, m2, acc_init=0, emit_every=vec_len)
    b.out("out0", s1)
    b.out("out1", s2)
    return b.done()


def scale(alpha: int) -> DFG:
    """out = alpha * x (gemver w-epilogue)."""
    b = DFG.build("scale")
    x = b.inp("x")
    o = b.alu("o", AluOp.MUL, x, const_b=alpha)
    b.out("out", o)
    return b.done()


def conv2d_row3(k0: int, k1: int, k2: int) -> DFG:
    """First conv2d shot: no partial-sum input (initializes the plane)."""
    b = DFG.build("conv2d_row3")
    x0, x1, x2 = b.inp("x0"), b.inp("x1"), b.inp("x2")
    t0 = b.alu("t0", AluOp.MUL, x0, const_b=k0)
    t1 = b.alu("t1", AluOp.MUL, x1, const_b=k1)
    t2 = b.alu("t2", AluOp.MUL, x2, const_b=k2)
    s0 = b.alu("s0", AluOp.ADD, t0, t1)
    s1 = b.alu("s1", AluOp.ADD, s0, t2)
    b.out("pout", s1)
    return b.done()


def conv2d_row(k0: int, k1: int, k2: int) -> DFG:
    """One filter-row partial sum of a 3x3 convolution (3 shots total).

    pout = pin + k0*x0 + k1*x1 + k2*x2, with x0/x1/x2 the same image row at
    column offsets 0/1/2 (three IMN streams over the same data) and pin the
    partial-sum plane of the previous shot (memory-resident between shots).
    """
    b = DFG.build("conv2d_row")
    x0, x1, x2 = b.inp("x0"), b.inp("x1"), b.inp("x2")
    pin = b.inp("pin")
    t0 = b.alu("t0", AluOp.MUL, x0, const_b=k0)
    t1 = b.alu("t1", AluOp.MUL, x1, const_b=k1)
    t2 = b.alu("t2", AluOp.MUL, x2, const_b=k2)
    s0 = b.alu("s0", AluOp.ADD, t0, t1)
    s1 = b.alu("s1", AluOp.ADD, s0, t2)
    po = b.alu("po", AluOp.ADD, pin, s1)
    b.out("pout", po)
    return b.done()


def axpby(alpha: int, beta: int) -> DFG:
    """out = alpha*x + beta*y (gemm epilogue: alpha*(AB) + beta*C)."""
    b = DFG.build("axpby")
    x, y = b.inp("x"), b.inp("y")
    ax = b.alu("ax", AluOp.MUL, x, const_b=alpha)
    by = b.alu("by", AluOp.MUL, y, const_b=beta)
    o = b.alu("o", AluOp.ADD, ax, by)
    b.out("out", o)
    return b.done()


def scale_add(alpha: int) -> DFG:
    """out = alpha*x + y."""
    b = DFG.build("scale_add")
    x, y = b.inp("x"), b.inp("y")
    ax = b.alu("ax", AluOp.MUL, x, const_b=alpha)
    o = b.alu("o", AluOp.ADD, ax, y)
    b.out("out", o)
    return b.done()


def vadd() -> DFG:
    """out = x + y (gemver x += z phase)."""
    b = DFG.build("vadd")
    x, y = b.inp("x"), b.inp("y")
    o = b.alu("o", AluOp.ADD, x, y)
    b.out("out", o)
    return b.done()


def outer_row2(u1_0: int, u2_0: int, u1_1: int, u2_1: int) -> DFG:
    """gemver phase 1, two rows fused (fabric-level unrolling, Sec. IV):
    a_k' = a_k + u1_k*v1 + u2_k*v2 for k in {0,1}, sharing the v1/v2 streams.
    """
    b = DFG.build("outer_row2")
    a0, a1 = b.inp("a0"), b.inp("a1")
    v1, v2 = b.inp("v1"), b.inp("v2")
    for k, (a, w1, w2) in enumerate([(a0, u1_0, u2_0), (a1, u1_1, u2_1)]):
        t1 = b.alu(f"t1_{k}", AluOp.MUL, v1, const_b=w1)
        t2 = b.alu(f"t2_{k}", AluOp.MUL, v2, const_b=w2)
        s = b.alu(f"s_{k}", AluOp.ADD, t1, t2)
        o = b.alu(f"o_{k}", AluOp.ADD, a, s)
        b.out(f"out{k}", o)
    return b.done()


def outer_row(u1_i: int, u2_i: int) -> DFG:
    """gemver phase 1, one row: a' = a + u1_i*v1 + u2_i*v2 (u*_i folded as
    consts for the shot — the CPU re-arms consts per row, Sec. IV strategy 3).
    """
    b = DFG.build("outer_row")
    a = b.inp("a")
    v1, v2 = b.inp("v1"), b.inp("v2")
    t1 = b.alu("t1", AluOp.MUL, v1, const_b=u1_i)
    t2 = b.alu("t2", AluOp.MUL, v2, const_b=u2_i)
    s = b.alu("s", AluOp.ADD, t1, t2)
    o = b.alu("o", AluOp.ADD, a, s)
    b.out("out", o)
    return b.done()


ONE_SHOT = {
    "fft": fft_butterfly,
    "relu": relu,
    "dither": dither,
    "find2min": find2min,
}

# hand-built data-dependent loop kernels (gated Branch/Merge recirculation)
LOOP_KERNELS = {
    "div_loop": div_loop,
}
