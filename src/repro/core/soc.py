"""SoC model: CV32E40P CPU cycle model + offload orchestration overheads.

The CPU baseline executes the same kernels in software (-O3). We model the
RV32IMC in-order 4-stage core with an instruction-level cost model using
*fixed architectural weights* (loads/stores 2 cycles with the load-use
hazard, ALU 1, MUL 2, taken branch 3, index arithmetic 2/element) applied
to per-benchmark -O3 instruction profiles (codegen-informed: mm64 spills B
accesses, mm16's inner loop unrolls). Unconstrained least-squares fits of
the weights against the paper's 12 published CPU cycle counts produce
non-physical (negative) costs, so we keep the weights architectural and
report per-benchmark residuals (typically within ±20%) as the calibration
artifact; benchmarks always show the paper's own CPU cycles side-by-side.

Offload orchestration (Sec. V-B 'Computation Model'):
  * kernel configuration fetch: ``isa.config_cycles`` (5 words/PE + setup);
  * per-shot re-arm: the CPU writes base/size/stride for every stream plus
    the start command over the memory-mapped interface, then synchronizes on
    the completion interrupt — ``RELOAD_OVERHEAD`` cycles (fitted to the
    mm16/mm64 totals of Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.paper_data import TABLE_I, TABLE_II

# ---------------------------------------------------------------------------
# CPU cycle model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelProfile:
    """Per-element instruction counts for the software version of a kernel
    (plus the element count), derived from the -O3 inner loop."""

    elements: int
    loads: float
    stores: float
    alu: float          # add/sub/logic/shift/compare
    mul: float
    branches: float     # taken branches (loop back-edges + data branches)


# architectural weights: (load, store, alu, mul, taken-branch, index/element)
CPU_WEIGHTS = np.array([2.0, 2.0, 1.0, 2.0, 3.0, 2.0])


# software inner-loop profiles (per element; codegen-informed, see docstring)
def profiles() -> Dict[str, KernelProfile]:
    p: Dict[str, KernelProfile] = {}
    # fft: per element-set of 4 values: 4 ld, 4 st, 10 arith (4 mul), loop
    p["fft"] = KernelProfile(256, 4, 4, 6, 4, 1)
    # relu: ld, cmp, conditional store path
    p["relu"] = KernelProfile(1024, 1, 1, 2, 0, 1)
    # dither: ld, add, cmp, sel, sub, st
    p["dither"] = KernelProfile(1024, 1, 1, 4, 0, 1.2)
    # find2min: ld, 2 cmp, conditional updates (branchy)
    p["find2min"] = KernelProfile(1024, 1, 0, 5, 0, 1.6)
    # mm 16x16: inner loop unrolls at -O3 (few loop branches)
    p["mm16"] = KernelProfile(16 ** 3, 2, 0, 2, 1, 0.25)
    # mm 64x64: register pressure spills the B access (extra load)
    p["mm64"] = KernelProfile(64 ** 3, 3, 0, 2, 1, 1)
    # conv2d 62x62 valid x 3x3 taps, taps unrolled
    p["conv2d"] = KernelProfile(62 * 62 * 9, 1, 0.12, 1, 1, 0.2)
    # polybench (SMALL): dominated by matmul/matvec inner loops
    p["gemm"] = KernelProfile(60 * 70 * 80, 2, 0.02, 2, 1, 0.3)
    # gemver/gesummv: fused loops share operand loads across phases
    p["gemver"] = KernelProfile(4 * 120 * 120, 1.6, 0.25, 1.8, 1, 0)
    p["gesummv"] = KernelProfile(2 * 90 * 90, 1.25, 0.03, 0.5, 1, 0.6)
    p["2mm"] = KernelProfile(40 * 50 * 70 + 40 * 80 * 50, 2, 0.03, 2, 1, 0.6)
    p["3mm"] = KernelProfile(40 * 50 * 60 + 50 * 70 * 80 + 40 * 70 * 50,
                             2, 0.03, 2, 1, 0.3)
    return p


_PAPER_CPU_CYCLES = {**{k: v[7] for k, v in TABLE_I.items()},
                     **{k: v[6] for k, v in TABLE_II.items()}}


def cpu_cycles(profile: KernelProfile) -> float:
    """Predicted CV32E40P cycles for a kernel's software version."""
    x = np.array([profile.loads, profile.stores, profile.alu, profile.mul,
                  profile.branches, 1.0])
    return float(profile.elements * (x @ CPU_WEIGHTS))


def cpu_model_report() -> List[dict]:
    """Fit-quality table: per benchmark, modeled vs published CPU cycles."""
    out = []
    for k, prof in profiles().items():
        pred = cpu_cycles(prof)
        ref = _PAPER_CPU_CYCLES[k]
        out.append({"kernel": k, "paper_cpu_cycles": ref,
                    "model_cpu_cycles": round(pred),
                    "rel_err": (pred - ref) / ref})
    return out


# ---------------------------------------------------------------------------
# Offload orchestration costs
# ---------------------------------------------------------------------------

# Per-shot re-arm: MMIO writes for stream parameters + start + interrupt
# synchronization. Fitted to Table II's mm16/mm64 totals (see DESIGN.md).
RELOAD_OVERHEAD = 95

# One-shot preamble (stream setup + start + final sync) — excluded from the
# paper's one-shot performance metrics (Sec. VII-B) but modeled for energy.
ONESHOT_PREAMBLE = 60


def offload_cycles(config_cycles: int, shot_exec_cycles: List[int],
                   reconfigs: int = 1) -> int:
    """Total offloaded execution time of a multi-shot kernel (Sec. V-B):
    config fetch (per reconfiguration) + per-shot re-arm + execution."""
    return (config_cycles * reconfigs
            + sum(shot_exec_cycles)
            + RELOAD_OVERHEAD * len(shot_exec_cycles))
