"""Functional (un-timed) execution of STRELA DFGs — the semantic oracle.

Three paths:
  * **vectorized** — acyclic graphs (optionally with reductions that feed only
    OUTPUT nodes): NumPy evaluation over the whole stream at once.
  * **loop** — graphs with loop-carried back edges (dither, find2min) or
    reductions consumed by interior nodes: per-token interpretation, exactly
    mirroring the elastic token semantics. Every node fires exactly once per
    stream element.
  * **token** — graphs with *recirculation* (a back edge with ``init=None``,
    the data-dependent-loop schema the frontend emits for ``lax.while_loop``):
    a token-driven interpreter with per-edge FIFOs. Nodes fire whenever their
    joined inputs hold tokens, so an element can circulate through a
    Branch/Merge loop a data-dependent number of times before its exit token
    is released. Execution terminates by *token exhaustion*: the network is
    run to quiescence after the input streams drain.

All use a wrapping 32-bit integer datapath (the fabric's ALU width).
The cycle-accurate timing lives in ``elastic_sim``; this module defines *what*
a mapped kernel computes, and is the reference for the Pallas kernels and the
fidelity checks of the elastic simulator itself.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.isa import AluOp, CmpOp

I32 = np.int32


def wrap32(x) -> np.ndarray:
    """Wrap to the fabric's 32-bit two's-complement datapath."""
    return np.asarray(x, dtype=np.int64).astype(np.uint64).astype(np.uint32).astype(I32)


def alu_eval(op: AluOp, a, b):
    a64 = np.asarray(a, dtype=np.int64)
    b64 = np.asarray(b, dtype=np.int64)
    if op == AluOp.ADD:
        r = a64 + b64
    elif op == AluOp.SUB:
        r = a64 - b64
    elif op == AluOp.MUL:
        r = a64 * b64
    elif op == AluOp.SHL:
        r = a64 << (b64 & 31)
    elif op == AluOp.SHR:
        r = a64 >> (b64 & 31)
    elif op == AluOp.AND:
        r = a64 & b64
    elif op == AluOp.OR:
        r = a64 | b64
    elif op == AluOp.XOR:
        r = a64 ^ b64
    elif op == AluOp.NOP:
        r = a64
    else:  # pragma: no cover
        raise ValueError(f"bad ALU op {op}")
    return wrap32(r)


def cmp_eval(op: CmpOp, a):
    a = np.asarray(a)
    if op == CmpOp.EQZ:
        return (a == 0).astype(I32)
    if op == CmpOp.GTZ:
        return (a > 0).astype(I32)
    raise ValueError(f"bad CMP op {op}")


def _needs_loop(g: D.DFG) -> bool:
    if g.back_edges():
        return True
    for n in g.nodes.values():
        if n.is_reduction():
            for e in g.out_edges(n.name):
                if g.nodes[e.dst].kind != D.OUTPUT:
                    return True
    return False


def execute(g: D.DFG, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Run a DFG over input streams; returns OMN output streams (compacted)."""
    if set(inputs) != set(g.inputs):
        raise ValueError(f"inputs {sorted(inputs)} != DFG inputs {sorted(g.inputs)}")
    arrays = {k: np.asarray(v, dtype=I32) for k, v in inputs.items()}
    lengths = {v.shape[0] for v in arrays.values()}
    if len(lengths) != 1:
        raise ValueError(f"all input streams must share a length, got {lengths}")
    (length,) = lengths
    if g.has_recirculation():
        return _execute_tokens(g, arrays, length)
    if _needs_loop(g):
        return _execute_loop(g, arrays, length)
    return _execute_vectorized(g, arrays, length)


# ---------------------------------------------------------------------------
# vectorized path
# ---------------------------------------------------------------------------

def _operand(g: D.DFG, vals, masks, node: D.Node, port: str):
    e = g.operand(node.name, port)
    if e is None:
        return None, None
    key = (e.src, e.src_port)
    return vals[key], masks[key]


def _execute_vectorized(g, arrays, length):
    vals: Dict[Tuple[str, str], np.ndarray] = {}
    masks: Dict[Tuple[str, str], np.ndarray] = {}
    outputs: Dict[str, np.ndarray] = {}
    full = np.ones(length, dtype=bool)
    for name in g.topo_order():
        n = g.nodes[name]
        if n.kind == D.INPUT:
            vals[(name, "out")], masks[(name, "out")] = arrays[name], full
        elif n.kind == D.CONST:
            vals[(name, "out")] = np.full(length, n.value, dtype=I32)
            masks[(name, "out")] = full
        elif n.kind == D.ALU:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if n.is_reduction():
                vals[(name, "out")], masks[(name, "out")] = _reduce_vec(n, a, ma, length)
                continue
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            m = ma & mb
            vals[(name, "out")] = alu_eval(n.op, a, b)
            masks[(name, "out")] = m
        elif n.kind == D.CMP:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if b is not None:
                a, ma = alu_eval(AluOp.SUB, a, b), ma & mb
            elif n.value is not None:
                a = alu_eval(AluOp.SUB, a, np.full(length, n.value, dtype=I32))
            vals[(name, "out")] = cmp_eval(n.op, a)
            masks[(name, "out")] = ma
        elif n.kind == D.MUX:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            c, mc = _operand(g, vals, masks, n, "ctrl")
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            vals[(name, "out")] = np.where(c != 0, a, b).astype(I32)
            masks[(name, "out")] = ma & mb & mc
        elif n.kind == D.BRANCH:
            a, ma = _operand(g, vals, masks, n, "a")
            c, mc = _operand(g, vals, masks, n, "ctrl")
            m = ma & mc
            vals[(name, "t")] = a
            masks[(name, "t")] = m & (c != 0)
            vals[(name, "f")] = a
            masks[(name, "f")] = m & (c == 0)
        elif n.kind == D.MERGE:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if np.any(ma & mb):
                raise ValueError(f"MERGE {name}: non-complementary token masks")
            vals[(name, "out")] = np.where(ma, a, b).astype(I32)
            masks[(name, "out")] = ma | mb
        elif n.kind == D.OUTPUT:
            a, ma = _operand(g, vals, masks, n, "a")
            out = a[ma]
            if n.emit_every == 0 and out.size:   # OMN 'last value' mode
                out = out[-1:]
            outputs[name] = out.astype(I32)
    return outputs


def _reduce_vec(n: D.Node, a: np.ndarray, ma: np.ndarray, length: int):
    """Segmented accumulate: acc = op(acc, x); emit & reset every k tokens
    (k=0: emit once at end). Vectorized-path reductions feed only OUTPUTs,
    so we return the emission stream directly."""
    if not np.all(ma):
        raise ValueError("reductions under branch masks need the loop path")
    if n.value is not None:  # paced counter: acc' = op(acc, const)
        x = np.full(length, n.value, dtype=I32)
    else:
        x = a
    k = n.emit_every if n.emit_every else length
    if length % k != 0:
        raise ValueError(f"stream length {length} not divisible by segment {k}")
    seg = np.asarray(x, dtype=np.int64).reshape(length // k, k)
    init = np.int64(n.acc_init)
    if n.op == AluOp.ADD:
        res = init + seg.sum(axis=1)
    elif n.op == AluOp.SUB:
        res = init - seg.sum(axis=1)
    elif n.op == AluOp.MUL:
        res = init * np.prod(seg, axis=1)
    elif n.op in (AluOp.AND, AluOp.OR, AluOp.XOR):
        ufunc = {AluOp.AND: np.bitwise_and, AluOp.OR: np.bitwise_or,
                 AluOp.XOR: np.bitwise_xor}[n.op]
        res = ufunc(init, ufunc.reduce(seg, axis=1))
    else:
        raise ValueError(f"unsupported reduction op {n.op}")
    emit = wrap32(res)
    mask = np.ones(emit.shape[0], dtype=bool)
    return emit, mask


# ---------------------------------------------------------------------------
# loop path (token-by-token)
# ---------------------------------------------------------------------------

def _execute_loop(g, arrays, length):
    order = g.topo_order()
    back = {(e.dst, e.dst_port): e for e in g.back_edges()}
    carry = {key: np.int64(e.init) for key, e in back.items()}
    accs = {n.name: np.int64(n.acc_init) for n in g.nodes.values() if n.is_reduction()}
    out_streams: Dict[str, List[int]] = {o: [] for o in g.outputs}
    last_vals: Dict[str, Optional[int]] = {o: None for o in g.outputs}

    def read(node: D.Node, port: str, vals, valid):
        key = (node.name, port)
        if key in back:
            return carry[key], True
        e = g.operand(node.name, port)
        if e is None:
            return None, None
        return vals.get((e.src, e.src_port)), valid.get((e.src, e.src_port), False)

    for t in range(length):
        vals: Dict[Tuple[str, str], np.int64] = {}
        valid: Dict[Tuple[str, str], bool] = {}
        for name in order:
            n = g.nodes[name]
            if n.kind == D.INPUT:
                vals[(name, "out")], valid[(name, "out")] = np.int64(arrays[name][t]), True
            elif n.kind == D.CONST:
                vals[(name, "out")], valid[(name, "out")] = np.int64(n.value), True
            elif n.kind == D.ALU:
                a, va = read(n, "a", vals, valid)
                b, vb = read(n, "b", vals, valid)
                if n.is_reduction():
                    if not va:
                        valid[(name, "out")] = False
                        continue
                    x = np.int64(n.value) if n.value is not None else a
                    accs[name] = np.int64(alu_eval(n.op, accs[name], x))
                    k = n.emit_every
                    emit = (k == 1) or (k > 1 and (t + 1) % k == 0) or \
                           (k == 0 and t == length - 1)
                    vals[(name, "out")] = accs[name]
                    valid[(name, "out")] = bool(emit)
                    if k > 1 and (t + 1) % k == 0:
                        accs[name] = np.int64(n.acc_init)
                    continue
                if b is None:
                    b, vb = np.int64(n.value), True
                ok = bool(va and vb)
                vals[(name, "out")] = np.int64(alu_eval(n.op, a, b)) if ok else np.int64(0)
                valid[(name, "out")] = ok
            elif n.kind == D.CMP:
                a, va = read(n, "a", vals, valid)
                b, vb = read(n, "b", vals, valid)
                if b is not None:
                    a, va = np.int64(alu_eval(AluOp.SUB, a, b)), bool(va and vb)
                elif n.value is not None and va:
                    a = np.int64(alu_eval(AluOp.SUB, a, np.int64(n.value)))
                vals[(name, "out")] = np.int64(cmp_eval(n.op, a)) if va else np.int64(0)
                valid[(name, "out")] = bool(va)
            elif n.kind == D.MUX:
                a, va = read(n, "a", vals, valid)
                b, vb = read(n, "b", vals, valid)
                c, vc = read(n, "ctrl", vals, valid)
                if b is None:
                    b, vb = np.int64(n.value), True
                ok = bool(va and vb and vc)
                vals[(name, "out")] = (a if c != 0 else b) if ok else np.int64(0)
                valid[(name, "out")] = ok
            elif n.kind == D.BRANCH:
                a, va = read(n, "a", vals, valid)
                c, vc = read(n, "ctrl", vals, valid)
                ok = bool(va and vc)
                vals[(name, "t")] = a if ok else np.int64(0)
                valid[(name, "t")] = ok and c != 0
                vals[(name, "f")] = a if ok else np.int64(0)
                valid[(name, "f")] = ok and c == 0
            elif n.kind == D.MERGE:
                a, va = read(n, "a", vals, valid)
                b, vb = read(n, "b", vals, valid)
                if va and vb:
                    raise ValueError(f"MERGE {name}: both inputs valid at t={t}")
                vals[(name, "out")] = a if va else (b if vb else np.int64(0))
                valid[(name, "out")] = bool(va or vb)
            elif n.kind == D.OUTPUT:
                a, va = read(n, "a", vals, valid)
                if va:
                    if n.emit_every == 0:
                        last_vals[name] = int(a)
                    else:
                        out_streams[name].append(int(a))
        # latch back-edge carries from this token's emissions
        for key, e in back.items():
            src_key = (e.src, e.src_port)
            if valid.get(src_key, False):
                carry[key] = np.int64(vals[src_key])

    outputs = {}
    for o in g.outputs:
        if g.nodes[o].emit_every == 0:
            outputs[o] = np.array([last_vals[o]] if last_vals[o] is not None else [],
                                  dtype=I32)
        else:
            outputs[o] = np.array(out_streams[o], dtype=I32)
    return outputs


# ---------------------------------------------------------------------------
# token path (data-dependent loops: Branch/Merge recirculation)
# ---------------------------------------------------------------------------

def _execute_tokens(g: D.DFG, arrays, length: int,
                    max_firings: Optional[int] = None):
    """Un-timed token-driven interpretation with per-edge FIFO queues.

    Mirrors the elastic fabric's firing rules without the timing: a node
    fires when every connected input port holds a token (MERGE: either
    port, priority a), consuming one token per port and forking its result
    to every consumer edge. Back edges with an ``init`` value seed one
    initial token; recirculation edges (``init=None``) start empty. The
    run terminates when the network quiesces with all input tokens
    injected — the token-exhaustion rule; a firing budget guards against
    a loop whose predicate never releases its token."""
    from collections import deque

    if max_firings is None:
        max_firings = 10_000 * (length + 1) * max(len(g.nodes), 1)

    # one FIFO per consumer port, keyed (dst, dst_port); producers fork
    # to every edge leaving (src, src_port)
    in_q: Dict[Tuple[str, str], deque] = {}
    consumers: Dict[Tuple[str, str], List[Tuple[str, str]]] = {}
    for e in g.edges:
        in_q[(e.dst, e.dst_port)] = deque()
        consumers.setdefault((e.src, e.src_port), []).append(
            (e.dst, e.dst_port))
    for e in g.back_edges():
        if e.init is not None:
            in_q[(e.dst, e.dst_port)].append(np.int64(e.init))

    def emit(src: str, port: str, value) -> None:
        for key in consumers.get((src, port), ()):
            in_q[key].append(np.int64(value))

    for name in g.inputs:
        for t in range(length):
            emit(name, "out", np.int64(arrays[name][t]))
    for n in g.nodes.values():
        if n.kind == D.CONST:
            # CONST paces one token per stream element (as in the loop path);
            # a const *inside* a recirculation body would need one token per
            # iteration instead, which no fabric stream can provide
            if n.name in g.recirculation_nodes():
                raise ValueError(
                    f"{g.name}: CONST node {n.name} inside a recirculation "
                    f"loop body; fold it into a PE constant")
            for _ in range(length):
                emit(n.name, "out", np.int64(n.value))

    accs = {n.name: np.int64(n.acc_init) for n in g.nodes.values()
            if n.is_reduction()}
    acc_count = {n: 0 for n in accs}
    out_streams: Dict[str, List[int]] = {o: [] for o in g.outputs}
    last_vals: Dict[str, Optional[int]] = {o: None for o in g.outputs}

    order = [n for n in g.topo_order()
             if g.nodes[n].kind not in (D.INPUT,)]
    firings = 0

    def q(name: str, port: str) -> Optional[deque]:
        return in_q.get((name, port))

    def ready(dq: Optional[deque]) -> bool:
        return dq is not None and len(dq) > 0

    progress = True
    while progress:
        progress = False
        for name in order:
            n = g.nodes[name]
            aq, bq, cq = q(name, "a"), q(name, "b"), q(name, "ctrl")
            if n.kind == D.CONST:
                continue          # folded into consumers as PE constants
            if n.kind == D.MERGE:
                if not (ready(aq) or ready(bq)):
                    continue
                src = aq if ready(aq) else bq
                emit(name, "out", src.popleft())
            elif n.kind == D.OUTPUT:
                if not ready(aq):
                    continue
                v = int(wrap32(aq.popleft()))
                if n.emit_every == 0:
                    last_vals[name] = v
                else:
                    out_streams[name].append(v)
            else:
                if (aq is not None and not ready(aq)) or \
                        (bq is not None and not ready(bq)) or \
                        (cq is not None and not ready(cq)):
                    continue
                a = aq.popleft() if aq is not None else None
                b = bq.popleft() if bq is not None else None
                c = cq.popleft() if cq is not None else None
                if n.kind == D.ALU:
                    if n.is_reduction():
                        x = np.int64(n.value) if n.value is not None else a
                        accs[name] = np.int64(alu_eval(n.op, accs[name], x))
                        acc_count[name] += 1
                        k = n.emit_every
                        if (k == 1) or (k > 1 and acc_count[name] % k == 0) \
                                or (k == 0 and acc_count[name] == length):
                            emit(name, "out", accs[name])
                            if k > 1:
                                accs[name] = np.int64(n.acc_init)
                    else:
                        bb = b if b is not None else np.int64(n.value)
                        emit(name, "out", np.int64(alu_eval(n.op, a, bb)))
                elif n.kind == D.CMP:
                    av = a
                    if b is not None:
                        av = np.int64(alu_eval(AluOp.SUB, a, b))
                    elif n.value is not None:
                        av = np.int64(alu_eval(AluOp.SUB, a,
                                               np.int64(n.value)))
                    emit(name, "out", np.int64(cmp_eval(n.op, av)))
                elif n.kind == D.MUX:
                    bb = b if b is not None else np.int64(n.value)
                    emit(name, "out", a if c != 0 else bb)
                elif n.kind == D.BRANCH:
                    emit(name, "t" if c != 0 else "f", a)
                else:   # pragma: no cover - validate() rejects other kinds
                    raise ValueError(f"bad node kind {n.kind}")
            progress = True
            firings += 1
            if firings > max_firings:
                raise RuntimeError(
                    f"{g.name}: token execution exceeded {max_firings} "
                    f"firings; a data-dependent loop predicate never "
                    f"released its token (non-terminating loop)")

    outputs = {}
    for o in g.outputs:
        if g.nodes[o].emit_every == 0:
            outputs[o] = np.array(
                [last_vals[o]] if last_vals[o] is not None else [], dtype=I32)
        else:
            outputs[o] = np.array(out_streams[o], dtype=I32)
    return outputs
