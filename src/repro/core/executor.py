"""Functional (un-timed) execution of STRELA DFGs — the semantic oracle.

Three paths:
  * **vectorized** — acyclic graphs (optionally with reductions that feed only
    OUTPUT nodes): NumPy evaluation over the whole stream at once.
  * **loop** — graphs with loop-carried back edges (dither, find2min) or
    reductions consumed by interior nodes: per-token interpretation, exactly
    mirroring the elastic token semantics. Every node fires exactly once per
    stream element.
  * **token** — graphs with *recirculation* (a back edge with ``init=None``,
    the data-dependent-loop schema the frontend emits for ``lax.while_loop``):
    a token-driven interpreter with per-edge FIFOs. Nodes fire whenever their
    joined inputs hold tokens, so an element can circulate through a
    Branch/Merge loop a data-dependent number of times before its exit token
    is released. Execution terminates by *token exhaustion*: the network is
    run to quiescence after the input streams drain.

All use a wrapping 32-bit integer datapath (the fabric's ALU width).
The cycle-accurate timing lives in ``elastic_sim``; this module defines *what*
a mapped kernel computes, and is the reference for the Pallas kernels and the
fidelity checks of the elastic simulator itself.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.isa import AluOp, CmpOp

I32 = np.int32


def wrap32(x) -> np.ndarray:
    """Wrap to the fabric's 32-bit two's-complement datapath."""
    return np.asarray(x, dtype=np.int64).astype(np.uint64).astype(np.uint32).astype(I32)


def alu_eval(op: AluOp, a, b):
    a64 = np.asarray(a, dtype=np.int64)
    b64 = np.asarray(b, dtype=np.int64)
    if op == AluOp.ADD:
        r = a64 + b64
    elif op == AluOp.SUB:
        r = a64 - b64
    elif op == AluOp.MUL:
        r = a64 * b64
    elif op == AluOp.SHL:
        r = a64 << (b64 & 31)
    elif op == AluOp.SHR:
        r = a64 >> (b64 & 31)
    elif op == AluOp.AND:
        r = a64 & b64
    elif op == AluOp.OR:
        r = a64 | b64
    elif op == AluOp.XOR:
        r = a64 ^ b64
    elif op == AluOp.NOP:
        r = a64
    else:  # pragma: no cover
        raise ValueError(f"bad ALU op {op}")
    return wrap32(r)


def cmp_eval(op: CmpOp, a):
    a = np.asarray(a)
    if op == CmpOp.EQZ:
        return (a == 0).astype(I32)
    if op == CmpOp.GTZ:
        return (a > 0).astype(I32)
    raise ValueError(f"bad CMP op {op}")


# ---------------------------------------------------------------------------
# scalar fast path: 32-bit ALU semantics on plain Python ints
# ---------------------------------------------------------------------------
# The per-token interpreters below (and the fast elastic simulator) spend
# their time on single-token arithmetic, where a NumPy scalar op costs
# microseconds. These are the same operations on Python ints with an
# explicit two's-complement wrap — bit-identical to ``alu_eval``/``wrap32``
# for int32-range operands, which is all the datapath ever carries.

def wrap_i(v: int) -> int:
    """32-bit two's-complement wrap of a Python int (matches ``wrap32``)."""
    v &= 0xFFFFFFFF
    return v - 0x100000000 if v >= 0x80000000 else v


_M, _H, _W = 0xFFFFFFFF, 0x80000000, 0x100000000

ALU_FN_I = {
    AluOp.ADD: lambda a, b: v - _W if (v := (a + b) & _M) >= _H else v,
    AluOp.SUB: lambda a, b: v - _W if (v := (a - b) & _M) >= _H else v,
    AluOp.MUL: lambda a, b: v - _W if (v := (a * b) & _M) >= _H else v,
    AluOp.SHL: lambda a, b: v - _W if (v := (a << (b & 31)) & _M) >= _H else v,
    AluOp.SHR: lambda a, b: v - _W if (v := (a >> (b & 31)) & _M) >= _H else v,
    AluOp.AND: lambda a, b: v - _W if (v := (a & b) & _M) >= _H else v,
    AluOp.OR: lambda a, b: v - _W if (v := (a | b) & _M) >= _H else v,
    AluOp.XOR: lambda a, b: v - _W if (v := (a ^ b) & _M) >= _H else v,
    AluOp.NOP: lambda a, b: v - _W if (v := a & _M) >= _H else v,
}


def _needs_loop(g: D.DFG) -> bool:
    if g.back_edges():
        return True
    for n in g.nodes.values():
        if n.is_reduction():
            for e in g.out_edges(n.name):
                if g.nodes[e.dst].kind != D.OUTPUT:
                    return True
    return False


def execute(g: D.DFG, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Run a DFG over input streams; returns OMN output streams (compacted)."""
    if set(inputs) != set(g.inputs):
        raise ValueError(f"inputs {sorted(inputs)} != DFG inputs {sorted(g.inputs)}")
    arrays = {k: np.asarray(v, dtype=I32) for k, v in inputs.items()}
    lengths = {v.shape[0] for v in arrays.values()}
    if len(lengths) != 1:
        raise ValueError(f"all input streams must share a length, got {lengths}")
    (length,) = lengths
    if g.has_recirculation():
        return _execute_tokens(g, arrays, length)
    if _needs_loop(g):
        return _execute_loop(g, arrays, length)
    return _execute_vectorized(g, arrays, length)


# ---------------------------------------------------------------------------
# vectorized path
# ---------------------------------------------------------------------------

def _operand(g: D.DFG, vals, masks, node: D.Node, port: str):
    e = g.operand(node.name, port)
    if e is None:
        return None, None
    key = (e.src, e.src_port)
    return vals[key], masks[key]


def _execute_vectorized(g, arrays, length):
    vals: Dict[Tuple[str, str], np.ndarray] = {}
    masks: Dict[Tuple[str, str], np.ndarray] = {}
    outputs: Dict[str, np.ndarray] = {}
    full = np.ones(length, dtype=bool)
    for name in g.topo_order():
        n = g.nodes[name]
        if n.kind == D.INPUT:
            vals[(name, "out")], masks[(name, "out")] = arrays[name], full
        elif n.kind == D.CONST:
            vals[(name, "out")] = np.full(length, n.value, dtype=I32)
            masks[(name, "out")] = full
        elif n.kind == D.ALU:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if n.is_reduction():
                vals[(name, "out")], masks[(name, "out")] = _reduce_vec(n, a, ma, length)
                continue
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            m = ma & mb
            vals[(name, "out")] = alu_eval(n.op, a, b)
            masks[(name, "out")] = m
        elif n.kind == D.CMP:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if b is not None:
                a, ma = alu_eval(AluOp.SUB, a, b), ma & mb
            elif n.value is not None:
                a = alu_eval(AluOp.SUB, a, np.full(length, n.value, dtype=I32))
            vals[(name, "out")] = cmp_eval(n.op, a)
            masks[(name, "out")] = ma
        elif n.kind == D.MUX:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            c, mc = _operand(g, vals, masks, n, "ctrl")
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            vals[(name, "out")] = np.where(c != 0, a, b).astype(I32)
            masks[(name, "out")] = ma & mb & mc
        elif n.kind == D.BRANCH:
            a, ma = _operand(g, vals, masks, n, "a")
            c, mc = _operand(g, vals, masks, n, "ctrl")
            m = ma & mc
            vals[(name, "t")] = a
            masks[(name, "t")] = m & (c != 0)
            vals[(name, "f")] = a
            masks[(name, "f")] = m & (c == 0)
        elif n.kind == D.MERGE:
            a, ma = _operand(g, vals, masks, n, "a")
            b, mb = _operand(g, vals, masks, n, "b")
            if np.any(ma & mb):
                raise ValueError(f"MERGE {name}: non-complementary token masks")
            vals[(name, "out")] = np.where(ma, a, b).astype(I32)
            masks[(name, "out")] = ma | mb
        elif n.kind == D.OUTPUT:
            a, ma = _operand(g, vals, masks, n, "a")
            out = a[ma]
            if n.emit_every == 0 and out.size:   # OMN 'last value' mode
                out = out[-1:]
            outputs[name] = out.astype(I32)
    return outputs


def _reduce_vec(n: D.Node, a: np.ndarray, ma: np.ndarray, length: int):
    """Segmented accumulate: acc = op(acc, x); emit & reset every k tokens
    (k=0: emit once at end). Vectorized-path reductions feed only OUTPUTs,
    so we return the emission stream directly."""
    if not np.all(ma):
        raise ValueError("reductions under branch masks need the loop path")
    if n.value is not None:  # paced counter: acc' = op(acc, const)
        x = np.full(length, n.value, dtype=I32)
    else:
        x = a
    k = n.emit_every if n.emit_every else length
    if length % k != 0:
        raise ValueError(f"stream length {length} not divisible by segment {k}")
    seg = np.asarray(x, dtype=np.int64).reshape(length // k, k)
    init = np.int64(n.acc_init)
    if n.op == AluOp.ADD:
        res = init + seg.sum(axis=1)
    elif n.op == AluOp.SUB:
        res = init - seg.sum(axis=1)
    elif n.op == AluOp.MUL:
        res = init * np.prod(seg, axis=1)
    elif n.op in (AluOp.AND, AluOp.OR, AluOp.XOR):
        ufunc = {AluOp.AND: np.bitwise_and, AluOp.OR: np.bitwise_or,
                 AluOp.XOR: np.bitwise_xor}[n.op]
        res = ufunc(init, ufunc.reduce(seg, axis=1))
    else:
        raise ValueError(f"unsupported reduction op {n.op}")
    emit = wrap32(res)
    mask = np.ones(emit.shape[0], dtype=bool)
    return emit, mask


# ---------------------------------------------------------------------------
# loop path (token-by-token)
# ---------------------------------------------------------------------------

def _execute_loop(g, arrays, length):
    """Per-token interpretation of loop-carried graphs, compiled to a flat
    wire-slot program evaluated on plain Python ints (the NumPy-scalar
    version of this interpreter dominated repeat-dispatch wall time)."""
    order = g.topo_order()
    back = {(e.dst, e.dst_port): e for e in g.back_edges()}

    # wire slots: one (value, valid) pair per produced (node, port)
    slot_of: Dict[Tuple[str, str], int] = {}

    def slot(key: Tuple[str, str]) -> int:
        if key not in slot_of:
            slot_of[key] = len(slot_of)
        return slot_of[key]

    for name in order:
        n = g.nodes[name]
        if n.kind == D.BRANCH:
            slot((name, "t"))
            slot((name, "f"))
        elif n.kind != D.OUTPUT:
            slot((name, "out"))

    carries: List[int] = []
    carry_slot: Dict[Tuple[str, str], int] = {}   # (dst, port) -> carry idx
    latches: List[Tuple[int, int]] = []           # (src slot, carry idx)
    for key, e in back.items():
        idx = len(carries)
        carries.append(int(e.init))
        carry_slot[key] = idx
        latches.append((slot_of[(e.src, e.src_port)], idx))

    # operand descriptor: ('s', slot) | ('k', carry idx) | None
    def operand(name: str, port: str):
        if (name, port) in carry_slot:
            return ("k", carry_slot[(name, port)])
        e = g.operand(name, port)
        if e is None:
            return None
        return ("s", slot_of[(e.src, e.src_port)])

    accs = {n.name: int(n.acc_init) for n in g.nodes.values()
            if n.is_reduction()}
    out_streams: Dict[str, List[int]] = {o: [] for o in g.outputs}
    last_vals: Dict[str, Optional[int]] = {o: None for o in g.outputs}
    in_cols = {name: [int(x) for x in arrays[name]] for name in g.inputs}

    prog: List[Tuple] = []
    for name in order:
        n = g.nodes[name]
        if n.kind == D.INPUT:
            prog.append(("in", in_cols[name], slot_of[(name, "out")]))
        elif n.kind == D.CONST:
            prog.append(("const", int(n.value), slot_of[(name, "out")]))
        elif n.kind == D.ALU and n.is_reduction():
            prog.append(("red", name, ALU_FN_I[n.op], n.value, n.emit_every,
                         int(n.acc_init), operand(name, "a"),
                         slot_of[(name, "out")]))
        elif n.kind == D.ALU:
            prog.append(("alu", ALU_FN_I[n.op], n.value, operand(name, "a"),
                         operand(name, "b"), slot_of[(name, "out")]))
        elif n.kind == D.CMP:
            if n.op not in (CmpOp.EQZ, CmpOp.GTZ):
                raise ValueError(f"bad CMP op {n.op}")
            prog.append(("cmp", n.op == CmpOp.EQZ, n.value,
                         operand(name, "a"), operand(name, "b"),
                         slot_of[(name, "out")]))
        elif n.kind == D.MUX:
            prog.append(("mux", n.value, operand(name, "a"),
                         operand(name, "b"), operand(name, "ctrl"),
                         slot_of[(name, "out")]))
        elif n.kind == D.BRANCH:
            prog.append(("br", operand(name, "a"), operand(name, "ctrl"),
                         slot_of[(name, "t")], slot_of[(name, "f")]))
        elif n.kind == D.MERGE:
            prog.append(("mg", name, operand(name, "a"), operand(name, "b"),
                         slot_of[(name, "out")]))
        elif n.kind == D.OUTPUT:
            prog.append(("out", name, n.emit_every, operand(name, "a")))

    n_slots = len(slot_of)
    vals = [0] * n_slots
    valid = [False] * n_slots

    def read(opd):
        if opd is None:
            return None, None
        if opd[0] == "s":
            return vals[opd[1]], valid[opd[1]]
        return carries[opd[1]], True

    for t in range(length):
        for i in range(n_slots):
            valid[i] = False
        for rec in prog:
            op = rec[0]
            if op == "in":
                vals[rec[2]] = rec[1][t]
                valid[rec[2]] = True
            elif op == "const":
                vals[rec[2]] = rec[1]
                valid[rec[2]] = True
            elif op == "alu":
                _, fn, const, oa, ob, dst = rec
                a, va = read(oa)
                b, vb = read(ob)
                if b is None:
                    b, vb = const, True
                ok = bool(va and vb)
                vals[dst] = fn(a, b) if ok else 0
                valid[dst] = ok
            elif op == "red":
                _, name, fn, const, k, acc_init, oa, dst = rec
                a, va = read(oa)
                if not va:
                    valid[dst] = False
                    continue
                x = const if const is not None else a
                acc = fn(accs[name], x)
                emit = (k == 1) or (k > 1 and (t + 1) % k == 0) or \
                       (k == 0 and t == length - 1)
                vals[dst] = acc
                valid[dst] = emit
                if k > 1 and (t + 1) % k == 0:
                    acc = acc_init
                accs[name] = acc
            elif op == "cmp":
                _, eqz, const, oa, ob, dst = rec
                a, va = read(oa)
                b, vb = read(ob)
                if b is not None:
                    a, va = wrap_i(a - b), bool(va and vb)
                elif const is not None and va:
                    a = wrap_i(a - const)
                vals[dst] = (1 if ((a == 0) if eqz else (a > 0)) else 0) \
                    if va else 0
                valid[dst] = bool(va)
            elif op == "mux":
                _, const, oa, ob, oc, dst = rec
                a, va = read(oa)
                b, vb = read(ob)
                c, vc = read(oc)
                if b is None:
                    b, vb = const, True
                ok = bool(va and vb and vc)
                vals[dst] = (a if c != 0 else b) if ok else 0
                valid[dst] = ok
            elif op == "br":
                _, oa, oc, dt, df = rec
                a, va = read(oa)
                c, vc = read(oc)
                ok = bool(va and vc)
                v = a if ok else 0
                vals[dt] = v
                valid[dt] = ok and c != 0
                vals[df] = v
                valid[df] = ok and c == 0
            elif op == "mg":
                _, name, oa, ob, dst = rec
                a, va = read(oa)
                b, vb = read(ob)
                if va and vb:
                    raise ValueError(f"MERGE {name}: both inputs valid "
                                     f"at t={t}")
                vals[dst] = a if va else (b if vb else 0)
                valid[dst] = bool(va or vb)
            else:   # "out"
                _, name, k, oa = rec
                a, va = read(oa)
                if va:
                    if k == 0:
                        last_vals[name] = a
                    else:
                        out_streams[name].append(a)
        # latch back-edge carries from this token's emissions
        for src_slot, idx in latches:
            if valid[src_slot]:
                carries[idx] = vals[src_slot]

    outputs = {}
    for o in g.outputs:
        if g.nodes[o].emit_every == 0:
            outputs[o] = np.array([last_vals[o]] if last_vals[o] is not None else [],
                                  dtype=I32)
        else:
            outputs[o] = np.array(out_streams[o], dtype=I32)
    return outputs


# ---------------------------------------------------------------------------
# token path (data-dependent loops: Branch/Merge recirculation)
# ---------------------------------------------------------------------------
# element-parallel fast path for canonical demand-gated loops
# ---------------------------------------------------------------------------

def _gated_plan(g: D.DFG):
    """Structural eligibility of the element-parallel gated-loop path.

    The demand-token gate of the canonical while-loop schema admits one
    stream element at a time, so elements are mutually independent and
    exit in element order; the loop body can then be evaluated as masked
    *vector* iteration — O(max trip count x body nodes) NumPy ops instead
    of O(elements x trips x nodes) Python token firings. Returns the body
    component list, or None when any condition fails (the general token
    interpreter remains the fallback):

      * every MERGE is a recirculation entry merge, and every
        recirculation edge targets a MERGE;
      * every BRANCH is inside a loop body; bodies contain no reductions;
      * non-body wires enter a body only through entry-merge ports;
      * every loop-carried (``init`` not None) back edge is a demand edge:
        init 0 and a provably-zero source (ALU MUL/AND with constant 0) —
        state cells fall back to token execution;
      * each body component is serialized by a demand edge: the edge's
        source is reachable from the component and its destination feeds
        the component's entries (this is what makes exits element-ordered);
      * stream OUTPUTs consume body wires only via branch exit legs.
    """
    cached = g.__dict__.get("_gated_plan_cache", False)
    if cached is not False:
        return cached

    def compute():
        if not g.has_recirculation():
            return None
        body = g.recirculation_nodes()
        recirc_targets = set()
        for e in g.edges:
            if e.back and e.init is None:
                if g.nodes[e.dst].kind != D.MERGE:
                    return None
                recirc_targets.add(e.dst)
        for n in g.nodes.values():
            if n.kind == D.MERGE and n.name not in recirc_targets:
                return None
            if n.kind == D.BRANCH and n.name not in body:
                return None
            if n.is_reduction() and n.name in body:
                return None
        for name in body:
            n = g.nodes[name]
            for e in g.in_edges(name):
                if e.back or e.src in body:
                    continue
                if n.kind != D.MERGE:
                    return None
        # loop-carried init edges must be zero-valued demand edges
        demand_edges = []
        for e in g.back_edges():
            if e.init is None:
                continue
            src = g.nodes[e.src]
            if e.init != 0 or src.kind != D.ALU or \
                    src.op not in (AluOp.MUL, AluOp.AND) or src.value != 0:
                return None
            demand_edges.append(e)
        # split the body into connected components
        adj: Dict[str, set] = {n: set() for n in body}
        for e in g.edges:
            if e.src in body and e.dst in body:
                adj[e.src].add(e.dst)
                adj[e.dst].add(e.src)
        comps: List[set] = []
        seen: set = set()
        for n in body:
            if n in seen:
                continue
            comp, stack = {n}, [n]
            while stack:
                for m in adj[stack.pop()]:
                    if m not in comp:
                        comp.add(m)
                        stack.append(m)
            seen |= comp
            comps.append(comp)
        # every component must be serialized by a demand edge
        fwd: Dict[str, List[str]] = {n: [] for n in g.nodes}
        for e in g.edges:
            if not e.back:
                fwd[e.src].append(e.dst)

        def reach(start: set) -> set:
            out, stack = set(start), list(start)
            while stack:
                for m in fwd[stack.pop()]:
                    if m not in out:
                        out.add(m)
                        stack.append(m)
            return out

        for comp in comps:
            downstream = reach(comp)
            ok = False
            for e in demand_edges:
                if e.src in downstream and comp & reach({e.dst}):
                    ok = True
                    break
            if not ok:
                return None
        # wires leaving a body must be branch exit legs with no consumer
        # inside the body (they fire exactly once per element); anything
        # else (e.g. a per-round body wire feeding an OMN) falls back
        for comp in comps:
            inner = {(e.src, e.src_port) for e in g.edges
                     if not e.back and e.src in comp and e.dst in comp}
            for e in g.edges:
                if e.back or e.src not in comp or e.dst in comp:
                    continue
                if g.nodes[e.src].kind != D.BRANCH or \
                        (e.src, e.src_port) in inner:
                    return None
        return comps

    plan = compute()
    g.__dict__["_gated_plan_cache"] = plan
    return plan


def _execute_gated_vec(g: D.DFG, arrays, length: int, comps,
                       max_rounds: int = 100_000):
    """Element-parallel evaluation of an eligible gated-loop graph.

    Non-body nodes evaluate exactly like ``_execute_vectorized`` (full
    streams + validity masks); each body component runs as masked vector
    iteration — one pass over the body per loop round, elements retiring
    from the ``active`` mask as their predicate releases them. Exit wires
    come out indexed by element, which is the arrival order the demand
    gate enforces in the token model.
    """
    body_of: Dict[str, set] = {}
    for comp in comps:
        for n in comp:
            body_of[n] = comp
    recirc = [e for e in g.back_edges() if e.init is None]

    vals: Dict[Tuple[str, str], np.ndarray] = {}
    masks: Dict[Tuple[str, str], np.ndarray] = {}
    outputs: Dict[str, np.ndarray] = {}
    full = np.ones(length, dtype=bool)

    def node_vec(n: D.Node, read):
        """One vectorized node evaluation; ``read(port)`` -> (vals, mask)."""
        name = n.name
        if n.kind == D.ALU:
            a, ma = read("a")
            if n.is_reduction():
                return {("out",): _reduce_vec(n, a, ma, length)}
            b, mb = read("b")
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            return {("out",): (alu_eval(n.op, a, b), ma & mb)}
        if n.kind == D.CMP:
            a, ma = read("a")
            b, mb = read("b")
            if b is not None:
                a, ma = alu_eval(AluOp.SUB, a, b), ma & mb
            elif n.value is not None:
                a = alu_eval(AluOp.SUB, a, np.full(length, n.value, dtype=I32))
            return {("out",): (cmp_eval(n.op, a), ma)}
        if n.kind == D.MUX:
            a, ma = read("a")
            b, mb = read("b")
            c, mc = read("ctrl")
            if b is None:
                b, mb = np.full(length, n.value, dtype=I32), full
            return {("out",): (np.where(c != 0, a, b).astype(I32),
                               ma & mb & mc)}
        if n.kind == D.BRANCH:
            a, ma = read("a")
            c, mc = read("ctrl")
            m = ma & mc
            return {("t",): (a, m & (c != 0)), ("f",): (a, m & (c == 0))}
        raise AssertionError(n.kind)      # pragma: no cover

    def run_component(comp: set):
        order = [n for n in g.topo_order() if n in comp]
        carries = {(e.dst, e.dst_port): e for e in recirc if e.dst in comp}
        carry_val = {k: np.zeros(length, dtype=I32) for k in carries}
        carry_ok = {k: np.zeros(length, dtype=bool) for k in carries}
        none_val = np.zeros(length, dtype=I32)
        none_ok = np.zeros(length, dtype=bool)
        bvals: Dict[Tuple[str, str], np.ndarray] = {}
        bmask: Dict[Tuple[str, str], np.ndarray] = {}
        exit_val: Dict[Tuple[str, str], np.ndarray] = {}
        exit_ok: Dict[Tuple[str, str], np.ndarray] = {}
        # wires leaving the body (consumed outside, incl. OUTPUT nodes)
        leaving = {(e.src, e.src_port) for e in g.edges
                   if e.src in comp and e.dst not in comp and not e.back}

        def merge_port(name: str, port: str, rounds: int):
            """Entry-merge operand: a recirculation carry, or the entry
            wire — consumable exactly once, in round 1."""
            key = (name, port)
            if key in carries:
                return carry_val[key], carry_ok[key]
            e = g.operand(name, port)
            if e is None or rounds != 1:
                return none_val, none_ok
            return vals[(e.src, e.src_port)], masks[(e.src, e.src_port)]

        rounds = 0
        while True:
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"{g.name}: token execution exceeded the loop budget; "
                    f"a data-dependent loop predicate never released its "
                    f"token (non-terminating loop)")
            for name in order:
                n = g.nodes[name]
                if n.kind == D.MERGE:
                    av, am = merge_port(name, "a", rounds)
                    bv, bm = merge_port(name, "b", rounds)
                    if np.any(am & bm):
                        raise ValueError(f"MERGE {name}: non-complementary "
                                         f"token masks")
                    outs = {("out",): (np.where(am, av, bv).astype(I32),
                                       am | bm)}
                else:
                    def read(port, _n=n):
                        e = g.operand(_n.name, port)
                        if e is None:
                            return None, None
                        key = (e.src, e.src_port)
                        return bvals.get(key, none_val), \
                            bmask.get(key, none_ok)
                    outs = node_vec(n, read)
                for (port,), (v, m) in outs.items():
                    bvals[(name, port)] = v
                    bmask[(name, port)] = m
            # latch recirculation carries and harvest exits
            for key, e in carries.items():
                src = (e.src, e.src_port)
                carry_val[key] = bvals.get(src, none_val)
                carry_ok[key] = bmask.get(src, none_ok)
            for w in leaving:
                if w not in bmask:
                    continue
                m = bmask[w]
                if w not in exit_ok:
                    exit_val[w] = np.zeros(length, dtype=I32)
                    exit_ok[w] = np.zeros(length, dtype=bool)
                new = m & ~exit_ok[w]
                if np.any(new):
                    exit_val[w] = np.where(new, bvals[w],
                                           exit_val[w]).astype(I32)
                    exit_ok[w] |= new
            if not any(np.any(m) for m in carry_ok.values()):
                break
        for w in leaving:
            if w in exit_ok:
                vals[w] = exit_val[w]
                masks[w] = exit_ok[w]
            else:
                vals[w] = np.zeros(length, dtype=I32)
                masks[w] = np.zeros(length, dtype=bool)

    def eval_node(name: str) -> None:
        n = g.nodes[name]
        if n.kind == D.INPUT:
            vals[(name, "out")], masks[(name, "out")] = arrays[name], full
        elif n.kind == D.CONST:
            vals[(name, "out")] = np.full(length, n.value, dtype=I32)
            masks[(name, "out")] = full
        elif n.kind == D.OUTPUT:
            e = g.operand(name, "a")
            a, ma = vals[(e.src, e.src_port)], masks[(e.src, e.src_port)]
            out = a[ma]
            if n.emit_every == 0 and out.size:
                out = out[-1:]
            outputs[name] = out.astype(I32)
        else:
            def read(port, _n=n):
                e = g.operand(_n.name, port)
                if e is None:
                    return None, None
                if e.back and e.init is not None:
                    # demand edge: provably zero-valued (plan condition)
                    return np.zeros(length, dtype=I32), full
                return vals[(e.src, e.src_port)], masks[(e.src, e.src_port)]
            for (port,), (v, m) in node_vec(n, read).items():
                vals[(name, port)] = v
                masks[(name, port)] = m

    def deps_ready(name: str) -> bool:
        for e in g.in_edges(name):
            if not e.back and (e.src, e.src_port) not in vals:
                return False
        return True

    # relaxation schedule: topo order ignores back edges, so a loop body's
    # exit consumers can precede the body's own trigger point — defer any
    # node whose operands aren't produced yet and re-sweep until done
    entries_of = {frozenset(c): {(e.src, e.src_port) for e in g.edges
                                 if not e.back and e.dst in c
                                 and e.src not in c}
                  for c in comps}
    done_comps: set = set()
    pending = g.topo_order()
    while pending:
        progress = False
        rest: List[str] = []
        for name in pending:
            if name in body_of:
                comp = frozenset(body_of[name])
                if comp in done_comps:
                    progress = True
                    continue
                if all(w in vals for w in entries_of[comp]):
                    done_comps.add(comp)
                    run_component(body_of[name])
                    progress = True
                else:
                    rest.append(name)
            elif deps_ready(name):
                eval_node(name)
                progress = True
            else:
                rest.append(name)
        if not progress:
            raise ValueError(f"{g.name}: gated-loop schedule stuck; "
                             f"falling back to token execution")
        pending = rest
    return outputs


def _execute_tokens(g: D.DFG, arrays, length: int,
                    max_firings: Optional[int] = None):
    """Un-timed token-driven interpretation with per-edge FIFO queues.

    Mirrors the elastic fabric's firing rules without the timing: a node
    fires when every connected input port holds a token (MERGE: either
    port, priority a), consuming one token per port and forking its result
    to every consumer edge. Back edges with an ``init`` value seed one
    initial token; recirculation edges (``init=None``) start empty. The
    run terminates when the network quiesces with all input tokens
    injected — the token-exhaustion rule; a firing budget guards against
    a loop whose predicate never releases its token.

    Scheduling: every node is a deterministic stream function of its input
    FIFOs (a Kahn network), so outputs are schedule-independent — except at
    MERGE, which commits tokens in *arrival* order. When every MERGE is a
    recirculation entry merge (one port fed by an ``init=None`` back edge),
    the demand-token gate serializes arrivals and an event-driven worklist
    is safe and fast. Any other MERGE (e.g. a Branch/Merge conditional
    inside a recirculating graph) forces the conservative round-robin
    sweep — one token per node per pass — which preserves the pipeline's
    arrival interleaving exactly.
    """
    from collections import deque

    if max_firings is None:
        max_firings = 10_000 * (length + 1) * max(len(g.nodes), 1)

    # canonical demand-gated loops: element-parallel masked vector
    # iteration (orders of magnitude fewer Python steps); any structural
    # or runtime ineligibility falls back to token interpretation
    if length:
        comps = _gated_plan(g)
        if comps is not None:
            try:
                return _execute_gated_vec(g, arrays, length, comps)
            except ValueError:
                pass
    # one FIFO per consumer port, keyed (dst, dst_port); producers fork
    # to every edge leaving (src, src_port)
    in_q: Dict[Tuple[str, str], deque] = {}
    consumers: Dict[Tuple[str, str], List[Tuple[str, str]]] = {}
    for e in g.edges:
        in_q[(e.dst, e.dst_port)] = deque()
        consumers.setdefault((e.src, e.src_port), []).append(
            (e.dst, e.dst_port))
    for e in g.back_edges():
        if e.init is not None:
            in_q[(e.dst, e.dst_port)].append(wrap_i(int(e.init)))

    for n in g.nodes.values():
        if n.kind == D.CONST and n.name in g.recirculation_nodes():
            # CONST paces one token per stream element (as in the loop
            # path); a const *inside* a recirculation body would need one
            # token per iteration instead, which no stream can provide
            raise ValueError(
                f"{g.name}: CONST node {n.name} inside a recirculation "
                f"loop body; fold it into a PE constant")

    # --- compile the graph to a flat node program ---
    order = [n for n in g.topo_order()
             if g.nodes[n].kind not in (D.INPUT, D.CONST)]
    node_idx = {name: i for i, name in enumerate(order)}
    accs = {n.name: wrap_i(int(n.acc_init)) for n in g.nodes.values()
            if n.is_reduction()}
    acc_count = {n: 0 for n in accs}
    out_streams: Dict[str, List[int]] = {o: [] for o in g.outputs}
    last_vals: Dict[str, Optional[int]] = {o: None for o in g.outputs}

    def sinks(name: str, port: str) -> List[Tuple[deque, int]]:
        """(consumer queue, consumer program index) fanout of one wire."""
        return [(in_q[key], node_idx.get(key[0], -1))
                for key in consumers.get((name, port), ())]

    recirc_targets = {e.dst for e in g.edges if e.back and e.init is None}
    worklist_safe = all(n.name in recirc_targets
                        for n in g.nodes.values() if n.kind == D.MERGE)

    # per-node closures: ``fire`` processes at most ONE token per call and
    # returns the woken consumer-index tuple (None = not ready); ``drain``
    # processes every available token in one call and returns
    # (count fired, wake tuple) or None. Queues and fanout are bound into
    # the closures; the 32-bit wrap is inlined in ALU_FN_I.
    fires: List = []
    drains: List = []
    for name in order:
        n = g.nodes[name]
        aq = in_q.get((name, "a"))
        bq = in_q.get((name, "b"))
        cq = in_q.get((name, "ctrl"))
        out_s = sinks(name, "out")
        out_qs = tuple(dq for dq, _ in out_s)
        wake = tuple(sorted({j for _, j in out_s if j >= 0}))
        fire, drain = _compile_token_node(
            n, length, aq, bq, cq, out_qs, wake,
            tuple(dq for dq, _ in sinks(name, "t")),
            tuple(sorted({j for _, j in sinks(name, "t") if j >= 0})),
            tuple(dq for dq, _ in sinks(name, "f")),
            tuple(sorted({j for _, j in sinks(name, "f") if j >= 0})),
            accs, acc_count, out_streams, last_vals)
        fires.append(fire)
        drains.append(drain)

    # seed stream tokens: inputs and (length-paced) consts
    for name in g.inputs:
        vals = [int(x) for x in arrays[name]]
        for dq, _ in sinks(name, "out"):
            dq.extend(vals)
    for n in g.nodes.values():
        if n.kind == D.CONST:
            for dq, _ in sinks(n.name, "out"):
                dq.extend([int(n.value)] * length)

    firings = 0
    overflow = RuntimeError(
        f"{g.name}: token execution exceeded {max_firings} firings; a "
        f"data-dependent loop predicate never released its token "
        f"(non-terminating loop)")

    if worklist_safe:
        # event-driven: drain each node, then revisit only nodes whose
        # input queues gained tokens
        pending = deque(range(len(drains)))
        queued = bytearray(len(drains))
        for i in pending:
            queued[i] = 1
        while pending:
            i = pending.popleft()
            queued[i] = 0
            res = drains[i]()
            if res is not None:
                count, w = res
                firings += count
                if firings > max_firings:
                    raise overflow
                for j in w:
                    if not queued[j]:
                        queued[j] = 1
                        pending.append(j)
    else:
        # conservative sweep: one token per node per pass, topo order —
        # preserves the pipeline interleaving that orders arrivals at
        # non-loop MERGEs
        progress = True
        while progress:
            progress = False
            for f in fires:
                if f() is not None:
                    progress = True
                    firings += 1
                    if firings > max_firings:
                        raise overflow

    outputs = {}
    for o in g.outputs:
        if g.nodes[o].emit_every == 0:
            outputs[o] = np.array(
                [last_vals[o]] if last_vals[o] is not None else [], dtype=I32)
        else:
            outputs[o] = np.array(out_streams[o], dtype=I32)
    return outputs


def _compile_token_node(n: D.Node, length: int, aq, bq, cq,
                        out_qs, wake, t_qs, t_wake, f_qs, f_wake,
                        accs, acc_count, out_streams, last_vals):
    """Compile one DFG node into a pair of closures for the token
    interpreter.

    ``fire()`` processes at most one token, returning the tuple of
    consumer indices to wake (empty tuple = fired without emitting) or
    ``None`` when not ready — the conservative sweep's unit step.
    ``drain()`` processes every available token in one call, returning
    ``(count, wake tuple)`` or ``None`` — the event-driven worklist's unit
    step, amortizing call overhead over token bursts. Queues, fanout, and
    constants are bound into the closures so the hot loops do no dict
    lookups or kind dispatch.
    """
    kind = n.kind
    name = n.name

    if kind == D.OUTPUT:
        if n.emit_every == 0:
            def fire():
                if not aq:
                    return None
                v = aq.popleft() & _M
                last_vals[name] = v - _W if v >= _H else v
                return wake

            def drain():
                if not aq:
                    return None
                c = len(aq)
                v = aq[-1] & _M
                last_vals[name] = v - _W if v >= _H else v
                aq.clear()
                return c, wake
        else:
            app = out_streams[name].append

            def fire():
                if not aq:
                    return None
                v = aq.popleft() & _M
                app(v - _W if v >= _H else v)
                return wake

            def drain():
                if not aq:
                    return None
                c = len(aq)
                while aq:
                    v = aq.popleft() & _M
                    app(v - _W if v >= _H else v)
                return c, wake
        return fire, drain

    if kind == D.MERGE:
        def fire():
            if aq:
                v = aq.popleft()
            elif bq:
                v = bq.popleft()
            else:
                return None
            for dq in out_qs:
                dq.append(v)
            return wake

        def drain():
            c = 0
            while True:
                if aq:
                    v = aq.popleft()
                elif bq:
                    v = bq.popleft()
                else:
                    break
                for dq in out_qs:
                    dq.append(v)
                c += 1
            return (c, wake) if c else None
        return fire, drain

    if kind == D.BRANCH:
        def fire():
            if not aq or not cq:
                return None
            c = cq.popleft()
            a = aq.popleft()
            if c != 0:
                for dq in t_qs:
                    dq.append(a)
                return t_wake
            for dq in f_qs:
                dq.append(a)
            return f_wake

        tf_wake = tuple(sorted(set(t_wake) | set(f_wake)))

        def drain():
            c = 0
            legs = 0
            while aq and cq:
                ctl = cq.popleft()
                a = aq.popleft()
                if ctl != 0:
                    for dq in t_qs:
                        dq.append(a)
                    legs |= 1
                else:
                    for dq in f_qs:
                        dq.append(a)
                    legs |= 2
                c += 1
            if not c:
                return None
            return c, (t_wake if legs == 1 else
                       f_wake if legs == 2 else tf_wake)
        return fire, drain

    if kind == D.CMP:
        if n.op not in (CmpOp.EQZ, CmpOp.GTZ):
            raise ValueError(f"bad CMP op {n.op}")
        eqz = n.op == CmpOp.EQZ
        const = n.value
        if bq is not None:
            def step():
                av = (aq.popleft() - bq.popleft()) & _M
                if av >= _H:
                    av -= _W
                v = 1 if ((av == 0) if eqz else (av > 0)) else 0
                for dq in out_qs:
                    dq.append(v)

            def fire():
                if not aq or not bq:
                    return None
                step()
                return wake

            def drain():
                c = 0
                while aq and bq:
                    step()
                    c += 1
                return (c, wake) if c else None
        else:
            def step():
                if const is not None:
                    av = (aq.popleft() - const) & _M
                    if av >= _H:
                        av -= _W
                else:
                    av = aq.popleft()
                v = 1 if ((av == 0) if eqz else (av > 0)) else 0
                for dq in out_qs:
                    dq.append(v)

            def fire():
                if not aq:
                    return None
                step()
                return wake

            def drain():
                c = len(aq)
                if not c:
                    return None
                while aq:
                    step()
                return c, wake
        return fire, drain

    if kind == D.MUX:
        const = n.value

        def step():
            ctl = cq.popleft()
            a = aq.popleft()
            b = bq.popleft() if bq is not None else const
            v = a if ctl != 0 else b
            for dq in out_qs:
                dq.append(v)

        def fire():
            if not aq or not cq or (bq is not None and not bq):
                return None
            step()
            return wake

        def drain():
            c = 0
            while aq and cq and (bq is None or bq):
                step()
                c += 1
            return (c, wake) if c else None
        return fire, drain

    # ALU
    fn = ALU_FN_I[n.op]
    const = n.value
    if n.is_reduction():
        k = n.emit_every
        acc_init = wrap_i(int(n.acc_init))
        extras = tuple(q for q in (bq, cq) if q is not None)

        def fire():
            if not aq:
                return None
            for q in extras:
                if not q:
                    return None
            a = aq.popleft()
            for q in extras:
                q.popleft()               # joined but unused (token pacing)
            x = const if const is not None else a
            acc = fn(accs[name], x)
            count = acc_count[name] = acc_count[name] + 1
            ret = ()
            if k == 1 or (k > 1 and count % k == 0) or \
                    (k == 0 and count == length):
                for dq in out_qs:
                    dq.append(acc)
                ret = wake
                if k > 1:
                    acc = acc_init
            accs[name] = acc
            return ret

        def drain():
            c = 0
            emitted = False
            while True:
                r = fire()
                if r is None:
                    break
                c += 1
                emitted = emitted or r is wake
            if not c:
                return None
            return c, (wake if emitted else ())
        return fire, drain

    if bq is None:
        if len(out_qs) == 1:
            app = out_qs[0].append

            def fire():
                if not aq:
                    return None
                app(fn(aq.popleft(), const))
                return wake

            def drain():
                c = len(aq)
                if not c:
                    return None
                while aq:
                    app(fn(aq.popleft(), const))
                return c, wake
        else:
            def fire():
                if not aq:
                    return None
                v = fn(aq.popleft(), const)
                for dq in out_qs:
                    dq.append(v)
                return wake

            def drain():
                c = len(aq)
                if not c:
                    return None
                while aq:
                    v = fn(aq.popleft(), const)
                    for dq in out_qs:
                        dq.append(v)
                return c, wake
        return fire, drain

    if len(out_qs) == 1:
        app = out_qs[0].append

        def fire():
            if not aq or not bq:
                return None
            app(fn(aq.popleft(), bq.popleft()))
            return wake

        def drain():
            c = 0
            while aq and bq:
                app(fn(aq.popleft(), bq.popleft()))
                c += 1
            return (c, wake) if c else None
    else:
        def fire():
            if not aq or not bq:
                return None
            v = fn(aq.popleft(), bq.popleft())
            for dq in out_qs:
                dq.append(v)
            return wake

        def drain():
            c = 0
            while aq and bq:
                v = fn(aq.popleft(), bq.popleft())
                for dq in out_qs:
                    dq.append(v)
                c += 1
            return (c, wake) if c else None
    return fire, drain
