"""Place & route of DFGs onto the elastic fabric (the paper's Sec. IV flow).

The paper maps kernels *manually*; this module provides the automatic
equivalent (the 'compiler guidelines' of Sec. VIII): a deterministic greedy
placer with randomized restarts plus a breadth-first signal router over the
fabric's port-resource graph. Manual placement hints are accepted so the
paper's published mappings (Fig. 7) can be reproduced exactly.

Conventions (Sec. IV-B): inputs enter through IMNs on the north border,
outputs leave through OMNs on the south border, and the E/W border columns
provide the south-to-north return paths for feedback signals.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import random
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

from repro.core import dfg as D
from repro.core.fabric import FU_INS, FU_OUT, Fabric, Res
from repro.core.isa import (AluOp, CmpOp, CtrlSel, JoinMergeMode, OperandSel,
                            OutMux, OutSel, PEConfig, config_cycles)

Signal = Tuple[str, str]          # (node name, out port)  e.g. ("c1","out")
FU_PORT_OF = {"a": "FU_A", "b": "FU_B", "ctrl": "FU_C"}

MAPPERS = ("greedy", "anneal")


def default_seed() -> int:
    """The mapper RNG seed: ``STRELA_MAP_SEED`` in the environment, else 0.

    Read at call time (not import) so tests and CI steps can re-seed
    without re-importing; every ``map_dfg``/annealer entry point resolves
    ``seed=None`` through this one function."""
    return int(os.environ.get("STRELA_MAP_SEED", "0"))


def default_mapper() -> str:
    """Mapper selection: ``STRELA_MAPPER`` in the environment, else greedy."""
    m = os.environ.get("STRELA_MAPPER", "greedy")
    if m not in MAPPERS:
        raise ValueError(f"STRELA_MAPPER must be one of {MAPPERS}, got {m!r}")
    return m


@dataclasses.dataclass
class Route:
    """Claimed resource tree for one signal: res -> parent res (None at src)."""

    source: Res
    parent: Dict[Res, Optional[Res]]

    def path_to(self, dst: Res) -> List[Res]:
        out: List[Res] = []
        cur: Optional[Res] = dst
        while cur is not None:
            out.append(cur)
            cur = self.parent[cur]
        return list(reversed(out))


@dataclasses.dataclass
class Mapping:
    dfg: D.DFG
    fabric: Fabric
    place: Dict[str, Tuple[int, int]]            # functional node -> (r, c)
    imn_of: Dict[str, int]                       # INPUT node -> IMN column
    omn_of: Dict[str, int]                       # OUTPUT node -> OMN column
    routes: Dict[Signal, Route]
    edge_dest: Dict[Tuple[str, str, str, str], Res]   # (src,sp,dst,dp) -> sink

    def __getstate__(self):
        # drop memo fields (_active_pes, _station_graph — the latter holds
        # compiled closures) so pickled artifacts stay lean and loadable
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_")}

    def active_pes(self) -> Set[Tuple[int, int]]:
        """PEs carrying an FU or any route-through traffic (need config).
        Memoized: routes are immutable once mapped, and per-request
        dispatch asks for the config cost on every run."""
        act = self.__dict__.get("_active_pes")
        if act is None:
            act = set(self.place.values())
            for route in self.routes.values():
                for res in route.parent:
                    if 0 <= res.r < self.fabric.rows and \
                            0 <= res.c < self.fabric.cols:
                        act.add((res.r, res.c))
            self.__dict__["_active_pes"] = act
        return act

    def n_active_pes(self) -> int:
        return len(self.active_pes())

    def config_cycles(self) -> int:
        return config_cycles(self.n_active_pes())

    def arithmetic_pes(self) -> int:
        return sum(1 for n in self.dfg.nodes.values() if n.kind == D.ALU)

    def control_pes(self) -> int:
        return sum(1 for n in self.dfg.nodes.values()
                   if n.kind in (D.CMP, D.MUX, D.BRANCH, D.MERGE))

    def n_mem_nodes(self) -> int:
        return len(self.imn_of) + len(self.omn_of)

    def digest(self) -> str:
        """Stable content hash of the mapping decision (placement, stream
        bindings, and every claimed route edge). Two mappings with equal
        digests configure the fabric identically — the determinism tests
        compare this across processes, and it is independent of memo
        fields and dict insertion order."""
        h = hashlib.sha1()
        for n in sorted(self.place):
            h.update(f"P|{n}|{self.place[n]}".encode())
        for n in sorted(self.imn_of):
            h.update(f"I|{n}|{self.imn_of[n]}".encode())
        for n in sorted(self.omn_of):
            h.update(f"O|{n}|{self.omn_of[n]}".encode())
        for sig in sorted(self.routes):
            route = self.routes[sig]
            edges = sorted((repr(res), repr(par))
                           for res, par in route.parent.items())
            h.update(f"R|{sig}|{edges}".encode())
        for key in sorted(self.edge_dest):
            h.update(f"D|{key}|{self.edge_dest[key]!r}".encode())
        return h.hexdigest()


class MappingError(RuntimeError):
    pass


def auto_unroll(g: D.DFG, fabric: Optional[Fabric] = None,
                max_factor: int = 4, chained: bool = False,
                restarts: int = 250, seed: int = 0
                ) -> Tuple["Mapping", int]:
    """Automate mapping strategy 2 (Sec. IV-B): replicate a small DFG as
    many times as still places & routes — the paper caps at 4 (one lane per
    IMN) and found relu fits x3 and dither x2 'due to congestion'; this
    search reproduces those numbers mechanically.

    ``chained``: use cross-lane state chaining (stateful kernels like
    dither); otherwise independent lanes. Returns (mapping, factor).
    """
    from repro.core.dfg import unroll, unroll_chained
    fabric = fabric or Fabric()
    best: Optional[Tuple[Mapping, int]] = None
    for factor in range(1, max_factor + 1):
        # gated data-dependent loops are self-contained per element: they
        # replicate as independent lanes, never with cross-lane chaining
        gu = (unroll_chained(g, factor)
              if chained and g.back_edges() and not g.has_recirculation()
              else unroll(g, factor))
        if len(gu.inputs) > fabric.n_imns or len(gu.outputs) > fabric.n_omns:
            break
        try:
            m = map_dfg(gu, fabric, seed=seed, restarts=restarts)
            best = (m, factor)
        except MappingError:
            break
    if best is None:
        raise MappingError(f"{g.name}: not mappable even at factor 1")
    return best


# ---------------------------------------------------------------------------
# Router — PathFinder-style negotiated congestion (McMurchie & Ebeling),
# the standard algorithm for mesh fabrics. Signals first route greedily
# (sharing allowed at a cost), then congestion history drives rip-up/reroute
# until every port resource is owned by exactly one signal.
#
# The hot loop runs on the fabric's dense integer resource index
# (``Fabric.rindex``): ids replace frozen ``Res`` dataclasses, whose
# hashing dominated mapping wall time (ISSUE 4). The search order, cost
# arithmetic, and RNG consumption are exactly those of the original
# ``Res``-keyed router, so every mapping (and every downstream cycle
# count) is bit-identical.
# ---------------------------------------------------------------------------

import heapq

_INF = float("inf")


class _NegotiatedRouter:
    def __init__(self, fabric: Fabric, rng: random.Random):
        self.fabric = fabric
        self.rng = rng
        self.idx = fabric.rindex()
        n = len(self.idx.res_of)
        self.hist: List[float] = [0.0] * n
        # version-stamped per-resource search state: arrays live across
        # dijkstra calls, a bumped epoch invalidates them in O(1)
        self._dist: List[float] = [0.0] * n
        self._parent: List[int] = [0] * n
        self._seen: List[int] = [0] * n           # epoch when dist was set
        self._done: List[int] = [0] * n           # epoch when finalized
        self._epoch = 0
        self._usage: List[Optional[Set[Signal]]] = [None] * n

    def route_all(self, demands: List[Tuple[Signal, Res, List[Res]]],
                  max_iters: int = 48) -> Dict[Signal, Route]:
        """demands: (signal, source res, sink res list). Returns conflict-free
        routes or raises MappingError."""
        idx = self.idx
        id_of = idx.id_of
        dem = [(sig, id_of[src], [id_of[d] for d in sinks])
               for sig, src, sinks in demands]
        pres_fac = 0.6
        trees: Dict[Signal, Dict[int, int]] = {}
        n_over = 0
        for it in range(max_iters):
            usage = self._usage = [None] * len(idx.res_of)
            trees = {}
            for sig, src, sinks in dem:
                # sources (FU_OUT / IMN) are exclusive by placement; branch
                # t/f legs legitimately share their FU_OUT, so sources are
                # not congestion-counted.
                tree = {src: -1}                  # res id -> parent id (-1 = src)
                for dst in sinks:
                    if not self._dijkstra(sig, tree, dst, pres_fac):
                        raise MappingError(f"no path {sig} -> {idx.res_of[dst]} "
                                           f"(disconnected or terminal blocked)")
                trees[sig] = tree
            n_over = 0
            for rid, users in enumerate(usage):
                if users is not None and len(users) > 1:
                    self.hist[rid] += len(users) - 1
                    n_over += 1
            if not n_over:
                routes: Dict[Signal, Route] = {}
                for sig, src, _ in dem:
                    parent = {idx.res_of[rid]: (None if pid < 0
                                                else idx.res_of[pid])
                              for rid, pid in trees[sig].items()}
                    routes[sig] = Route(idx.res_of[src], parent)
                return routes
            pres_fac *= 1.7
        raise MappingError(f"congestion unresolved after {max_iters} iterations "
                           f"({n_over} oversubscribed ports)")

    def _claim(self, rid: int, sig) -> None:
        s = self._usage[rid]
        if s is None:
            self._usage[rid] = {sig}
        else:
            s.add(sig)

    def _dijkstra(self, sig, tree: Dict[int, int], dst: int,
                  pres_fac) -> bool:
        if dst in tree:
            self._claim(dst, sig)
            return True
        idx = self.idx
        fan = idx.fanout_ids
        hist = self.hist
        is_terminal = idx.is_terminal
        rnd = self.rng.random
        usage = self._usage
        self._epoch += 1
        epoch = self._epoch
        dist, parent = self._dist, self._parent
        seen, done = self._seen, self._done
        heap = []
        for rid in tree:
            dist[rid] = 0.0
            seen[rid] = epoch
            heap.append((0.0, rnd(), rid))
        heapq.heapify(heap)
        while heap:
            d, _, cur = heapq.heappop(heap)
            if done[cur] == epoch:
                continue
            done[cur] = epoch
            if cur == dst:
                chain: List[int] = []
                node = cur
                while node not in tree:
                    chain.append(node)
                    node = parent[node]
                for rid in reversed(chain):
                    tree[rid] = parent[rid]
                    self._claim(rid, sig)
                return True
            for nxt in fan[cur]:
                if is_terminal[nxt] and nxt != dst:
                    continue                      # FU inputs / OMNs: sinks only
                users = usage[nxt]
                if users:
                    nd = d + (1.0 + hist[nxt]) * \
                        (1.0 + (len(users) - (sig in users)) * pres_fac)
                else:
                    nd = d + 1.0 + hist[nxt]
                if seen[nxt] != epoch or nd < dist[nxt]:
                    dist[nxt] = nd
                    seen[nxt] = epoch
                    parent[nxt] = cur
                    heapq.heappush(heap, (nd, rnd(), nxt))
        return False


# ---------------------------------------------------------------------------
# Placer + top-level map()
# ---------------------------------------------------------------------------

def _functional_nodes(g: D.DFG) -> List[str]:
    return [n for n in g.topo_order()
            if g.nodes[n].kind in (D.ALU, D.CMP, D.MUX, D.BRANCH, D.MERGE)]


def _depths(g: D.DFG) -> Dict[str, int]:
    depth: Dict[str, int] = {}
    for n in g.topo_order():
        preds = [depth.get(e.src, 0) for e in g.in_edges(n) if not e.back]
        base = max(preds) if preds else 0
        kind = g.nodes[n].kind
        depth[n] = base + (1 if kind not in (D.INPUT, D.CONST) else 0)
    return depth


def map_dfg(g: D.DFG, fabric: Optional[Fabric] = None,
            hints: Optional[Dict[str, Tuple[int, int]]] = None,
            imn_hint: Optional[Dict[str, int]] = None,
            omn_hint: Optional[Dict[str, int]] = None,
            seed: Optional[int] = None, restarts: int = 400,
            optimize: Optional[str] = None) -> Mapping:
    """Place & route ``g``; raises MappingError if no mapping is found.

    ``hints`` pins functional nodes to PEs and ``imn_hint``/``omn_hint`` pin
    the stream-to-memory-node binding — used to reproduce the paper's manual
    mappings (Fig. 7) deterministically.

    ``seed`` (default: ``STRELA_MAP_SEED``, else 0) seeds the single RNG
    driving restart jitter and route tie-breaking — the same seed always
    yields a bit-identical ``Mapping``. ``optimize`` selects the mapper
    (default: ``STRELA_MAPPER``, else greedy): ``"anneal"`` refines the
    greedy mapping with the cost-driven simulated annealer
    (``core.opt_mapper``), guaranteed never cycle-worse. Pinned mappings
    (any hint given) always stay greedy — they *are* the answer.
    """
    fabric = fabric or Fabric()
    seed = default_seed() if seed is None else seed
    optimize = default_mapper() if optimize is None else optimize
    if optimize not in MAPPERS:
        raise ValueError(f"optimize must be one of {MAPPERS}, "
                         f"got {optimize!r}")
    if len(g.inputs) > fabric.n_imns:
        raise MappingError(f"{g.name}: {len(g.inputs)} inputs > {fabric.n_imns} IMNs")
    if len(g.outputs) > fabric.n_omns:
        raise MappingError(f"{g.name}: {len(g.outputs)} outputs > {fabric.n_omns} OMNs")
    rng = random.Random(seed)
    last_err: Optional[str] = None
    greedy: Optional[Mapping] = None
    for attempt in range(restarts):
        temp = attempt / max(restarts - 1, 1)      # 0 → deterministic greedy,
        try:                                       # 1 → near-random search
            greedy = _try_map(g, fabric, hints, imn_hint, omn_hint, rng,
                              temp=temp)
            break
        except MappingError as e:
            last_err = str(e)
    if greedy is None:
        raise MappingError(f"{g.name}: no feasible mapping after {restarts} "
                           f"restarts (last: {last_err})")
    if optimize == "anneal" and not (hints or imn_hint or omn_hint):
        from repro.core.opt_mapper import anneal_map
        return anneal_map(g, fabric, seed=seed, baseline=greedy)
    return greedy


def _try_map(g, fabric, hints, imn_hint, omn_hint, rng, temp: float) -> Mapping:
    depth = _depths(g)
    funcs = _functional_nodes(g)
    jitter = temp > 0
    # IMN/OMN binding is a software choice (stream configuration), so the
    # mapper searches permutations of it on jittered attempts.
    imn_cols = list(range(len(g.inputs)))
    omn_cols = list(range(len(g.outputs)))
    if jitter and rng.random() < min(1.0, temp * 2):
        rng.shuffle(imn_cols)
        rng.shuffle(omn_cols)
    imn_of = {name: imn_cols[i] for i, name in enumerate(g.inputs)}
    omn_of = {name: omn_cols[i] for i, name in enumerate(g.outputs)}
    if imn_hint:
        imn_of = dict(imn_hint)
    if omn_hint:
        omn_of = dict(omn_hint)

    # ---- placement ----
    place: Dict[str, Tuple[int, int]] = {}
    free = {(r, c) for r in range(fabric.rows) for c in range(fabric.cols)}
    for n in funcs:
        if hints and n in hints:
            pos = hints[n]
            if pos not in free:
                raise MappingError(f"hint collision at {pos}")
            place[n] = pos
            free.discard(pos)
            continue
        pref_row = min(depth[n] - 1, fabric.rows - 1)
        # anchor columns: predecessors' columns / IMN columns; successors' OMNs
        anchors: List[int] = []
        for e in g.in_edges(n):
            if e.back:
                continue
            if e.src in place:
                anchors.append(place[e.src][1])
            elif g.nodes[e.src].kind == D.INPUT:
                anchors.append(imn_of[e.src])
        for e in g.out_edges(n):
            if g.nodes[e.dst].kind == D.OUTPUT:
                anchors.append(omn_of[e.dst])
        best, best_cost = None, None
        options = sorted(free)
        if jitter:
            rng.shuffle(options)
        for (r, c) in options:
            cost = abs(r - pref_row) * 2
            for e in g.in_edges(n):
                if e.src in place and not e.back:
                    pr, pc = place[e.src]
                    cost += abs(r - pr) + abs(c - pc)
                    cost += 0 if pr < r else 2      # prefer northward producers
            for a in anchors:
                cost += abs(c - a)
            if jitter:
                cost += rng.random() * (0.5 + temp * 12)   # annealed noise
            if best_cost is None or cost < best_cost:
                best, best_cost = (r, c), cost
        if best is None:
            raise MappingError("fabric full")
        place[n] = best
        free.discard(best)

    # ---- routing (negotiated congestion over all signals at once) ----
    routes, edge_dest = route_signals(g, fabric, place, imn_of, omn_of, rng,
                                      depth=depth)
    return Mapping(g, fabric, place, imn_of, omn_of, routes, edge_dest)


def route_signals(g: D.DFG, fabric: Fabric,
                  place: Dict[str, Tuple[int, int]],
                  imn_of: Dict[str, int], omn_of: Dict[str, int],
                  rng: random.Random,
                  depth: Optional[Dict[str, int]] = None
                  ) -> Tuple[Dict[Signal, Route],
                             Dict[Tuple[str, str, str, str], Res]]:
    """Route every signal of ``g`` for a *fixed* placement + stream binding.

    This is the routing half of ``_try_map``, shared with the annealing
    mapper (``core.opt_mapper``), whose moves mutate the placement and then
    re-route. Demand order and RNG consumption are identical to the greedy
    path, so the same (placement, rng state) always reproduces the same
    routes. Raises MappingError when congestion cannot be resolved."""
    if depth is None:
        depth = _depths(g)

    def source_res(sig: Signal) -> Res:
        node, port = sig
        kind = g.nodes[node].kind
        if kind == D.INPUT:
            return fabric.imn_res(imn_of[node])
        if kind == D.CONST:
            raise MappingError("CONST nodes must be folded into PE constants")
        r, c = place[node]
        return Res(r, c, FU_OUT)

    edge_dest: Dict[Tuple[str, str, str, str], Res] = {}
    sinks_of: Dict[Signal, List[Res]] = {}
    order: List[Signal] = []
    for e in sorted((e for e in g.edges if g.nodes[e.src].kind != D.CONST),
                    key=lambda e: (depth.get(e.src, 0), e.src, e.dst)):
        sig: Signal = (e.src, e.src_port)
        if g.nodes[e.dst].kind == D.OUTPUT:
            dst = fabric.omn_res(omn_of[e.dst])
        else:
            dr, dc = place[e.dst]
            dst = Res(dr, dc, FU_PORT_OF[e.dst_port])
        if sig not in sinks_of:
            sinks_of[sig] = []
            order.append(sig)
        sinks_of[sig].append(dst)
        edge_dest[(e.src, e.src_port, e.dst, e.dst_port)] = dst

    demands = [(sig, source_res(sig), sinks_of[sig]) for sig in order]
    routes = _NegotiatedRouter(fabric, rng).route_all(demands)
    return routes, edge_dest


# ---------------------------------------------------------------------------
# Configuration-word generation
# ---------------------------------------------------------------------------

_ALU_KIND = {D.ALU: OutMux.ALU, D.CMP: OutMux.CMP, D.MUX: OutMux.MUX,
             D.BRANCH: OutMux.ALU, D.MERGE: OutMux.MUX}


def generate_configs(m: Mapping) -> List[PEConfig]:
    """Emit one 158-bit configuration word per active PE (Sec. V-B/V-C)."""
    fabric = m.fabric
    by_pe: Dict[Tuple[int, int], PEConfig] = {}

    def cfg(r: int, c: int) -> PEConfig:
        key = (r, c)
        if key not in by_pe:
            by_pe[key] = PEConfig(pe_id=fabric.pe_index(r, c))
        return by_pe[key]

    node_at = {pos: n for n, pos in m.place.items()}

    # functional configuration
    for n, (r, c) in m.place.items():
        node = m.dfg.nodes[n]
        pc = cfg(r, c)
        if node.kind == D.ALU:
            pc.alu_op = node.op
            pc.out_mux = OutMux.ALU
            pc.jm_mode = JoinMergeMode.JOIN
            if node.is_reduction():
                pc.alu_fb_imm = 1
                pc.data_reg_init = node.acc_init & 0xFFFFFFFF
                pc.valid_delay = min(node.emit_every, 63)
        elif node.kind == D.CMP:
            pc.cmp_op = node.op
            pc.out_mux = OutMux.CMP
            pc.jm_mode = JoinMergeMode.JOIN
        elif node.kind == D.MUX:
            pc.out_mux = OutMux.MUX
            pc.jm_mode = JoinMergeMode.JOIN_CTRL
        elif node.kind == D.BRANCH:
            pc.out_mux = OutMux.ALU
            pc.alu_op = AluOp.NOP
            pc.jm_mode = JoinMergeMode.JOIN_CTRL
        elif node.kind == D.MERGE:
            pc.out_mux = OutMux.MUX
            pc.jm_mode = JoinMergeMode.MERGE
        if node.value is not None:
            pc.const_val = node.value & 0xFFFFFFFF
            if node.kind == D.ALU and not node.is_reduction():
                pc.in_b_sel = OperandSel.CONST
            elif node.kind == D.MUX and m.dfg.operand(n, "b") is None:
                pc.in_b_sel = OperandSel.CONST

    # routing configuration: walk every claimed tree edge
    for sig, route in m.routes.items():
        for res, par in route.parent.items():
            if par is None:
                continue
            r, c = res.r, res.c
            if res.port == "OMN" or res.port == "IMN":
                continue
            pc = cfg(r, c) if 0 <= r < fabric.rows else None
            if pc is None:
                continue
            if res.port.startswith("OUT_"):
                d = res.port[4:]
                attr = f"out_sel_{d.lower()}"
                if par.port == FU_OUT:
                    setattr(pc, attr, OutSel.FU)
                elif par.port.startswith("IN_"):
                    setattr(pc, attr, OutSel[f"IN_{par.port[3:]}"])
            elif res.port.startswith("IN_"):
                # fork mask of the upstream producer's input port is set when
                # we see its fanout legs; gating: mark this EB active
                side = {"N": 0, "E": 1, "S": 2, "W": 3}[res.port[3:]]
                pc.gate_mask |= (1 << side)
            elif res.port in FU_INS:
                sel_attr = {"FU_A": "in_a_sel", "FU_B": "in_b_sel",
                            "FU_C": "ctrl_sel"}[res.port]
                if par.port.startswith("IN_"):
                    side = par.port[3:]
                    sel = (OperandSel[f"PORT_{side}"] if res.port != "FU_C"
                           else CtrlSel[f"PORT_{side}"])
                    setattr(pc, sel_attr, sel)
                elif par.port == FU_OUT:     # non-immediate feedback loop
                    setattr(pc, sel_attr, OperandSel.FEEDBACK)
    return [by_pe[k] for k in sorted(by_pe)]
