"""Cycle-level simulation of a mapped kernel on the elastic fabric.

Timing model (Sec. III-C microarchitecture):
  * every PE input port and FU input holds a 2-slot Elastic Buffer with
    **fall-through** forwarding: 0-cycle latency when empty (data/valid
    bypass), full backpressure via the registered ready path. This is the
    only timing consistent with the paper's published IIs — dither's 4-FU
    feedback loop has II=4, i.e. exactly one cycle per FU stage and zero
    per routing hop;
  * PE output ports are combinational (the valid/ready FF was removed);
  * the FU datapath (ALU/comparator/mux) is registered — 1 cycle — into an
    output register + Fork Sender;
  * IMNs/OMNs have damping FIFOs and arbitrate for interleaved banks
    (one beat per bank per cycle, per-bank round-robin).

Each cycle: (A) bank grants fill IMN FIFOs / drain OMN FIFOs; (B) tokens
fall through EB chains to a combinational fixpoint; (C) FUs fire on the
settled state, registering results (visible next cycle).

This module is the *fast* implementation (ISSUE 4): the station graph is
compiled once per mapping into flat structure-of-arrays form — integer
station ids, precomputed successor lists and reverse maps (no ``place``
scans or OMN column searches) — and the per-cycle loops run on plain
Python ints instead of NumPy scalars. The original token-by-token
implementation is preserved verbatim in ``elastic_sim_ref.py`` and
selected by ``STRELA_SIM=reference``; the conformance suite asserts both
produce bit-identical cycles, arrivals, and outputs.

Two further products of the same core:
  * ``simulate_lanes`` — lane-parallel mode: N independent same-mapping
    requests advance through one compiled station graph in a single
    per-cycle sweep (each lane is a suspended cycle-step coroutine), the
    shape ``Engine.flush`` config-class batches present.
  * ``TimingTrace`` — for static-rate DFGs (no Branch/Merge steering) the
    cycle schedule is independent of input *values*; a trace recorded once
    per (mapping, length, layout, bus) replays into a ``SimResult``
    without re-simulating (see ``core/multishot.py`` / ``engine``).

Termination: kernels with static token counts finish when every OMN received
its expected stream. Data-dependent loops (Branch/Merge recirculation, back
edges with ``init=None``) have no static expectation — they finish by *token
exhaustion*: the IMN streams drain and the elastic network quiesces, the
condition on which the real hardware raises its end-of-kernel interrupt.
"""
from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import dfg as D
from repro.core.executor import ALU_FN_I as _ALU_FN, wrap_i as _wrap_i
from repro.core.fabric import FU_INS, FU_OUT, Res
from repro.core.isa import CmpOp
from repro.core.mapper import FU_PORT_OF, Mapping, Signal
from repro.core.streams import BusConfig, StreamSpec

EB_CAP = 2          # 2-slot elastic buffers
FIFO_CAP = 4        # IMN/OMN damping FIFOs
FUOUT_CAP = 2       # FU output register + delayed-valid slot

# station kinds (ints — the SoA arrays index on them)
_IMN, _EB, _FUOUT, _OMN = 0, 1, 2, 3
# branch-leg codes
_LEG = {"out": 0, "t": 1, "f": 2}


@dataclasses.dataclass
class SimResult:
    cycles: int
    outputs: Dict[str, np.ndarray]        # per OUTPUT node, arrival order
    arrival_cycles: Dict[str, List[int]]
    fu_firings: Dict[str, int]
    bank_beats: int
    replayed: bool = False                # True: served from a TimingTrace

    def outputs_per_cycle(self) -> float:
        n = sum(len(v) for v in self.outputs.values())
        return n / self.cycles if self.cycles else 0.0

    def steady_ii(self) -> float:
        """Median inter-arrival gap at the busiest OMN (steady-state II).

        Non-positive gaps are ignored: when lane-parallel batching
        concatenates per-request arrival streams, the cycle counter resets
        at each request boundary and the spurious negative gap there must
        not enter the steady-state statistic.
        """
        gaps: List[int] = []
        for arr in self.arrival_cycles.values():
            if len(arr) > 1:
                d = np.diff(arr)
                gaps.extend(int(x) for x in d[d > 0])
        return float(np.median(gaps)) if gaps else float("inf")


@dataclasses.dataclass
class TimingTrace:
    """Value-independent cycle schedule of one static-rate execution.

    Valid for exactly one (mapping/config-class, stream length, stream
    layout, bank count); the DFG must be static-rate (``DFG.is_static_rate``
    — no Branch/Merge token steering), which makes every quantity below a
    pure function of structure, never of input values.
    """

    length: int
    layout: Tuple[int, ...]
    n_banks: int
    cycles: int
    arrival_cycles: Dict[str, List[int]]
    fu_firings: Dict[str, int]
    bank_beats: int

    @classmethod
    def from_sim(cls, sim: SimResult, length: int, layout: Tuple[int, ...],
                 n_banks: int) -> "TimingTrace":
        return cls(length=length, layout=tuple(layout), n_banks=n_banks,
                   cycles=sim.cycles,
                   arrival_cycles={k: list(v)
                                   for k, v in sim.arrival_cycles.items()},
                   fu_firings=dict(sim.fu_firings),
                   bank_beats=sim.bank_beats)

    def replay(self, outputs: Dict[str, np.ndarray]) -> SimResult:
        """Rebuild a ``SimResult`` from this trace plus executor outputs.

        ``outputs`` supplies the values (the functional executor's streams,
        already in OMN arrival order for static-rate graphs); the trace
        supplies every timing quantity. O(length) NumPy, no simulation.
        """
        outs = {k: np.asarray(v, dtype=np.int32) for k, v in outputs.items()}
        return SimResult(self.cycles, outs,
                         {k: list(v) for k, v in self.arrival_cycles.items()},
                         dict(self.fu_firings), self.bank_beats,
                         replayed=True)


# ---------------------------------------------------------------------------
# station-graph compilation (once per mapping)
# ---------------------------------------------------------------------------

class StationGraph:
    """The mapped netlist compiled to flat arrays for the cycle loops.

    Construction uses reverse maps built once from the ``Mapping`` —
    ``pos -> functional node`` and ``OMN column -> OUTPUT node`` — instead
    of the per-resource linear scans of the original implementation.
    Station ids index parallel lists (kind / cap / successor ids / leg
    codes); FU semantics are precompiled per functional node.
    """

    def __init__(self, m: Mapping):
        self.m = m
        g = m.dfg
        self.g = g

        # reverse maps (ISSUE 4 satellite: no O(n^2) scans)
        pos2node = {pos: n for n, pos in m.place.items()}
        col2out = {col: oname for oname, col in m.omn_of.items()}

        kinds: List[int] = []
        caps: List[int] = []
        legs: List[int] = []
        succs: List[List[int]] = []
        owner: List[Optional[str]] = []

        def new_station(kind: int, cap: int, leg: str = "out",
                        node: Optional[str] = None) -> int:
            kinds.append(kind)
            caps.append(cap)
            legs.append(_LEG[leg])
            succs.append([])
            owner.append(node)
            return len(kinds) - 1

        self.imn_station = {name: new_station(_IMN, FIFO_CAP, node=name)
                            for name in g.inputs}
        self.omn_station = {name: new_station(_OMN, FIFO_CAP, node=name)
                            for name in g.outputs}
        self.fuout_station = {n: new_station(_FUOUT, FUOUT_CAP, node=n)
                              for n in m.place}
        fu_in_station: Dict[Tuple[str, str], int] = {}

        def registered(res: Res) -> bool:
            return res.port.startswith("IN_") or res.port in FU_INS or \
                res.port in ("IMN", "OMN")

        res_station: Dict[Tuple[Signal, Res], int] = {}
        for sig, route in m.routes.items():
            src_node, src_port = sig
            for res, par in route.parent.items():
                if par is None or not registered(res):
                    continue
                if res.port == "OMN":
                    continue    # OMN stations pre-made; wired below
                if res.port in FU_INS:
                    sid = new_station(_EB, EB_CAP, leg=src_port,
                                      node=pos2node[(res.r, res.c)])
                    fu_in_station[(pos2node[(res.r, res.c)], res.port)] = sid
                else:
                    sid = new_station(_EB, EB_CAP, leg=src_port)
                res_station[(sig, res)] = sid
        self.fu_in_station = fu_in_station

        def station_of(sig: Signal, res: Res) -> int:
            """Station for a tree resource: nearest registered
            self-or-ancestor."""
            route = m.routes[sig]
            cur: Optional[Res] = res
            while cur is not None:
                if cur.port == "IMN":
                    return self.imn_station[sig[0]]
                if cur.port == "OMN":
                    return self.omn_station[col2out[cur.c]]
                if (sig, cur) in res_station:
                    return res_station[(sig, cur)]
                if cur.port == FU_OUT and route.parent[cur] is None:
                    return self.fuout_station[sig[0]]
                cur = route.parent[cur]
            raise AssertionError("unrooted resource")

        # wire successor lists
        for sig, route in m.routes.items():
            for res, par in route.parent.items():
                if par is None or not registered(res):
                    continue
                child = (self.omn_station[col2out[res.c]]
                         if res.port == "OMN" else res_station.get((sig, res)))
                parent_sid = station_of(sig, par)
                if child is not None and child not in succs[parent_sid]:
                    if kinds[parent_sid] == _FUOUT:
                        # the Branch leg filter applies at the FU output
                        # register: a child fed *directly* by it (e.g. an
                        # OMN in the producer's own column) must carry the
                        # signal's leg, not the station-creation default
                        legs[child] = _LEG[sig[1]]
                    succs[parent_sid].append(child)

        self.kinds = kinds
        self.caps = caps
        self.legs = legs
        self.succs = succs
        self.owner = owner
        # phase-B scannable stations: those that can act in a settle pass —
        # anything with successors, plus succ-less FUOUTs (token drop). The
        # settle fixpoint is confluent (each station has one producer), so
        # relaxing only the currently-occupied subset of these, worklist-
        # driven, reaches exactly the reference fixpoint.
        self.scannable = [k in (_IMN, _EB, _FUOUT) and (bool(s) or k == _FUOUT)
                          for k, s in zip(kinds, succs)]
        # reverse edges: which scannable stations feed each station (used to
        # re-enable a backpressured producer when its consumer drains)
        self.feeders: List[List[int]] = [[] for _ in kinds]
        for sid, ss in enumerate(succs):
            if self.scannable[sid]:
                for child in ss:
                    self.feeders[child].append(sid)

        # FU semantics, precompiled per functional node: (name, kind code,
        # op fn, const, is_reduction, emit_every, acc_init, a/b/c/out sids)
        self.fu_list: List[Tuple] = []
        for n in m.place:
            nd = g.nodes[n]
            a = fu_in_station.get((n, "FU_A"), -1)
            b = fu_in_station.get((n, "FU_B"), -1)
            c = fu_in_station.get((n, "FU_C"), -1)
            fn = _ALU_FN.get(nd.op) if nd.kind == D.ALU else None
            self.fu_list.append(
                (n, nd.kind, fn, nd.value, nd.is_reduction(), nd.emit_every,
                 nd.acc_init, nd.op, a, b, c, self.fuout_station[n]))

        # initial tokens for loop-carried signals (register init values,
        # Sec. III-C), seeded at the *consumer's* FU input; recirculation
        # edges (init=None) start empty.
        self.init_tokens: List[Tuple[int, int]] = []
        for e in g.back_edges():
            if e.init is None:
                continue
            sid = fu_in_station[(e.dst, FU_PORT_OF[e.dst_port])]
            self.init_tokens.append((sid, _wrap_i(int(e.init))))

        self.data_dependent = g.has_recirculation()


def _expected_counts(g: D.DFG, length: int, data_dependent: bool
                     ) -> Dict[str, int]:
    expected: Dict[str, int] = {}
    for name in g.outputs:
        producer = g.operand(name, "a").src
        nd = g.nodes[producer]
        if data_dependent or g.nodes[name].emit_every == 0:
            expected[name] = -1
        elif nd.is_reduction() and nd.emit_every:
            expected[name] = length // nd.emit_every
        else:
            expected[name] = length
    return expected


# ---------------------------------------------------------------------------
# the cycle engine — one coroutine per request, yielding once per cycle
# ---------------------------------------------------------------------------

def _default_streams(g: D.DFG, length: int, n_banks: int):
    sin = {name: StreamSpec(base=i % n_banks, size=length, stride=n_banks)
           for i, name in enumerate(g.inputs)}
    sout = {name: StreamSpec(base=(len(g.inputs) + i) % n_banks,
                             size=length, stride=n_banks)
            for i, name in enumerate(g.outputs)}
    return sin, sout


def _run_lane(sg: StationGraph, inputs: Dict[str, np.ndarray],
              streams_in: Dict[str, StreamSpec],
              streams_out: Dict[str, StreamSpec],
              bus: BusConfig, max_cycles: int):
    """Generator advancing one request by one cycle per ``next()`` call;
    returns the ``SimResult`` via ``StopIteration.value``.

    The generator form is what makes lane parallelism free: every lane's
    full cycle state lives in this frame's locals, and ``simulate_lanes``
    sweeps ``next()`` across lanes to advance N requests in lockstep
    through one shared ``StationGraph``.
    """
    g = sg.g
    n_banks = bus.n_banks
    length, = {np.asarray(v).shape[0] for v in inputs.values()}

    caps, legs, kinds = sg.caps, sg.legs, sg.kinds
    qs: List[deque] = [deque() for _ in kinds]
    for sid, val in sg.init_tokens:
        qs[sid].append(val)

    # per-lane fanout tables: (child queue, cap, leg code, child sid if the
    # child can itself act in a settle pass, else -1) — queue objects are
    # resolved once so the settle loop does no indexing, and the sid lets a
    # push activate the child for fall-through cascading
    is_fuout = [k == _FUOUT for k in kinds]
    scannable = sg.scannable
    fan: List[List[Tuple[deque, int, int, int]]] = [
        [(qs[s], caps[s], legs[s], s if scannable[s] else -1) for s in ss]
        for ss in sg.succs]
    feeders = sg.feeders
    # occupied scannable stations (seeds each cycle's settle worklist)
    active: set = set()

    # per-run FU state; runtime tuples bind the queue objects directly
    fu_list = sg.fu_list
    accs = {n: _wrap_i(int(acc_init)) for
            (n, _, _, _, red, _, acc_init, *_r) in fu_list if red}
    acc_count = {n: 0 for n in accs}
    fu_firings = {fu[0]: 0 for fu in fu_list}
    for fu in fu_list:
        if fu[1] == D.CMP and fu[7] not in (CmpOp.EQZ, CmpOp.GTZ):
            raise ValueError(f"bad CMP op {fu[7]}")
    is_eqz = {fu[0]: fu[7] == CmpOp.EQZ for fu in fu_list
              if fu[1] == D.CMP}
    fu_rt = [(n, kind, fn, const, red, emit_every, acc_init,
              qs[a] if a >= 0 else None, qs[b] if b >= 0 else None,
              qs[c] if c >= 0 else None, qs[o], caps[o], o)
             for (n, kind, fn, const, red, emit_every, acc_init, _op,
                  a, b, c, o) in fu_list]

    # IMN/OMN progress + precomputed input bank sequences and data
    in_names = list(g.inputs)
    out_names = list(g.outputs)
    n_in = len(in_names)
    imn_sids = [sg.imn_station[n] for n in in_names]
    omn_sids = [sg.omn_station[n] for n in out_names]
    in_banks = [[streams_in[n].bank(k, n_banks) for k in range(length)]
                for n in in_names]
    data_in = [[int(x) for x in np.asarray(inputs[n])] for n in in_names]
    out_spec = [streams_out[n] for n in out_names]
    imn_sent = [0] * n_in
    omn_vals: List[List[int]] = [[] for _ in out_names]
    omn_cycs: List[List[int]] = [[] for _ in out_names]
    expected = _expected_counts(g, length, sg.data_dependent)
    bank_beats = 0
    n_io = n_in + len(out_names)
    pending_in = n_in * length

    # per-bank round-robin arbiter state (mirrors streams.BankArbiter)
    last_grant: Dict[int, int] = {}

    ALU, CMP, MUX, BRANCH, MERGE = D.ALU, D.CMP, D.MUX, D.BRANCH, D.MERGE

    cycle = 0
    while cycle < max_cycles:
        cycle += 1
        progress = False

        # --- phase A: bank arbitration (IMN fetches + OMN stores) ---
        reqs: List[int] = []
        any_req = False
        if pending_in:
            for i in range(n_in):
                sid = imn_sids[i]
                if imn_sent[i] < length and len(qs[sid]) < caps[sid]:
                    reqs.append(in_banks[i][imn_sent[i]])
                    any_req = True
                else:
                    reqs.append(-1)
        else:
            reqs.extend([-1] * n_in)
        for j, sid in enumerate(omn_sids):
            if qs[sid]:
                reqs.append(out_spec[j].bank(len(omn_vals[j]), n_banks))
                any_req = True
            else:
                reqs.append(-1)
        if any_req:
            by_bank: Dict[int, List[int]] = {}
            for i, bk in enumerate(reqs):
                if bk >= 0:
                    by_bank.setdefault(bk, []).append(i)
            for bk, nodes in by_bank.items():
                start = last_grant.get(bk, -1)
                pick = (nodes[0] if len(nodes) == 1 else
                        min(nodes, key=lambda i: ((i - start - 1) % n_io)))
                last_grant[bk] = pick
                bank_beats += 1
                progress = True
                if pick < n_in:
                    sid = imn_sids[pick]
                    qs[sid].append(data_in[pick][imn_sent[pick]])
                    imn_sent[pick] += 1
                    pending_in -= 1
                    if scannable[sid]:
                        active.add(sid)
                else:
                    j = pick - n_in
                    omn_vals[j].append(qs[omn_sids[j]].popleft())
                    omn_cycs[j].append(cycle)

        # --- phase B: combinational settle (fall-through EB chains) ---
        # worklist relaxation: the fixpoint is confluent (each station has
        # one producer), so event-driven scheduling lands on exactly the
        # reference scan's final state. A move re-enqueues the mover (more
        # tokens may fall through), its now-occupied children, and its
        # feeders (their backpressure just eased).
        if active:
            work = sorted(active)
            wset = set(work)
            qi = 0
            while qi < len(work):
                sid = work[qi]
                qi += 1
                wset.discard(sid)
                q = qs[sid]
                if not q:
                    active.discard(sid)
                    continue
                ff = fan[sid]
                if is_fuout[sid]:
                    if not ff:
                        # empty Fork-Sender mask: the FU result is
                        # deliberately discarded (find2min drops its loser
                        # this way, Sec. VI-B) — never backpressure
                        q.clear()
                        active.discard(sid)
                        progress = True
                        continue
                    value, leg = q[0]
                    ok = True
                    for cq, cap, cleg, _cs in ff:
                        if cleg == leg and len(cq) >= cap:
                            ok = False
                            break
                    if not ok:
                        continue
                    q.popleft()
                    for cq, cap, cleg, cs in ff:
                        if cleg == leg:
                            cq.append(value)
                            if cs >= 0:
                                active.add(cs)
                                if cs not in wset:
                                    work.append(cs)
                                    wset.add(cs)
                else:
                    ok = True
                    for cq, cap, _cl, _cs in ff:
                        if len(cq) >= cap:
                            ok = False
                            break
                    if not ok:
                        continue
                    value = q.popleft()
                    for cq, cap, _cl, cs in ff:
                        cq.append(value)
                        if cs >= 0:
                            active.add(cs)
                            if cs not in wset:
                                work.append(cs)
                                wset.add(cs)
                progress = True
                if q:
                    if sid not in wset:
                        work.append(sid)
                        wset.add(sid)
                else:
                    active.discard(sid)
                for p in feeders[sid]:
                    if p not in wset and qs[p]:
                        work.append(p)
                        wset.add(p)

        # --- phase C: FU firings on the settled state (registered) ---
        fires: List[Tuple] = []
        for fu in fu_rt:
            kind = fu[1]
            aq, bq, cq, oq = fu[7], fu[8], fu[9], fu[10]
            if kind == MERGE:
                if not (aq or bq):
                    continue      # priority-a confluence (Sec. III-C Merge)
            else:
                if aq is not None and not aq:
                    continue
                if bq is not None and not bq:
                    continue
                if cq is not None and not cq:
                    continue
            if fu[4]:
                # reduction: non-emitting firings don't need downstream space
                count = acc_count[fu[0]] + 1
                emit_every = fu[5]
                will_emit = (emit_every == 1 or
                             (emit_every == 0 and count == length) or
                             (emit_every > 1 and count % emit_every == 0))
                if will_emit and len(oq) >= fu[11]:
                    continue
            elif len(oq) >= fu[11]:
                continue
            fires.append(fu)

        for (n, kind, fn, const, red, emit_every, acc_init,
             aq, bq, cq, out_q, _ocap, out_sid) in fires:
            fu_firings[n] += 1
            progress = True
            active.add(out_sid)      # FUOUTs are always settle-scannable
            if kind == MERGE:
                src = aq if aq else bq
                out_q.append((src.popleft(), 0))
                continue
            a = aq.popleft() if aq is not None else None
            b = bq.popleft() if bq is not None else None
            c = cq.popleft() if cq is not None else None
            if kind == ALU:
                if red:
                    x = const if const is not None else a
                    acc = fn(accs[n], x)
                    count = acc_count[n] = acc_count[n] + 1
                    if emit_every == 1 or \
                            (emit_every == 0 and count == length) or \
                            (emit_every > 1 and count % emit_every == 0):
                        out_q.append((acc, 0))
                        if emit_every > 1:
                            acc = _wrap_i(int(acc_init))
                    accs[n] = acc
                else:
                    out_q.append((fn(a, b if b is not None else const), 0))
            elif kind == CMP:
                av = a
                if b is not None:
                    av = _wrap_i(a - b)
                elif const is not None:
                    av = _wrap_i(a - const)
                hit = (av == 0) if is_eqz[n] else (av > 0)
                out_q.append((1 if hit else 0, 0))
            elif kind == MUX:
                bb = b if b is not None else const
                out_q.append((a if c != 0 else bb, 0))
            elif kind == BRANCH:
                out_q.append((a, 1 if c != 0 else 2))

        if not progress:
            # quiescent: either done (only loop-carried leftovers remain in
            # their EBs, as in real hardware) or a true deadlock.
            cycle -= 1
            drained = all(s >= length for s in imn_sent)
            met = all(expected[name] < 0 or len(omn_vals[j]) >= expected[name]
                      for j, name in enumerate(out_names))
            if drained and met:
                break
            raise RuntimeError(
                f"deadlock in kernel {g.name} at cycle {cycle}: "
                f"imn_sent={dict(zip(in_names, imn_sent))}, received="
                f"{ {k: len(v) for k, v in zip(out_names, omn_vals)} }, "
                f"expected={expected}")
        yield cycle
    else:
        raise RuntimeError(f"simulation did not converge in {max_cycles} "
                           f"cycles (kernel {g.name}; likely routing "
                           f"deadlock)")

    outputs = {name: np.array(omn_vals[j], dtype=np.int32)
               for j, name in enumerate(out_names)}
    arrivals = {name: omn_cycs[j] for j, name in enumerate(out_names)}
    # last-value OMNs (stride 0): every token overwrote one word
    for name in out_names:
        if g.nodes[name].emit_every == 0 and len(outputs[name]):
            outputs[name] = outputs[name][-1:]
    return SimResult(cycle, outputs, arrivals, fu_firings, bank_beats)


def _drive(gen) -> SimResult:
    try:
        while True:
            next(gen)
    except StopIteration as stop:
        return stop.value


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def simulate(m: Mapping, inputs: Dict[str, np.ndarray],
             streams_in: Optional[Dict[str, StreamSpec]] = None,
             streams_out: Optional[Dict[str, StreamSpec]] = None,
             bus: Optional[BusConfig] = None,
             max_cycles: int = 2_000_000) -> SimResult:
    """Cycle-accurate simulation of one request on the mapped netlist.

    ``STRELA_SIM=reference`` in the environment selects the original
    token-by-token implementation (``elastic_sim_ref``) for differential
    checking; the default fast core is bit-identical to it.
    """
    if os.environ.get("STRELA_SIM", "") == "reference":
        from repro.core import elastic_sim_ref
        return elastic_sim_ref.simulate_reference(
            m, inputs, streams_in=streams_in, streams_out=streams_out,
            bus=bus, max_cycles=max_cycles)
    bus = bus or BusConfig()
    if streams_in is None or streams_out is None:
        length, = {np.asarray(v).shape[0] for v in inputs.values()}
        din, dout = _default_streams(m.dfg, length, bus.n_banks)
        streams_in = streams_in or din
        streams_out = streams_out or dout
    with obs.span("sim.cycle_sim", kernel=m.dfg.name) as sp:
        res = _drive(_run_lane(_station_graph(m), inputs, streams_in,
                               streams_out, bus, max_cycles))
        sp.set(cycles=res.cycles)
        return res


def _station_graph(m: Mapping) -> StationGraph:
    """Per-mapping memo: routes are immutable once mapped, so the compiled
    station structure (not the per-run queues) is computed once."""
    sg = m.__dict__.get("_station_graph")
    if sg is None:
        sg = StationGraph(m)
        m.__dict__["_station_graph"] = sg
    return sg


def simulate_lanes(m: Mapping, inputs_list: List[Dict[str, np.ndarray]],
                   streams_in: Optional[Dict[str, StreamSpec]] = None,
                   streams_out: Optional[Dict[str, StreamSpec]] = None,
                   bus: Optional[BusConfig] = None,
                   max_cycles: int = 2_000_000) -> List[SimResult]:
    """Lane-parallel simulation: N independent same-mapping requests.

    The station graph is compiled once and every request becomes a lane —
    a suspended cycle-step coroutine over the shared structure. One sweep
    of the outer loop advances all live lanes by one cycle; lanes retire
    individually as they quiesce. Results are bit-identical to N separate
    ``simulate`` calls (asserted by tests/test_timing_trace.py).
    """
    bus = bus or BusConfig()
    obs.inc("sim.lane_sweeps")
    sg = _station_graph(m)
    lanes = []
    for inputs in inputs_list:
        sin, sout = streams_in, streams_out
        if sin is None or sout is None:
            length, = {np.asarray(v).shape[0] for v in inputs.values()}
            din, dout = _default_streams(m.dfg, length, bus.n_banks)
            sin = sin or din
            sout = sout or dout
        lanes.append(_run_lane(sg, inputs, sin, sout, bus, max_cycles))
    results: List[Optional[SimResult]] = [None] * len(lanes)
    live = list(range(len(lanes)))
    while live:
        nxt = []
        for i in live:
            try:
                next(lanes[i])
                nxt.append(i)
            except StopIteration as stop:
                results[i] = stop.value
        live = nxt
    return results
