"""Cost-driven optimizing place & route: simulated annealing over mappings.

The greedy mapper (``core.mapper``) returns the *first* placement that
routes; nothing pulls it toward the two costs that actually price a
mapping in this system:

  * **steady-state II / total cycles** — a placement that starves a join
    (reconvergent operand paths whose FU-stage skew exceeds the elastic-
    buffer slack of the shallow path) inflates the initiation interval,
    and every inflated cycle multiplies by the stream length;
  * **config footprint** — every PE carrying route-through traffic costs
    five configuration words, and multi-shot traffic pays that fetch on
    every reconfiguration (Sec. V-B) — the rearm cost the engine's
    config-class batching exists to amortize.

This module anneals over the mapping state (PE placement + IMN/OMN column
binding), re-routing each move with the shared negotiated router and
scoring it with a cheap congestion/criticality model; whenever the cheap
model finds a new best state ("accepted plateau"), the candidate is
*validated* by the fast elastic simulator on short deterministic probe
streams — cheap enough post-PR 4 to sit in the inner loop for 4x4–8x8
fabrics. A candidate is only ever adopted when, on **every** probe, it is

  * value-bit-exact with the greedy baseline, and
  * never cycle-worse,

so ``anneal_map`` is a strict refinement: the greedy mapping itself stays
the answer whenever nothing provably cheaper is found. Selection among
admissible candidates minimizes ``sim_cycles + w_config *
config_cycles`` — the weighted objective multi-shot plans care about.

Observability (``STRELA_OBS=1``): the whole search runs inside a
``pnr.anneal`` span, with ``pnr.anneal.moves_tried`` / ``moves_accepted``
/ ``temp_steps`` / ``validations`` counters.
"""
from __future__ import annotations

import math
import os
import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import dfg as D
from repro.core.fabric import FU_INS, Fabric
from repro.core.isa import config_cycles
from repro.core.mapper import (Mapping, MappingError, Signal, _depths,
                               default_seed, map_dfg, route_signals)

# probe stream lengths used for simulation-validated plateaus; two lengths
# make "never cycle-worse" evidence structural (fill + slope), not a
# single-length coincidence
PROBE_LENGTHS = (24, 48)

# a candidate validation simulation that exceeds this budget is simply
# rejected (runaway irregular-loop mappings must not stall compilation)
_VALIDATE_MAX_CYCLES = 200_000


def default_moves() -> int:
    """Anneal move budget: ``STRELA_ANNEAL_MOVES`` in the env, else 240."""
    return int(os.environ.get("STRELA_ANNEAL_MOVES", "240"))


# ---------------------------------------------------------------------------
# cheap incremental cost model (guides the anneal; sim validates plateaus)
# ---------------------------------------------------------------------------

def _route_ebs(routes: Dict[Signal, "object"], edge_dest, e) -> int:
    """Registered elastic-buffer stations on one edge's claimed path.

    Each IN_* port and FU input along the route is a 2-slot EB; the count
    prices the *buffering slack* of the path (fall-through EBs add no
    latency, but their capacity is what absorbs reconvergence skew)."""
    sig = (e.src, e.src_port)
    route = routes.get(sig)
    if route is None:
        return 0
    dst = edge_dest.get((e.src, e.src_port, e.dst, e.dst_port))
    if dst is None or dst not in route.parent:
        return 0
    n = 0
    for res in route.path_to(dst):
        if res.port.startswith("IN_") or res.port in FU_INS:
            n += 1
    return n


def mapping_cost(g: D.DFG, fabric: Fabric, place, routes, edge_dest,
                 depth: Dict[str, int], w_config: float = 1.0,
                 w_skew: float = 48.0, w_len: float = 0.05
                 ) -> Tuple[float, int]:
    """(cheap cost, active-PE count) of one routed mapping state.

    Three terms, mirroring the objective the validator measures for real:

      * ``config_cycles(active PEs)`` — the reconfiguration footprint
        (functional + route-through PEs, exactly ``Mapping.active_pes``);
      * a **criticality/skew** penalty: for every join, operands arriving
        from different pipeline depths must be absorbed by the EB slack of
        the shallow path — any deficit backpressures the shared fork
        upstream and inflates II, so deficits dominate the cost;
      * total claimed route hops — a light congestion tiebreaker pulling
        routes (and therefore active PEs and fork pressure) short.
    """
    active = set(place.values())
    hops = 0
    for route in routes.values():
        for res in route.parent:
            if 0 <= res.r < fabric.rows and 0 <= res.c < fabric.cols:
                active.add((res.r, res.c))
            hops += 1
    cost = w_config * config_cycles(len(active)) + w_len * hops

    skew = 0
    for n in g.nodes:
        ops = [e for e in g.in_edges(n)
               if not e.back and g.nodes[e.src].kind != D.CONST]
        if len(ops) < 2:
            continue
        arr = [(depth.get(e.src, 0), _route_ebs(routes, edge_dest, e))
               for e in ops]
        dmax = max(d for d, _ in arr)
        for d, ebs in arr:
            deficit = (dmax - d) - 2 * ebs        # 2 slots per EB station
            if deficit > 0:
                skew += deficit
    return cost + w_skew * skew, len(active)


# ---------------------------------------------------------------------------
# simulation-validated plateaus
# ---------------------------------------------------------------------------

def probe_inputs(g: D.DFG, seed: int,
                 lengths: Tuple[int, ...] = PROBE_LENGTHS
                 ) -> List[Dict[str, np.ndarray]]:
    """Deterministic probe streams (one dict per probe length).

    Recirculation graphs draw small non-negative values so data-dependent
    trip counts stay bounded — the same convention the benchmarks use."""
    rng = np.random.default_rng((seed & 0xFFFFFFFF) ^ 0x5EED)
    lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
    return [{n: rng.integers(lo, hi, ln).astype(np.int32) for n in g.inputs}
            for ln in lengths]


def _probe_sims(m: Mapping, probes: List[Dict[str, np.ndarray]]):
    """Fast-sim every probe; None if any probe deadlocks/diverges."""
    from repro.core.elastic_sim import simulate
    out = []
    for ins in probes:
        try:
            out.append(simulate(m, ins, max_cycles=_VALIDATE_MAX_CYCLES))
        except RuntimeError:
            return None
    return out


def _admissible(cand_sims, base_sims) -> bool:
    """Never cycle-worse AND value-bit-exact vs the baseline, per probe."""
    for cs, bs in zip(cand_sims, base_sims):
        if cs.cycles > bs.cycles:
            return False
        if set(cs.outputs) != set(bs.outputs):
            return False
        for k, v in bs.outputs.items():
            if not np.array_equal(cs.outputs[k], v):
                return False
    return True


# ---------------------------------------------------------------------------
# the annealer
# ---------------------------------------------------------------------------

def _propose(rng: random.Random, fabric: Fabric, place, imn_of, omn_of,
             funcs: List[str]):
    """One mutated (place, imn_of, omn_of) copy. Move set:

      * relocate — move one functional node to a free PE;
      * swap     — exchange the PEs of two functional nodes;
      * imn/omn  — rebind one stream to another memory-node column
                   (swapping with the current holder when occupied).
    """
    place, imn_of, omn_of = dict(place), dict(imn_of), dict(omn_of)
    r = rng.random()
    if r < 0.45 or len(funcs) < 2:
        n = funcs[rng.randrange(len(funcs))]
        used = set(place.values())
        free = [(rr, cc) for rr in range(fabric.rows)
                for cc in range(fabric.cols) if (rr, cc) not in used]
        if not free:
            return None
        place[n] = free[rng.randrange(len(free))]
    elif r < 0.80:
        a, b = rng.sample(funcs, 2)
        place[a], place[b] = place[b], place[a]
    elif r < 0.90 and imn_of:
        names = sorted(imn_of)
        n = names[rng.randrange(len(names))]
        col = rng.randrange(fabric.n_imns)
        holder = next((k for k, v in imn_of.items() if v == col), None)
        if holder is not None:
            imn_of[holder] = imn_of[n]
        imn_of[n] = col
    elif omn_of:
        names = sorted(omn_of)
        n = names[rng.randrange(len(names))]
        col = rng.randrange(fabric.n_omns)
        holder = next((k for k, v in omn_of.items() if v == col), None)
        if holder is not None:
            omn_of[holder] = omn_of[n]
        omn_of[n] = col
    else:
        return None
    return place, imn_of, omn_of


def anneal_map(g: D.DFG, fabric: Optional[Fabric] = None,
               seed: Optional[int] = None,
               baseline: Optional[Mapping] = None,
               moves: Optional[int] = None,
               w_config: float = 1.0,
               t0: float = 24.0, t1: float = 0.4,
               n_steps: int = 24,
               max_validations: int = 24,
               extra_probes: Optional[List[Dict[str, np.ndarray]]] = None,
               restarts: int = 400) -> Mapping:
    """Anneal a mapping of ``g``; returns a mapping that is never
    cycle-worse than — and value-bit-exact with — the greedy ``baseline``
    (computed here when not supplied) on every validation probe.

    ``extra_probes``: additional input-stream dicts validated alongside
    the default probes — profile-guided clients (the mapper gate, the
    benchmarks) pass their real workload so the never-worse guarantee
    holds on exactly the streams they will measure.
    """
    fabric = fabric or Fabric()
    seed = default_seed() if seed is None else seed
    moves = default_moves() if moves is None else moves
    if baseline is None:
        baseline = map_dfg(g, fabric, seed=seed, restarts=restarts,
                           optimize="greedy")

    probes = probe_inputs(g, seed) + list(extra_probes or [])
    base_sims = _probe_sims(baseline, probes)
    depth = _depths(g)
    funcs = sorted(baseline.place)

    with obs.span("pnr.anneal", kernel=g.name, moves=moves) as sp:
        if base_sims is None:
            # the greedy netlist itself deadlocks on the probes (a liveness
            # limit of 2-slot EBs on some corpus graphs): stay semantics-
            # identical to greedy rather than silently "fixing" behavior
            sp.set(outcome="baseline_deadlock")
            return baseline

        base_cycles = sum(s.cycles for s in base_sims)
        base_score = base_cycles + w_config * baseline.config_cycles()
        best_score, best_mapping = base_score, baseline

        cur = (dict(baseline.place), dict(baseline.imn_of),
               dict(baseline.omn_of))
        cur_routes, cur_dest = baseline.routes, baseline.edge_dest
        cur_cost, _ = mapping_cost(g, fabric, cur[0], cur_routes, cur_dest,
                                   depth, w_config=w_config)
        best_cost = cur_cost

        rng = random.Random((seed * 1_000_003) ^ 0xA11EA1ED)
        tried = accepted = validations = improved = 0
        moves_per_step = max(1, moves // n_steps)
        for step in range(n_steps):
            frac = step / max(n_steps - 1, 1)
            temp = t0 * (t1 / t0) ** frac
            obs.inc("pnr.anneal.temp_steps")
            for _ in range(moves_per_step):
                tried += 1
                prop = _propose(rng, fabric, *cur, funcs)
                if prop is None:
                    continue
                try:
                    routes2, dest2 = route_signals(
                        g, fabric, prop[0], prop[1], prop[2],
                        random.Random(rng.getrandbits(32)), depth=depth)
                except MappingError:
                    continue
                cost2, _ = mapping_cost(g, fabric, prop[0], routes2, dest2,
                                        depth, w_config=w_config)
                d = cost2 - cur_cost
                if d > 0 and rng.random() >= math.exp(-d / max(temp, 1e-9)):
                    continue
                cur, cur_routes, cur_dest, cur_cost = \
                    prop, routes2, dest2, cost2
                accepted += 1
                if cost2 >= best_cost or validations >= max_validations:
                    continue
                # accepted plateau: the cheap model claims a new best —
                # validate with the real simulator before believing it
                best_cost = cost2
                validations += 1
                obs.inc("pnr.anneal.validations")
                cand = Mapping(g, fabric, dict(prop[0]), dict(prop[1]),
                               dict(prop[2]), routes2, dest2)
                cand_sims = _probe_sims(cand, probes)
                if cand_sims is None or not _admissible(cand_sims,
                                                        base_sims):
                    continue
                score = sum(s.cycles for s in cand_sims) \
                    + w_config * cand.config_cycles()
                if score < best_score:
                    best_score, best_mapping = score, cand
                    improved += 1
        obs.inc("pnr.anneal.moves_tried", tried)
        obs.inc("pnr.anneal.moves_accepted", accepted)
        sp.set(tried=tried, accepted=accepted, validations=validations,
               adopted=best_mapping is not baseline,
               score_delta=base_score - best_score)
    return best_mapping
