"""Frozen 'manual' mappings for the paper's benchmark kernels (Fig. 7).

The paper maps kernels by hand; we freeze mapper-discovered placements here
so benchmark and test runs are deterministic and fast (the search that found
them is reproducible via ``map_dfg(g, restarts=400)``). Active-PE counts are
in the same range as the configuration-cycle data of Table I (fft uses the
whole 4x4 fabric + all 8 memory nodes, exactly as described for Fig. 7b).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core import kernels_lib as K
from repro.core.dfg import DFG, unroll, unroll_chained
from repro.core.mapper import Mapping, map_dfg

# node -> (row, col) placements; imn/omn stream bindings
_PLACEMENTS: Dict[str, dict] = {
    "fft": {
        "place": {"t1": (0, 2), "t2": (0, 3), "t3": (0, 1), "t4": (1, 3),
                  "ti": (1, 1), "oi0": (2, 1), "oi1": (2, 2), "tr": (1, 2),
                  "or0": (2, 0), "or1": (3, 2)},
        "imn": {"ar": 0, "ai": 1, "br": 2, "bi": 3},
        "omn": {"out_or0": 0, "out_oi0": 1, "out_or1": 2, "out_oi1": 3},
    },
    "relu_x3": {
        "place": {"c@0": (0, 1), "o@0": (1, 0), "c@1": (0, 2), "o@1": (1, 1),
                  "c@2": (0, 3), "o@2": (1, 2)},
        "imn": {"x@0": 0, "x@1": 1, "x@2": 2},
        "omn": {"out@0": 0, "out@1": 1, "out@2": 2},
    },
    "dither_c2": {
        "place": {"v@0": (0, 0), "c@0": (1, 0), "o@0": (2, 0), "e@0": (3, 0),
                  "v@1": (3, 1), "c@1": (3, 2), "o@1": (2, 2), "e@1": (2, 1)},
        "imn": {"x@0": 0, "x@1": 1},
        "omn": {"out@0": 0, "out@1": 1},
    },
    "find2min": {
        "place": {"c1": (0, 0), "cand": (1, 0), "c2": (2, 0), "idx": (0, 1),
                  "i1": (1, 1), "iold": (1, 2), "i2": (3, 2), "m1": (2, 1),
                  "m2": (3, 0)},
        "imn": {"x": 0},
        "omn": {"out_m1": 1, "out_i1": 3, "out_m2": 0, "out_i2": 2},
    },
    "find2min_brmg": {
        "place": {"c1": (0, 1), "brm": (1, 1), "brx": (1, 0), "cand": (2, 0),
                  "c2": (3, 0), "brc": (3, 1), "brm2": (3, 2), "m1": (2, 1),
                  "m2": (2, 2)},
        "imn": {"x": 0},
        "omn": {"out_m1": 0, "out_m2": 1},
    },
    "relu": {
        "place": {"c": (0, 0), "o": (1, 0)},
        "imn": {"x": 0}, "omn": {"out": 0},
    },
    "dither": {
        "place": {"v": (0, 0), "c": (1, 0), "o": (2, 0), "e": (3, 0)},
        "imn": {"x": 0}, "omn": {"out": 0},
    },
}

_BUILDERS = {
    "fft": K.fft_butterfly,
    "relu": K.relu,
    "relu_x3": lambda: unroll(K.relu(), 3),
    "dither": K.dither,
    "dither_c2": lambda: unroll_chained(K.dither(), 2),
    "find2min": K.find2min,
    "find2min_brmg": K.find2min_brmg,
}


def paper_dfg(name: str) -> DFG:
    return _BUILDERS[name]()


def paper_mapping(name: str) -> Mapping:
    """Deterministically rebuild the frozen mapping for a paper kernel."""
    g = paper_dfg(name)
    info = _PLACEMENTS[name]
    return map_dfg(g, hints=dict(info["place"]), imn_hint=dict(info["imn"]),
                   omn_hint=dict(info["omn"]), restarts=8)


PAPER_KERNELS = tuple(_PLACEMENTS)
