"""Multi-shot kernel planner & runner (mapping strategy 3, Sec. IV-B).

A multi-shot application is a sequence of *shots*: each shot is one fabric
execution of a small kernel, with the CPU re-arming stream parameters in
between (and reconfiguring PEs when the kernel — or a folded constant —
changes). The runners below implement the paper's Table II benchmarks:

  mm      — three dot-products per shot (Fig. 7c), rows x col-triples
  conv2d  — 3 shots, one per 3x3 filter row, partial sums memory-resident
  gemm    — mm shots + axpby epilogue (alpha*AB + beta*C)
  gemver  — fused outer-product row shots (consts re-configured per row),
            then A^T y and A x matvec shots with scale/add epilogues
  gesummv — dual-MAC row shots sharing the x stream + axpby epilogue
  2mm/3mm — chained mm phases

Numeric results come from the functional executor per shot (validated
against NumPy in the tests). Timing: every distinct (kernel, length,
stream-layout) class is simulated once cycle-accurately on its real
StreamSpecs (bank strides matter: mm's B-columns hammer single banks,
giving Table II's ~1.9 cycles/element), and identical shots reuse it.

Re-arm cost model (Sec. V-B preamble; fitted to Table II's mm16/mm64):
interrupt sync + MMIO stream writes + partial config-word streaming.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.elastic_sim import SimResult, TimingTrace, simulate
from repro.core.executor import execute
from repro.core.fabric import Fabric
from repro.core.mapper import Mapping, map_dfg
from repro.core.streams import BusConfig, StreamSpec

I32 = np.int32

SYNC_CYCLES = 16
CYCLES_PER_STREAM_WRITE = 14
CYCLES_PER_CONFIG_WORD = 5


def rearm_cycles(streams_changed: int, pe_config_words: int = 0) -> int:
    c = SYNC_CYCLES + CYCLES_PER_STREAM_WRITE * streams_changed
    if pe_config_words:
        c += CYCLES_PER_CONFIG_WORD * pe_config_words + 4
    return c


@dataclasses.dataclass
class Tally:
    """Accumulated offload cost of a multi-shot run."""

    config: int = 0
    rearm: int = 0
    exec: int = 0
    ops: int = 0           # measured FU firings
    shots: int = 0

    @property
    def total(self) -> int:
        return self.config + self.rearm + self.exec

    @property
    def duty(self) -> float:
        return self.exec / max(self.total, 1)

    def merge(self, other: "Tally") -> "Tally":
        return Tally(self.config + other.config, self.rearm + other.rearm,
                     self.exec + other.exec, self.ops + other.ops,
                     self.shots + other.shots)


class ShotRunner:
    """Executes shots functionally and accounts cycle costs, memoizing one
    cycle-level simulation per (kernel-name, length, layout) class.

    ``fabric`` selects the target geometry (rows/cols/IMN/OMN counts) for
    every mapping the runner performs itself; pre-seeded mappings keep the
    geometry they were produced with. ``bus`` sets the interleaved-bank
    count used for shot stream layouts.

    ``value_fn`` selects the *value substrate*: the callable producing a
    shot's numeric outputs (default: the functional executor). The pallas
    backend passes its fused-kernel dispatcher here, so multi-shot plans
    chain per-shot pallas kernels through the same IMN/OMN buffer handoff
    — while cycle accounting keeps flowing through the memoized timing
    simulation (PR 4's timing/value decoupling, now across backends).
    """

    def __init__(self, with_timing: bool = True,
                 fabric: Optional[Fabric] = None,
                 bus: Optional[BusConfig] = None,
                 value_fn: Optional[Callable] = None):
        self.with_timing = with_timing
        self.fabric = fabric or Fabric()
        self.bus = bus or BusConfig()
        self.value_fn = value_fn or execute
        self.tally = Tally()
        self._mappings: Dict[str, Mapping] = {}
        self._sims: Dict[Tuple, SimResult] = {}
        # timing traces: (cfg key, length, layout, n_banks) -> TimingTrace;
        # seeded from artifacts, recorded after fresh static-rate sims
        self._traces: Dict[Tuple, TimingTrace] = {}
        self._fresh_traces: Dict[Tuple, TimingTrace] = {}
        self._current_kernel: Optional[str] = None

    def mapping(self, key: str, g: DFG) -> Mapping:
        if key not in self._mappings:
            self._mappings[key] = map_dfg(g, self.fabric, restarts=300)
        return self._mappings[key]

    @property
    def current_config_class(self) -> Optional[str]:
        """Config class the fabric currently holds (None = unconfigured)."""
        return self._current_kernel

    def invalidate_config(self) -> None:
        """Forget the fabric's configuration state. Models independent
        per-request dispatch: between isolated requests the fabric cannot be
        assumed to still hold the caller's configuration, so the next shot
        pays a full configuration fetch."""
        self._current_kernel = None

    def seed_mapping(self, key: str, m: Mapping) -> None:
        """Pre-register a place-and-route result for a config class (e.g.
        computed at compile time by the frontend partitioner) so runs reuse
        it instead of re-mapping."""
        self._mappings.setdefault(key, m)

    def seed_trace(self, key: str, length: int, layout: Tuple[int, ...],
                   trace: TimingTrace) -> None:
        """Pre-register a recorded timing trace (e.g. carried inside a
        ``CompiledArtifact``) so a static-rate shot replays it instead of
        re-simulating — the repeat-dispatch path becomes O(length) NumPy."""
        self._traces.setdefault((key, length, tuple(layout), trace.n_banks),
                                trace)

    def fresh_traces(self) -> Dict[Tuple, TimingTrace]:
        """Traces recorded by this runner since the last harvest; the
        engine persists them back into the owning artifact. Clears the
        fresh set."""
        out, self._fresh_traces = self._fresh_traces, {}
        return out

    def run_shot(self, key: str, g: DFG,
                 inputs: Dict[str, np.ndarray],
                 streams_changed: int,
                 pe_config_words: int = 0,
                 layout: Tuple[int, ...] = (),
                 config_class: Optional[str] = None,
                 outs: Optional[Dict[str, np.ndarray]] = None
                 ) -> Dict[str, np.ndarray]:
        """config_class: kernels sharing a configuration family (e.g. gemver
        rows differ only in folded constants) avoid full config re-fetch.

        ``outs``: pre-computed shot values (e.g. one lane of a batched
        pallas grid) — cycle accounting still runs, value computation is
        skipped."""
        with obs.span("shot", key=key,
                      config_class=config_class or key) as sp:
            if outs is None:
                with obs.span("shot.values", key=key):
                    outs = self.value_fn(g, inputs)
            if not self.with_timing:
                return outs
            cfg_key = config_class or key
            m = self.mapping(cfg_key, g)
            if self._current_kernel != cfg_key:
                self.tally.config += m.config_cycles()
                self._current_kernel = cfg_key
                obs.inc("shot.config_fetches")
            (length,) = {v.shape[0] for v in inputs.values()}
            sig = (cfg_key, length, layout)
            if sig not in self._sims:
                tkey = (cfg_key, length, tuple(layout), self.bus.n_banks)
                trace = self._traces.get(tkey)
                if trace is not None and g.is_static_rate():
                    # timing/value decoupling: the cycle schedule of a
                    # static-rate DFG is value-independent, so replay the
                    # recorded trace and take the values from the functional
                    # executor — no simulation on the repeat-dispatch path
                    with obs.span("shot.trace_replay", key=cfg_key):
                        self._sims[sig] = trace.replay(outs)
                    obs.inc("shot.trace_replays")
                else:
                    sin, sout = _shot_streams(g, length, layout,
                                              self.bus.n_banks)
                    with obs.span("shot.simulate", key=cfg_key,
                                  length=length):
                        sim = simulate(m, inputs, streams_in=sin,
                                       streams_out=sout, bus=self.bus)
                    obs.inc("shot.fresh_sims")
                    self._sims[sig] = sim
                    if g.is_static_rate():
                        trace = TimingTrace.from_sim(sim, length,
                                                     tuple(layout),
                                                     self.bus.n_banks)
                        self._traces[tkey] = trace
                        self._fresh_traces[tkey] = trace
                        obs.inc("shot.traces_recorded")
            else:
                obs.inc("shot.sim_memo_hits")
            sim = self._sims[sig]
            self.tally.exec += sim.cycles
            self.tally.rearm += rearm_cycles(streams_changed,
                                             pe_config_words)
            self.tally.ops += sum(sim.fu_firings.values())
            self.tally.shots += 1
            sp.set(cycles=sim.cycles, length=length)
            return outs

    def rep_sims(self) -> Dict[Tuple, SimResult]:
        return dict(self._sims)

    def mappings(self) -> Dict[str, Mapping]:
        return dict(self._mappings)


def _shot_streams(g: DFG, length: int, layout: Tuple[int, ...],
                  n_banks: int = 4):
    """StreamSpecs matching the shot's real bank behaviour. ``layout`` holds
    per-(inputs+outputs) stride residues mod the bank count; residue 0 =
    single-bank stream (stride multiple of the bank count, e.g. a matrix
    column)."""
    names = list(g.inputs) + list(g.outputs)
    if not layout:
        layout = tuple([1] * len(names))
    sin, sout = {}, {}
    for i, name in enumerate(names):
        res = layout[i] if i < len(layout) else 1
        stride = n_banks if res == 0 else res
        spec = StreamSpec(base=i % n_banks, size=length, stride=stride)
        (sin if name in g.inputs else sout)[name] = spec
    return sin, sout


# ---------------------------------------------------------------------------
# Table II benchmark runners
# ---------------------------------------------------------------------------

def run_mm(A: np.ndarray, B: np.ndarray, out: np.ndarray,
           runner: Optional[ShotRunner] = None,
           with_timing: bool = True) -> Tally:
    """C = A @ B via mac3 shots (Fig. 7c)."""
    r = runner or ShotRunner(with_timing)
    M, Kd = A.shape
    _, N = B.shape
    Np = math.ceil(N / 3) * 3
    Bp = np.zeros((Kd, Np), dtype=I32)
    Bp[:, :N] = B
    g = K.mac3(Kd)
    key = f"mac3_{Kd}"
    for i in range(M):
        for j in range(0, Np, 3):
            outs = r.run_shot(key, g,
                              {"a": A[i].astype(I32),
                               "b0": Bp[:, j].astype(I32),
                               "b1": Bp[:, j + 1].astype(I32),
                               "b2": Bp[:, j + 2].astype(I32)},
                              streams_changed=6,
                              layout=(1, 0, 0, 0, 0, 0, 0))
            for t in range(3):
                if j + t < N:
                    out[i, j + t] = outs[f"out{t}"][0]
    return r.tally


def run_conv2d(img: np.ndarray, kern: np.ndarray, out: np.ndarray,
               runner: Optional[ShotRunner] = None,
               with_timing: bool = True) -> Tally:
    """3x3 'valid' convolution in exactly 3 shots (partial sums in memory)."""
    r = runner or ShotRunner(with_timing)
    H, W = img.shape
    L = (H - 2) * W
    flat = np.zeros(H * W + 2, dtype=np.int64)
    flat[:H * W] = img.reshape(-1)
    partial = np.zeros(L, dtype=I32)
    for row in range(3):
        k0, k1, k2 = (int(v) for v in kern[row])
        ins = {f"x{t}": flat[row * W + t: row * W + t + L].astype(I32)
               for t in range(3)}
        if row == 0:
            g = K.conv2d_row3(k0, k1, k2)
            outs = r.run_shot(f"convrow3_{k0}_{k1}_{k2}", g, ins,
                              streams_changed=4, layout=(1, 1, 1, 1))
        else:
            g = K.conv2d_row(k0, k1, k2)
            ins["pin"] = partial
            outs = r.run_shot(f"convrow_{k0}_{k1}_{k2}", g, ins,
                              streams_changed=5, layout=(1, 1, 1, 1, 1))
        partial = outs["pout"].astype(I32)
    plane = partial.reshape(H - 2, W)
    out[:, :] = plane[:, :W - 2]
    return r.tally


def run_axpby(alpha: int, x: np.ndarray, beta: int, y: np.ndarray,
              out: np.ndarray, runner: ShotRunner) -> None:
    """out = alpha*x + beta*y, one-shot elementwise epilogue."""
    g = K.axpby(alpha, beta)
    outs = runner.run_shot(f"axpby_{alpha}_{beta}", g,
                           {"x": x.astype(I32), "y": y.astype(I32)},
                           streams_changed=3, layout=(1, 1, 1))
    out[:] = outs["out"]


def _engine_for(runner: ShotRunner):
    """Engine sharing this runner's tally/mappings (lazy import: the engine
    package layers above core)."""
    from repro.engine.scheduler import Engine
    return Engine(runner=runner)


def run_gemm(alpha: int, A: np.ndarray, B: np.ndarray, beta: int,
             C: np.ndarray, with_timing: bool = True,
             runner: Optional[ShotRunner] = None) -> Tally:
    """C = alpha*A@B + beta*C (PolyBench gemm). Engine client — see
    ``repro.engine.clients``."""
    from repro.engine import clients
    r = runner or ShotRunner(with_timing)
    return clients.run_gemm(_engine_for(r), alpha, A, B, beta, C)


def run_gesummv(alpha: int, beta: int, A: np.ndarray, B: np.ndarray,
                x: np.ndarray, y: np.ndarray, with_timing: bool = True,
                runner: Optional[ShotRunner] = None) -> Tally:
    """y = alpha*A@x + beta*B@x (dual-MAC row shots share the x stream).
    Engine client — see ``repro.engine.clients``."""
    from repro.engine import clients
    r = runner or ShotRunner(with_timing)
    return clients.run_gesummv(_engine_for(r), alpha, beta, A, B, x, y)


def run_gemver(alpha: int, beta: int, A: np.ndarray,
               u1: np.ndarray, v1: np.ndarray, u2: np.ndarray,
               v2: np.ndarray, w: np.ndarray, x: np.ndarray,
               y: np.ndarray, z: np.ndarray, with_timing: bool = True,
               runner: Optional[ShotRunner] = None) -> Tally:
    """PolyBench gemver: A' = A + u1 v1^T + u2 v2^T ;
    x = beta*A'^T y + z ; w = alpha*A' x.

    Decomposition uses fabric-level unrolling (the only way to land in the
    paper's 39.8k-cycle budget — see DESIGN.md): phase 1 fuses two rows per
    shot sharing the v1/v2 streams (u*_i folded as constants, re-configured
    per shot); phases 2/3 are mac3 shots sharing the y/x stream across three
    columns/rows at a time.
    """
    r = runner or ShotRunner(with_timing)
    N = A.shape[0]
    Ap = np.zeros_like(A, dtype=I32)
    # phase 1: two fused outer-product rows per shot
    for i in range(0, N, 2):
        i1 = min(i + 1, N - 1)
        g = K.outer_row2(int(u1[i]), int(u2[i]), int(u1[i1]), int(u2[i1]))
        outs = r.run_shot("outer_row2", g,
                          {"a0": A[i].astype(I32), "a1": A[i1].astype(I32),
                           "v1": v1.astype(I32), "v2": v2.astype(I32)},
                          streams_changed=4, pe_config_words=20,
                          layout=(1, 1, 1, 1, 1, 1),
                          config_class="outer_row2")
        Ap[i], Ap[i1] = outs["out0"], outs["out1"]
    # phase 2: x = beta * (A'^T y) + z — three columns per mac3 shot
    d = _matvec_mac3(r, np.ascontiguousarray(Ap.T), y, col_layout=True)
    gsa = K.scale_add(beta)
    outs = r.run_shot(f"scale_add_{beta}", gsa,
                      {"x": d, "y": z.astype(I32)}, streams_changed=3)
    xnew = outs["out"].astype(I32)
    x[:] = xnew
    # phase 3: w = alpha * (A' x) — three rows per mac3 shot
    d = _matvec_mac3(r, Ap, xnew, col_layout=False)
    gs = K.scale(alpha)
    outs = r.run_shot(f"scale_{alpha}", gs, {"x": d}, streams_changed=2)
    w[:] = outs["out"]
    A[:, :] = Ap
    return r.tally


def _matvec_mac3(r: ShotRunner, M: np.ndarray, v: np.ndarray,
                 col_layout: bool) -> np.ndarray:
    """d = M @ v using mac3 shots: the vector stream is shared across three
    simultaneous row dot-products (same structure as Fig. 7c)."""
    n_rows, n_cols = M.shape
    d = np.zeros(n_rows, dtype=I32)
    g = K.mac3(n_cols)
    vv = v.astype(I32)
    res = 0 if col_layout else 1      # columns of the original are stride-N
    for i in range(0, n_rows, 3):
        rows = [min(i + t, n_rows - 1) for t in range(3)]
        outs = r.run_shot(f"mac3_{n_cols}", g,
                          {"a": vv, "b0": M[rows[0]].astype(I32),
                           "b1": M[rows[1]].astype(I32),
                           "b2": M[rows[2]].astype(I32)},
                          streams_changed=6,
                          layout=(1, res, res, res, 0, 0, 0))
        for t in range(3):
            if i + t < n_rows:
                d[i + t] = outs[f"out{t}"][0]
    return d


def run_2mm(alpha: int, beta: int, A, B, C, D, with_timing=True,
            runner: Optional[ShotRunner] = None) -> Tally:
    """D = alpha*A@B@C + beta*D (PolyBench 2mm). Engine client — see
    ``repro.engine.clients``."""
    from repro.engine import clients
    r = runner or ShotRunner(with_timing)
    return clients.run_2mm(_engine_for(r), alpha, beta, A, B, C, D)


def run_3mm(A, B, C, D, with_timing=True,
            runner: Optional[ShotRunner] = None) -> Tuple[Tally, np.ndarray]:
    """G = (A@B) @ (C@D) (PolyBench 3mm)."""
    r = runner or ShotRunner(with_timing)
    NI, NJ = A.shape[0], B.shape[1]
    NL = D.shape[1]
    E = np.zeros((NI, NJ), dtype=I32)
    run_mm(A, B, E, runner=r)
    F = np.zeros((B.shape[1], NL), dtype=I32)  # (NJ x NL) = C@D
    run_mm(C, D, F, runner=r)
    G = np.zeros((NI, NL), dtype=I32)
    run_mm(E, F, G, runner=r)
    return r.tally, G


# algorithmic op counts (paper conventions, Sec. VII-B)
def ops_mm(n: int) -> int:
    return 2 * n ** 3 - n ** 2


def ops_conv2d(h: int, w: int) -> int:
    return (h - 2) * (w - 2) * 17
