"""Fabric resource model: the 4x4 elastic PE array and its routing fabric.

Resources per PE (Figs. 1-4):
  * 4 input ports  IN_N/E/S/W   — Elastic Buffer + Fork Sender; an input port
    may fan out to the FU operand/control inputs and to the other three
    output ports (route-through).
  * 4 output ports OUT_N/E/S/W  — data/valid mux; carries exactly one signal.
  * FU inputs  FU_A / FU_B / FU_C — operand & control muxes.
  * FU output  FU_OUT           — registered datapath result + Fork Sender.

Inter-PE wiring is a nearest-neighbour mesh: OUT_S(r,c) feeds IN_N(r+1,c) etc.
IMNs feed IN_N of the north border; OMNs drain OUT_S of the south border
(Sec. IV-B mapping convention: inputs north, outputs south, E/W columns as
south-to-north return paths).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

# port name constants
IN_PORTS = ("IN_N", "IN_E", "IN_S", "IN_W")
OUT_PORTS = ("OUT_N", "OUT_E", "OUT_S", "OUT_W")
FU_INS = ("FU_A", "FU_B", "FU_C")
FU_OUT = "FU_OUT"

_OPPOSITE = {"N": "S", "S": "N", "E": "W", "W": "E"}


@dataclasses.dataclass(frozen=True)
class Res:
    """One routing resource: (pe row, pe col, port name). pe=(-1,c) denotes
    IMN c (north of row 0); pe=(rows,c) denotes OMN c (south of last row)."""

    r: int
    c: int
    port: str

    def __repr__(self):
        return f"{self.port}({self.r},{self.c})"


@dataclasses.dataclass
class Fabric:
    rows: int = 4
    cols: int = 4
    n_imns: int = 4
    n_omns: int = 4

    def __getstate__(self):
        # drop the routing-resource index memo (``rindex``): it is cheap
        # to rebuild and would otherwise bloat every pickled Mapping
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_")}

    def pes(self) -> Iterable[Tuple[int, int]]:
        for r in range(self.rows):
            for c in range(self.cols):
                yield (r, c)

    def pe_index(self, r: int, c: int) -> int:
        return r * self.cols + c

    # -- static connectivity -------------------------------------------------
    def imn_res(self, c: int) -> Res:
        return Res(-1, c, "IMN")

    def omn_res(self, c: int) -> Res:
        return Res(self.rows, c, "OMN")

    def next_hop(self, res: Res) -> Optional[Res]:
        """The unique sink wired to an OUT port / IMN (mesh wiring).

        The otherwise-dangling E/W ports of the border columns are wired as
        two extra vertical rails (Sec. IV-B: 'the CGRA now has n [vertical]
        paths plus two more' using the east and west borders). This is a
        reconstruction decision: without the two rails the fft butterfly of
        Fig. 7b is *provably* unroutable on a 4-wide mesh (min-cut 5 > 4
        column wires — see DESIGN.md §7), so the fabricated design must have
        had this extra border capacity.
        """
        r, c, p = res.r, res.c, res.port
        if p == "IMN":
            return Res(0, c, "IN_N")
        if not p.startswith("OUT_"):
            return None
        d = p[4:]
        if d == "N":
            return Res(r - 1, c, "IN_S") if r > 0 else None
        if d == "S":
            return Res(r + 1, c, "IN_N") if r + 1 < self.rows else \
                (self.omn_res(c) if c < self.n_omns else None)
        if d == "E":
            if c + 1 < self.cols:
                return Res(r, c + 1, "IN_W")
            # east border rail: dangling OUT_E feeds the PE below's IN_E
            return Res(r + 1, c, "IN_E") if r + 1 < self.rows else None
        if d == "W":
            if c - 1 >= 0:
                return Res(r, c - 1, "IN_E")
            # west border rail: dangling OUT_W feeds the PE below's IN_W
            return Res(r + 1, c, "IN_W") if r + 1 < self.rows else None
        return None

    def fanout(self, res: Res) -> List[Res]:
        """Resources reachable from ``res`` inside the same PE (fork/mux legs)
        or across the mesh (for OUT ports / IMN)."""
        r, c, p = res.r, res.c, res.port
        if p == "IMN" or p.startswith("OUT_"):
            nxt = self.next_hop(res)
            return [nxt] if nxt is not None else []
        if p.startswith("IN_"):
            side = p[3:]
            legs = [Res(r, c, fi) for fi in FU_INS]
            legs += [Res(r, c, f"OUT_{d}") for d in "NESW" if d != side]
            return legs
        if p in FU_INS:
            return [Res(r, c, FU_OUT)]
        if p == FU_OUT:
            # cardinal outputs + same-PE non-immediate feedback into the FU
            # data inputs (Fig. 3: dout_FU through an Elastic Buffer); the
            # control input never takes feedback (Sec. III-C).
            return ([Res(r, c, f"OUT_{d}") for d in "NESW"]
                    + [Res(r, c, "FU_A"), Res(r, c, "FU_B")])
        return []

    def rindex(self) -> "FabricIndex":
        """Cached integer index of this fabric's routing-resource graph.

        The negotiated router runs thousands of Dijkstra expansions per
        mapping; hashing frozen ``Res`` dataclasses dominated that cost
        (ISSUE 4). The index enumerates every resource once, assigns dense
        integer ids, and precomputes ``fanout`` as id adjacency lists, so
        the router's hot loop touches only ints and flat lists.
        """
        idx = self.__dict__.get("_rindex")
        if idx is None or idx.geometry != (self.rows, self.cols,
                                           self.n_imns, self.n_omns):
            idx = FabricIndex(self)
            self.__dict__["_rindex"] = idx
        return idx

    def hop_latency(self, res: Res) -> int:
        """Forward latency contributed by traversing ``res`` (cycles).

        Per Sec. III-C the PE output valid/ready FF was removed (0 cycles) and
        PE input Elastic Buffers register once (1 cycle); the FU datapath is
        registered (1 cycle, charged at firing). IMN/OMN bus beats take their
        cycle in the bank arbiter.
        """
        if res.port.startswith("IN_") or res.port in FU_INS:
            return 1
        return 0


class FabricIndex:
    """Dense-integer view of a fabric's routing resources.

    ``res_of[i]`` / ``id_of[res]`` translate between ids and ``Res``;
    ``fanout_ids[i]`` mirrors ``Fabric.fanout`` exactly (same order), and
    the ``is_*`` flags precompute the router's per-port skip tests.
    """

    def __init__(self, fabric: Fabric):
        self.geometry = (fabric.rows, fabric.cols, fabric.n_imns,
                         fabric.n_omns)
        res_list: List[Res] = []
        for c in range(fabric.n_imns):
            res_list.append(fabric.imn_res(c))
        for c in range(fabric.n_omns):
            res_list.append(fabric.omn_res(c))
        pe_ports = IN_PORTS + OUT_PORTS + tuple(FU_INS) + (FU_OUT,)
        for r in range(fabric.rows):
            for c in range(fabric.cols):
                for p in pe_ports:
                    res_list.append(Res(r, c, p))
        self.res_of: List[Res] = res_list
        self.id_of: Dict[Res, int] = {res: i for i, res in enumerate(res_list)}
        # router's view of fanout: FU_OUT entries are dropped up front (a
        # foreign FU is never traversable, and skipping it consumes no
        # router state), order otherwise preserved
        self.fanout_ids: List[List[int]] = [
            [self.id_of[n] for n in fabric.fanout(res) if n.port != FU_OUT]
            for res in res_list]
        # terminals may only be entered when they are the sink being routed
        self.is_terminal: List[bool] = [res.port in FU_INS or
                                        res.port == "OMN"
                                        for res in res_list]
