"""Per-backend capability sets — the declared contract of what each
execution substrate can lower.

Historically every layer that dispatched to the Pallas backend carried its
own ad-hoc ``backend == "pallas"`` refusal (acyclic non-reduction DFGs
only).  This module replaces those special cases with *feature detection*:

  * :func:`dfg_features` analyzes one DFG and returns the set of fabric
    features its execution requires (conditionals, reductions, loop state,
    recirculation, ...);
  * :data:`CAPS` declares, per backend, which features that substrate can
    lower;
  * :func:`check_backend` raises a :class:`CapabilityError` **naming every
    offending feature** when a kernel exceeds its backend's capability set
    — mirroring the frontend's named-equation diagnostics.

The split between compile-time (structural) and dispatch-time checks:
``emit_every`` is a node property but "single emission" depends on the
stream length, which DFG-compiled artifacts only learn at dispatch — so
:func:`check_stream_length` runs inside the Pallas dispatcher as well.

Capability matrix (DESIGN.md §11):

  feature               sim   pallas   why pallas can('t)
  ------------------------------------------------------------------
  elementwise chains     x      x      VPU ops over (8,128) tiles
  branch-merge conds     x      x      speculative legs + masked select
  reductions (1 emit)    x      x      tile-reduce + carry across grid
  segmented reductions   x      -      mid-stream emissions misalign tiles
  loop-state cells       x      -      per-element sequential carry
  recirculation loops    x      -      data-dependent trip counts
  multi-shot plans       x      x      per-shot kernels, IMN/OMN handoff
  lane batching          x      x      padded lane-major grid
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, FrozenSet, Iterable, Tuple

from repro.core import dfg as D
from repro.core.isa import AluOp


class CapabilityError(ValueError):
    """A kernel requires a feature its backend's capability set lacks."""


# reduction ops a tile-parallel substrate can re-associate (the identity /
# combine table in kernels/fabric_reduce.py); SHL/SHR/NOP accumulators are
# order-dependent and stay on the sequential simulator
ASSOCIATIVE_REDUCTION_OPS = (AluOp.ADD, AluOp.SUB, AluOp.MUL, AluOp.AND,
                             AluOp.OR, AluOp.XOR)

# feature flags a DFG (or plan) may require
FEATURE_DESC: Dict[str, str] = {
    "branch-merge": "Branch/Merge conditional (select-reducible legs)",
    "merge-order": "arrival-ordered MERGE (legs are not complementary "
                   "branch paths)",
    "reduction": "accumulator reduction feeding an output",
    "reduction-interior": "reduction consumed by an interior node",
    "reduction-op": "reduction with a non-associative op (SHL/SHR/NOP)",
    "reduction-subrate": "reduction paced by a sub-rate (branch-leg) stream",
    "subrate-output": "sub-rate output stream (unmerged branch leg)",
    "loop-state": "loop-carried back edge (state cell)",
    "recirculation": "recirculation edge (data-dependent loop)",
    "multi-shot": "multi-shot plan (IMN/OMN buffer handoff between shots)",
}

# what each backend can lower; "sim" is the semantic reference and takes
# everything the IR can express
CAPS: Dict[str, FrozenSet[str]] = {
    "sim": frozenset(FEATURE_DESC),
    "pallas": frozenset({"branch-merge", "reduction", "multi-shot"}),
}

BACKENDS = tuple(sorted(CAPS))


def _rates(g: D.DFG) -> Dict[Tuple[str, str], Fraction]:
    """Token rate of every signal relative to the input streams — the
    partitioner's analysis, reused verbatim so capability classification
    can never drift from the rates the planner actually cuts on. Callers
    only consult it for graphs without recirculation (data-dependent loops
    have no static rates), where the partitioner's loop-body cases are
    inert. Lazy import: the frontend layers above the engine."""
    from repro.frontend.partition import _rates as partition_rates
    return partition_rates(g)


def select_conds(g: D.DFG):
    """Per-wire structural validity provenance: the set of
    ((predicate wire), leg) constraints ANDed into each wire's token
    validity. Proves select-reducibility — every MERGE's legs must be
    complementary t/f paths of ONE predicate wire. The single shared
    implementation behind both the compile-time capability gate (here)
    and the jnp evaluator's trace-time check (``ref.eval_dfg_streams``),
    so the two can never drift. Back-edge operands carry an
    always-present register token (empty condition set).

    Returns ``(conds, offender)``: the provenance map plus the name of
    the first non-reducible MERGE (``None`` when every merge reduces;
    ``conds`` is partial past an offender)."""
    conds: Dict[Tuple[str, str], frozenset] = {}

    def cond(e) -> frozenset:
        if e is None or e.back:
            return frozenset()
        return conds.get((e.src, e.src_port), frozenset())

    for name in g.topo_order():
        n = g.nodes[name]
        if n.kind in (D.INPUT, D.CONST):
            conds[(name, "out")] = frozenset()
        elif n.kind == D.BRANCH:
            ec = g.operand(name, "ctrl")
            base = cond(g.operand(name, "a")) | cond(ec)
            pred = (ec.src, ec.src_port)
            conds[(name, "t")] = base | {(pred, "t")}
            conds[(name, "f")] = base | {(pred, "f")}
        elif n.kind == D.MERGE:
            ca = cond(g.operand(name, "a"))
            cb = cond(g.operand(name, "b"))
            da, db = ca - cb, cb - ca
            ok = len(da) == 1 and len(db) == 1
            if ok:
                ((pa, la),) = da
                ((pb, lb),) = db
                ok = pa == pb and {la, lb} == {"t", "f"}
            if not ok:
                return conds, name
            conds[(name, "out")] = ca & cb
        elif n.kind != D.OUTPUT:
            conds[(name, "out")] = frozenset().union(
                *(cond(e) for e in g.in_edges(name)))
    return conds, None


def _merges_select_reducible(g: D.DFG) -> bool:
    return select_conds(g)[1] is None


def dfg_features(g: D.DFG) -> FrozenSet[str]:
    """The feature set one DFG requires of its execution substrate.

    Memoized on the DFG object (dropped by ``DFG.__getstate__`` like the
    executor's plan cache): the analysis includes the partitioner's rate
    model and runs on dispatch paths, so repeat requests must not re-walk
    the graph."""
    memo = g.__dict__.get("_features_memo")
    if memo is not None:
        return memo
    feats = set()
    if g.has_recirculation():
        feats.add("recirculation")
    if any(e.back and e.init is not None for e in g.edges):
        feats.add("loop-state")
    if any(n.kind in (D.BRANCH, D.MERGE) for n in g.nodes.values()):
        feats.add("branch-merge")
        if "recirculation" not in feats and not _merges_select_reducible(g):
            feats.add("merge-order")
    reductions = [n for n in g.nodes.values() if n.is_reduction()]
    if reductions:
        feats.add("reduction")
        for n in reductions:
            if n.op not in ASSOCIATIVE_REDUCTION_OPS:
                feats.add("reduction-op")
            if any(g.nodes[e.dst].kind != D.OUTPUT
                   for e in g.out_edges(n.name)):
                feats.add("reduction-interior")
    if "recirculation" not in feats:
        rate = _rates(g)
        for o in g.outputs:
            e = g.operand(o, "a")
            if g.nodes[e.src].is_reduction():
                continue        # covered by the reduction flags
            if rate.get((e.src, e.src_port)) != Fraction(1):
                feats.add("subrate-output")
        for n in reductions:
            # a branch-masked accumulator fires only on arriving tokens; a
            # speculative tile-reduce would fold every lane — flag it so
            # tile-parallel backends reject instead of silently diverging
            e = g.operand(n.name, "a")
            if e is not None and \
                    rate.get((e.src, e.src_port)) != Fraction(1):
                feats.add("reduction-subrate")
    g.__dict__["_features_memo"] = frozenset(feats)
    return g.__dict__["_features_memo"]


def plan_features(plan) -> FrozenSet[str]:
    """Feature union over a partition plan's shots (+ the plan shape)."""
    feats = set()
    for shot in plan.shots:
        feats |= dfg_features(shot.dfg)
    if plan.n_shots > 1:
        feats.add("multi-shot")
    return frozenset(feats)


def missing_features(features: Iterable[str], backend: str) -> Tuple[str, ...]:
    if backend not in CAPS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    return tuple(sorted(f for f in features if f not in CAPS[backend]))


def check_backend(features: Iterable[str], backend: str, name: str) -> None:
    """Raise a named diagnostic when ``features`` exceed the backend caps."""
    missing = missing_features(features, backend)
    if missing:
        detail = "; ".join(f"{FEATURE_DESC.get(f, f)} [{f}]" for f in missing)
        raise CapabilityError(
            f"{name}: backend '{backend}' cannot lower: {detail} — "
            f"use backend='sim'")


def backend_skip_reason(g: D.DFG, length: int,
                        backend: str = "pallas"):
    """One-stop eligibility probe: the named reason ``backend`` cannot run
    ``g`` at ``length`` (missing capability features joined with '+', or
    ``"segmented-reduction"``), or ``None`` when it must run. The single
    source of truth shared by the conformance gate, the benchmarks, and
    any caller that wants to route around a rejection instead of catching
    :class:`CapabilityError`."""
    missing = missing_features(dfg_features(g), backend)
    if missing:
        return "+".join(missing)
    if backend != "sim":               # a tile-parallel-only constraint
        try:
            check_stream_length(g, length, backend)
        except CapabilityError:
            return "segmented-reduction"
    return None


def check_stream_length(g: D.DFG, length: int,
                        backend: str = "pallas") -> None:
    """Dispatch-time reduction-emission check: a tile-parallel backend only
    lowers *single-emission* reductions (``emit_every`` of 0 or the full
    stream length); mid-stream segment emissions misalign with the tile
    grid.  Raises naming the offending node."""
    for n in g.nodes.values():
        if n.is_reduction() and n.emit_every not in (0, length):
            raise CapabilityError(
                f"{g.name}: reduction node '{n.name}' emits every "
                f"{n.emit_every} tokens mid-stream (stream length {length}); "
                f"the '{backend}' backend lowers only single-emission "
                f"reductions (emit_every 0 or the full stream length) — "
                f"use backend='sim'")
