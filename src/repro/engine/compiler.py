"""``compile(fn_or_dfg, geometry) -> CompiledArtifact`` — the unified
compile half of the execution pipeline.

Accepts either of the two kernel sources the code base produces:

  * a hand-built ``core.dfg.DFG`` (kernels_lib / benchmark decompositions),
    keyed by a structural content digest;
  * a plain Python/JAX callable, traced through the frontend
    (``frontend.tracer``) and keyed by its jaxpr hash — the same key the
    ``@offload`` decorator uses, so both entry points share one artifact
    cache.

Either way the kernel is partitioned against the *target geometry*
(``frontend.partition.plan`` on an arbitrary ``Fabric``), every shot is
placed & routed, and the per-shot ISA configuration word streams are packed
(Sec. V-B bus format). The resulting ``CompiledArtifact`` is stored in the
persistent cache and handed to ``engine.Engine`` for execution.

Frontend modules are imported lazily: ``repro.frontend`` imports this
package for its cache, and function-level imports keep the cycle inert.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro import obs
from repro.core import dfg as D
from repro.core.fabric import Fabric
from repro.core.isa import config_stream
from repro.core.mapper import default_mapper, default_seed, generate_configs
from repro.engine.artifact import (SCHEMA_VERSION, ArtifactError,
                                   CompiledArtifact, Geometry)
from repro.engine.cache import ArtifactCache, default_cache


def geometry_of(fabric: Fabric) -> Geometry:
    return (fabric.rows, fabric.cols, fabric.n_imns, fabric.n_omns)


def dfg_digest(g: D.DFG, geometry: Geometry, backend: str,
               pe_limit: Optional[int] = None, mapper: str = "greedy",
               seed: int = 0) -> str:
    """Content digest of a DFG compile request. Node names participate (a
    Mapping's placement is keyed by node name, so structural equality alone
    would alias artifacts whose mappings don't transfer). ``pe_limit``
    changes the partition plan, so it keys too; ``restarts`` is a search
    budget, not a semantic input, and deliberately does not. The *mapper
    identity and seed* DO key: greedy and annealed compilations of the same
    DFG produce different mappings, and the on-disk cache must never serve
    one where the other was requested."""
    h = hashlib.sha1()
    h.update(f"v{SCHEMA_VERSION}|{g.name}|{geometry}|{backend}|"
             f"{pe_limit}|{mapper}|{seed}".encode())
    for name in sorted(g.nodes):
        n = g.nodes[name]
        op = int(n.op) if n.op is not None else -1
        h.update(f"N|{name}|{n.kind}|{op}|{n.value}|{n.acc_init}|"
                 f"{n.emit_every}".encode())
    for e in sorted(g.edges, key=lambda e: (e.src, e.src_port, e.dst,
                                            e.dst_port)):
        h.update(f"E|{e.src}|{e.src_port}|{e.dst}|{e.dst_port}|"
                 f"{int(e.back)}|{e.init}".encode())
    h.update(f"I|{g.inputs}|O|{g.outputs}".encode())
    return h.hexdigest()


def fn_cache_key(fn: Callable, length: int, mode: str, backend: str,
                 geometry: Geometry, arg_names: List[str],
                 pe_limit: Optional[int] = None, mapper: str = "greedy",
                 seed: int = 0) -> Tuple[str, Any, bool]:
    """(digest, jax out_shape, element_mode) for a traced-function compile.

    Mirrors the tracer's mode resolution so the recorded output shapes
    match what lowering will actually produce; captured closure values
    (jaxpr constvars) participate in the digest.
    """
    import jax
    import jax.numpy as jnp
    avals = [jax.ShapeDtypeStruct((length,), jnp.int32) for _ in arg_names]
    scalars = [jax.ShapeDtypeStruct((), jnp.int32) for _ in arg_names]
    if mode == "element":
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*scalars)
        element_mode = True
    elif mode == "stream":
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*avals)
        element_mode = False
    else:
        element_mode = False
        try:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*avals)
        except TypeError:
            # lax.cond needs scalar operands; mirror the tracer's fallback
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*scalars)
            element_mode = True
    consts = [np.asarray(c).tolist() for c in closed.consts]
    digest = hashlib.sha1(
        f"v{SCHEMA_VERSION}|{closed.jaxpr}|{consts}|{length}|{geometry}|"
        f"{backend}|{pe_limit}|{mapper}|{seed}".encode()).hexdigest()
    return digest, out_shape, element_mode


def build_artifact(g: D.DFG, key: str, fabric: Fabric, backend: str,
                   name: Optional[str] = None, length: Optional[int] = None,
                   element_mode: bool = False,
                   out_shapes: Optional[List[Tuple[int, ...]]] = None,
                   restarts: int = 200,
                   pe_limit: Optional[int] = None,
                   mapper: Optional[str] = None,
                   seed: Optional[int] = None) -> CompiledArtifact:
    """Partition + place & route + config-word emission (no cache I/O).

    The plan's required capability features are computed here and checked
    against the target backend's declared capability set — a kernel that
    exceeds it fails *at compile time* with a diagnostic naming every
    offending feature (engine/capabilities.py), not at first dispatch.
    """
    from repro.engine import capabilities
    from repro.frontend import partition
    name = name or g.name
    mapper = default_mapper() if mapper is None else mapper
    seed = default_seed() if seed is None else seed
    with obs.span("pnr", kernel=name, backend=backend, mapper=mapper) as sp:
        pl = partition.plan(g, fabric, restarts=restarts, pe_limit=pe_limit,
                            mapper=mapper, seed=seed)
        sp.set(shots=pl.n_shots)
    features = capabilities.plan_features(pl)
    capabilities.check_backend(features, backend, name)
    if backend == "pallas" and length is not None:
        for shot in pl.shots:
            capabilities.check_stream_length(shot.dfg, length, backend)
    config_class = f"{name}:{key[:10]}"
    words: Dict[str, List[int]] = {}
    with obs.span("config_emit", kernel=name):
        for i, shot in enumerate(pl.shots):
            # globally unique shot keys: runner memoization must never alias
            # two artifacts whose shot DFGs happen to share a name
            shot.key = config_class if pl.n_shots == 1 \
                else f"{config_class}/s{i}"
            words[shot.key] = config_stream(generate_configs(shot.mapping))
    return CompiledArtifact(
        name=name, key=key, backend=backend, geometry=geometry_of(fabric),
        plan=pl, config_words=words, config_class=config_class,
        length=length, element_mode=element_mode, out_shapes=out_shapes,
        features=tuple(sorted(features)), mapper=mapper)


def compile(fn_or_dfg: Union[Callable, D.DFG], length: Optional[int] = None,
            *, fabric: Optional[Fabric] = None, backend: str = "sim",
            mode: str = "auto", name: Optional[str] = None,
            cache: Optional[ArtifactCache] = None, restarts: int = 200,
            pe_limit: Optional[int] = None, mapper: Optional[str] = None,
            seed: Optional[int] = None) -> CompiledArtifact:
    """Compile a kernel into a cached, runnable ``CompiledArtifact``.

    ``length`` is required for callables (the traced stream extent) and
    ignored for DFGs, whose mappings are length-independent. ``mapper``
    selects place & route ("greedy" | "anneal", default from
    ``STRELA_MAPPER``) and ``seed`` the P&R RNG stream (default from
    ``STRELA_MAP_SEED``); both key the artifact digest.
    """
    fabric = fabric or Fabric()
    cache = cache if cache is not None else default_cache()
    geometry = geometry_of(fabric)
    mapper = default_mapper() if mapper is None else mapper
    seed = default_seed() if seed is None else seed

    if isinstance(fn_or_dfg, D.DFG):
        g = fn_or_dfg
        with obs.span("compile", kernel=name or g.name,
                      backend=backend) as sp:
            key = dfg_digest(g, geometry, backend, pe_limit,
                             mapper=mapper, seed=seed)
            with obs.span("cache.lookup", key=key[:12]):
                hit = cache.get(key)
            if hit is not None:
                obs.inc("compile.cache_hits")
                sp.set(cache="hit")
                return hit
            obs.inc("compile.cache_misses")
            art = build_artifact(g, key, fabric, backend, name=name,
                                 restarts=restarts, pe_limit=pe_limit,
                                 mapper=mapper, seed=seed)
            cache.put(art)
            return art

    if not callable(fn_or_dfg):
        raise ArtifactError(f"compile() takes a DFG or a callable, got "
                            f"{type(fn_or_dfg)!r}")
    if length is None:
        raise ArtifactError("compile(fn) requires the stream length")
    import inspect
    import jax
    fn = fn_or_dfg
    kname = name or getattr(fn, "__name__", "kernel")
    with obs.span("compile", kernel=kname, backend=backend) as sp:
        arg_names = [p.name for p in inspect.signature(fn).parameters.values()
                     if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
        key, out_shape, element_mode = fn_cache_key(
            fn, length, mode, backend, geometry, arg_names, pe_limit,
            mapper=mapper, seed=seed)
        with obs.span("cache.lookup", key=key[:12]):
            hit = cache.get(key)
        if hit is not None:
            obs.inc("compile.cache_hits")
            sp.set(cache="hit")
            return hit
        obs.inc("compile.cache_misses")
        from repro.frontend.tracer import trace
        with obs.span("frontend.trace", kernel=kname):
            g = trace(fn, length, name=kname, mode=mode)
        leaves, _ = jax.tree_util.tree_flatten(out_shape)
        shapes = [(length,) if element_mode else tuple(l.shape)
                  for l in leaves]
        art = build_artifact(g, key, fabric, backend, name=kname,
                             length=length, element_mode=element_mode,
                             out_shapes=shapes, restarts=restarts,
                             pe_limit=pe_limit, mapper=mapper, seed=seed)
        cache.put(art)
        return art
