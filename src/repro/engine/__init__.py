"""repro.engine — the unified execution engine.

One pipeline for every way this code base runs a kernel::

    compile(fn | DFG, geometry) -> CompiledArtifact -> Engine.run(...)

  * :func:`compile`            — trace/lower, partition against an arbitrary
                                 ``Fabric`` geometry, place & route, pack ISA
                                 config words (compiler.py)
  * :class:`CompiledArtifact`  — the serializable bundle, persistently
                                 cached on disk keyed by content digest x
                                 length x geometry x backend (artifact.py,
                                 cache.py)
  * :class:`Engine`            — dispatch: naive per-request ``run`` or
                                 batched ``submit``/``flush`` grouping
                                 requests by config class so same-config
                                 traffic pays re-arm instead of full
                                 reconfiguration (scheduler.py)
  * clients                    — Table II benchmarks (gemm/gesummv/2mm)
                                 rewritten over the engine (clients.py)
"""
from repro.engine.artifact import (ArtifactError, CompiledArtifact,
                                   estimate_ii)
from repro.engine.cache import ArtifactCache, default_cache
from repro.engine.capabilities import (CAPS, CapabilityError, check_backend,
                                       dfg_features, plan_features)
from repro.engine.compiler import compile, geometry_of
from repro.engine.scheduler import Engine, EngineStats, Handle

__all__ = [
    "ArtifactCache", "ArtifactError", "CAPS", "CapabilityError",
    "CompiledArtifact", "Engine", "EngineStats", "Handle", "check_backend",
    "compile", "default_cache", "dfg_features", "estimate_ii",
    "geometry_of", "plan_features",
]
