"""Table II benchmark kernels as execution-engine clients.

These reimplement the hand-written ``core.multishot`` helpers (``run_gemm``,
``run_gesummv``, ``run_2mm``) on top of ``Engine.compile`` + ``submit`` /
``flush``: kernels are compiled once into cached artifacts, independent
shots within a phase are submitted and batched by config class, and
data-dependent phases flush in between. Cycle accounting is identical to
the legacy helpers (same shot structure, stream counts, and layouts), which
is the proof that the old per-benchmark runner code can be retired in favor
of the one pipeline.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core import kernels_lib as K
from repro.core.multishot import Tally
from repro.engine.scheduler import Engine

I32 = np.int32


def run_mm(eng: Engine, A: np.ndarray, B: np.ndarray,
           out: np.ndarray) -> None:
    """C = A @ B via batched mac3 shots (Fig. 7c)."""
    M, Kd = A.shape
    _, N = B.shape
    Np = math.ceil(N / 3) * 3
    Bp = np.zeros((Kd, Np), dtype=I32)
    Bp[:, :N] = B
    art = eng.compile(K.mac3(Kd))
    handles = []
    for i in range(M):
        for j in range(0, Np, 3):
            h = eng.submit(art,
                           {"a": A[i].astype(I32),
                            "b0": Bp[:, j].astype(I32),
                            "b1": Bp[:, j + 1].astype(I32),
                            "b2": Bp[:, j + 2].astype(I32)},
                           streams_changed=6,
                           layout=(1, 0, 0, 0, 0, 0, 0))
            handles.append((i, j, h))
    eng.flush()
    for i, j, h in handles:
        outs = h.result()
        for t in range(3):
            if j + t < N:
                out[i, j + t] = outs[f"out{t}"][0]


def run_axpby(eng: Engine, alpha: int, x: np.ndarray, beta: int,
              y: np.ndarray, out: np.ndarray) -> None:
    """out = alpha*x + beta*y, one-shot elementwise epilogue."""
    art = eng.compile(K.axpby(alpha, beta))
    h = eng.submit(art, {"x": x.astype(I32), "y": y.astype(I32)},
                   streams_changed=3, layout=(1, 1, 1))
    eng.flush()
    out[:] = h.result()["out"]


def run_gemm(eng: Engine, alpha: int, A: np.ndarray, B: np.ndarray,
             beta: int, C: np.ndarray) -> Tally:
    """C = alpha*A@B + beta*C (PolyBench gemm)."""
    NI, NJ = A.shape[0], B.shape[1]
    tmp = np.zeros((NI, NJ), dtype=I32)
    run_mm(eng, A, B, tmp)
    res = np.zeros(NI * NJ, dtype=I32)
    run_axpby(eng, alpha, tmp.reshape(-1), beta, C.reshape(-1), res)
    C[:, :] = res.reshape(NI, NJ)
    return eng.tally


def run_gesummv(eng: Engine, alpha: int, beta: int, A: np.ndarray,
                B: np.ndarray, x: np.ndarray, y: np.ndarray) -> Tally:
    """y = alpha*A@x + beta*B@x (dual-MAC row shots share the x stream)."""
    N = A.shape[0]
    art = eng.compile(K.mac2x(N))
    xi = x.astype(I32)
    handles = []
    for i in range(N):
        # only the two row bases change between shots (x, outputs, sizes
        # and strides persist) -> 2 MMIO writes per re-arm
        h = eng.submit(art,
                       {"a": A[i].astype(I32), "b": B[i].astype(I32),
                        "x": xi},
                       streams_changed=2, layout=(1, 1, 1, 0, 0))
        handles.append(h)
    eng.flush()
    d1 = np.array([h.result()["out0"][0] for h in handles], dtype=I32)
    d2 = np.array([h.result()["out1"][0] for h in handles], dtype=I32)
    run_axpby(eng, alpha, d1, beta, d2, y)
    return eng.tally


def run_2mm(eng: Engine, alpha: int, beta: int, A: np.ndarray,
            B: np.ndarray, C: np.ndarray, D: np.ndarray) -> Tally:
    """D = alpha*A@B@C + beta*D (PolyBench 2mm)."""
    NI, NJ = A.shape[0], B.shape[1]
    NL = C.shape[1]
    tmp = np.zeros((NI, NJ), dtype=I32)
    run_mm(eng, A, B, tmp)
    tmp2 = np.zeros((NI, NL), dtype=I32)
    run_mm(eng, tmp, C, tmp2)
    res = np.zeros(NI * NL, dtype=I32)
    run_axpby(eng, alpha, tmp2.reshape(-1), beta, D.reshape(-1), res)
    D[:, :] = res.reshape(NI, NL)
    return eng.tally
