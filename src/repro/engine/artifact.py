"""CompiledArtifact — the serializable unit the execution engine runs.

One artifact bundles everything a fabric execution needs, so the whole
compile pipeline (trace -> lower -> partition -> place & route -> config
emission) runs at most once per (kernel, geometry, backend) and the result
can round-trip through a persistent cache (``engine/cache.py``):

  * the lowered DFG and its backend plan — a ``frontend.partition.Plan``,
    which is single-shot (one mapped sub-DFG) or multi-shot (an ordered
    shot sequence with stream bindings);
  * every shot's ``Mapping`` (place & route result on the target
    ``Fabric`` geometry);
  * the packed per-shot ISA configuration word streams (Sec. V-B bus
    format, five 32-bit words per active PE);
  * the config class — the batching key: requests whose artifacts share a
    config class can run back-to-back on the fabric paying only stream
    re-arm, not a full reconfiguration (the paper's multi-shot
    amortization, Sec. IV-B).

Artifacts are plain pickles of dataclass trees (DFG / Mapping / Fabric are
all dataclasses); ``SCHEMA_VERSION`` participates in every cache digest so
stale on-disk artifacts from older layouts are never resurrected.
"""
from __future__ import annotations

import dataclasses
import math
import pickle
from typing import Dict, List, Optional, Tuple

from repro.core import dfg as D
from repro.core.elastic_sim import TimingTrace
from repro.core.mapper import Mapping
from repro.core.multishot import rearm_cycles

# Bump whenever the artifact layout or any compile-pipeline semantics
# change; the version is hashed into cache keys, so old entries miss.
# v2: Edge.init became Optional (None = recirculation edge of a
#     data-dependent loop) and the frontend lowers while/fori/scan.
# v3: artifacts carry TimingTraces — per (shot key, length, layout, bank
#     count) cycle schedules recorded once for static-rate shots and
#     replayed on every later dispatch (timing/value decoupling).
# v4: artifacts carry their required capability feature set (``features``,
#     see engine/capabilities.py) so every dispatch layer validates against
#     the declared per-backend capability matrix instead of ad-hoc
#     ``backend == "pallas"`` special cases.
# v5: cache digests key on mapper identity + P&R seed and artifacts carry
#     ``mapper`` ("greedy" | "anneal", core/opt_mapper.py) — greedy and
#     annealed compilations of the same kernel must never alias on disk.
SCHEMA_VERSION = 5

# key of one recorded trace: (shot/config key, length, layout, n_banks)
TraceKey = Tuple[str, int, Tuple[int, ...], int]

Geometry = Tuple[int, int, int, int]          # (rows, cols, n_imns, n_omns)


class ArtifactError(RuntimeError):
    pass


@dataclasses.dataclass
class CompiledArtifact:
    """A compiled, mapped, config-emitted kernel ready for ``Engine.run``."""

    name: str
    key: str                                  # full cache digest
    backend: str                              # "sim" | "pallas"
    geometry: Geometry
    plan: "object"                            # frontend.partition.Plan
    config_words: Dict[str, List[int]]        # shot key -> packed 32-bit words
    config_class: str                         # batching key
    length: Optional[int] = None              # traced kernels fix the length
    element_mode: bool = False                # traced per-element (lax.cond)
    out_shapes: Optional[List[Tuple[int, ...]]] = None
    # value-independent cycle schedules of static-rate shots, recorded on
    # first execution and replayed ever after (persisted with the artifact)
    timing_traces: Dict[TraceKey, TimingTrace] = \
        dataclasses.field(default_factory=dict)
    # capability features this kernel requires of its execution substrate
    # (sorted flags from engine/capabilities.py, computed at compile time)
    features: Tuple[str, ...] = ()
    # which place & route produced the plan's mappings ("greedy" | "anneal")
    mapper: str = "greedy"
    schema: int = SCHEMA_VERSION

    # -- structure ---------------------------------------------------------
    @property
    def dfg(self) -> D.DFG:
        return self.plan.dfg

    @property
    def n_shots(self) -> int:
        return self.plan.n_shots

    @property
    def mapping(self) -> Mapping:
        if self.n_shots != 1:
            raise ArtifactError(f"{self.name}: multi-shot artifact has no "
                                f"single mapping")
        return self.plan.shots[0].mapping

    def total_config_words(self) -> int:
        return sum(len(w) for w in self.config_words.values())

    def trace_for(self, key: str, length: Optional[int] = None
                  ) -> Optional[TimingTrace]:
        """The recorded timing trace of shot/config-class ``key`` (first
        match when ``length`` is None — artifacts usually carry one trace
        per shot). Consumers: the fabric profiler attributes per-PE
        occupancy from exactly these firing counts (``repro.obs``)."""
        for (k, tlen, _layout, _banks), tr in self.timing_traces.items():
            if k == key and (length is None or tlen == length):
                return tr
        return None

    def config_cycles(self) -> int:
        """Full-reconfiguration cost: config fetch for every shot class."""
        return sum(s.mapping.config_cycles() for s in self.plan.shots)

    # -- cost model --------------------------------------------------------
    def estimated_ii(self, n_banks: int = 4) -> float:
        """Static initiation-interval estimate (cycles/element), the max
        over the plan's shots."""
        return max(estimate_ii(s.dfg, n_banks) for s in self.plan.shots)

    def model_cycles(self, length: int, n_banks: int = 4) -> int:
        """Model-based execution estimate for a stream of ``length``:
        per shot, configuration fetch + stream re-arm + II x length. Used
        where no cycle-accurate measurement exists (the pallas backend)."""
        total = 0
        for shot in self.plan.shots:
            ii = estimate_ii(shot.dfg, n_banks)
            streams = len(shot.inputs) + len(shot.outputs)
            total += (shot.mapping.config_cycles() + rearm_cycles(streams)
                      + math.ceil(ii * length))
        return total

    # -- serialization -----------------------------------------------------
    def to_bytes(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "CompiledArtifact":
        art = pickle.loads(blob)
        if not isinstance(art, cls):
            raise ArtifactError(f"not a CompiledArtifact: {type(art)!r}")
        if art.schema != SCHEMA_VERSION:
            raise ArtifactError(f"artifact schema {art.schema} != "
                                f"{SCHEMA_VERSION}")
        return art

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.to_bytes())

    @classmethod
    def load(cls, path: str) -> "CompiledArtifact":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())


def estimate_ii(g: D.DFG, n_banks: int = 4) -> float:
    """Static II model of one shot DFG on the interleaved-bank bus.

    Two steady-state bottlenecks bound the element rate:
      * memory: each bank serves one beat per cycle, so ``ceil(full-rate
        streams / n_banks)`` cycles per element set (fft: 8 streams on 4
        banks -> 2, matching the measured 1.95);
      * loop-carried feedback: a back-edge cycle of k registered FUs can
        only accept a new element every k cycles (dither: 4-FU loop ->
        II = 4, Sec. VII-B). Immediate-feedback accumulators pipeline at
        II = 1 and impose no loop bound.
    """
    full_rate_outs = 0
    for name in g.outputs:
        if g.nodes[name].emit_every == 0:
            continue                      # last-value OMN (stride-0 store)
        e = g.operand(name, "a")
        producer = g.nodes[e.src]
        if not (producer.is_reduction() and producer.emit_every != 1):
            full_rate_outs += 1
    streams = len(g.inputs) + full_rate_outs
    ii_mem = math.ceil(streams / n_banks) if streams else 1

    ii_loop = 1
    funcs = {n for n, nd in g.nodes.items()
             if nd.kind in (D.ALU, D.CMP, D.MUX, D.BRANCH, D.MERGE)}
    fwd: Dict[str, List[str]] = {n: [] for n in funcs}
    rev: Dict[str, List[str]] = {n: [] for n in funcs}
    for e in g.edges:
        if not e.back and e.src in funcs and e.dst in funcs:
            fwd[e.src].append(e.dst)
            rev[e.dst].append(e.src)

    def _reach(start: str, adj: Dict[str, List[str]]) -> set:
        seen, stack = {start}, [start]
        while stack:
            for nxt in adj[stack.pop()]:
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen

    for e in g.back_edges():
        if e.src not in funcs or e.dst not in funcs:
            continue
        body = _reach(e.dst, fwd) & _reach(e.src, rev)
        body.update((e.src, e.dst))
        ii_loop = max(ii_loop, len(body))
    return float(max(1, ii_mem, ii_loop))
