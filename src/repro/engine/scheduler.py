"""The execution engine: artifact dispatch with config-class batching.

The paper's central performance lever is amortization: multi-shot traffic
wins (115.96 vs 72.68 MOPs/mW, Table II) exactly when reconfiguration and
stream re-arm costs are shared across work. ``Engine`` applies that lever
at the request level:

  * ``run(artifact, inputs)`` — *naive per-request dispatch*. Between
    independent requests the fabric cannot be assumed to still hold the
    caller's configuration (another tenant may have claimed it), so every
    run pays a full configuration fetch plus re-arm.
  * ``submit(...)`` / ``flush()`` — *batched dispatch*. Queued requests
    are grouped by their artifact's config class (stable within a class,
    classes ordered by first arrival); consecutive shots sharing a fabric
    configuration pay only the re-arm preamble
    (``SYNC + 14*streams_changed + 5*config_words``) instead of a full
    reconfiguration. The scheduler may reorder *across* classes only —
    requests are independent by contract (data-dependent phases flush
    between submissions).

All cycle accounting lands in the shared ``ShotRunner`` tally;
``EngineStats`` additionally tracks what the same requests would have cost
one-by-one, so the batching savings are directly observable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.fabric import Fabric
from repro.core.multishot import ShotRunner, Tally
from repro.engine.artifact import ArtifactError, CompiledArtifact
from repro.engine.cache import ArtifactCache, default_cache
from repro.engine import compiler


@dataclasses.dataclass
class EngineStats:
    """Batching observability: actual vs naive-dispatch configuration cost."""

    requests: int = 0
    flushes: int = 0
    config_cycles_paid: int = 0       # what the batched schedule charged
    config_cycles_naive: int = 0      # what one-by-one dispatch would charge

    @property
    def config_cycles_saved(self) -> int:
        return self.config_cycles_naive - self.config_cycles_paid


class Handle:
    """Future-like result slot for a submitted request."""

    __slots__ = ("artifact", "inputs", "streams_changed", "layout",
                 "pe_config_words", "_outputs", "_done")

    def __init__(self, artifact: CompiledArtifact,
                 inputs: Dict[str, np.ndarray], streams_changed: int,
                 layout: Tuple[int, ...], pe_config_words: int):
        self.artifact = artifact
        self.inputs = inputs
        self.streams_changed = streams_changed
        self.layout = layout
        self.pe_config_words = pe_config_words
        self._outputs: Optional[Dict[str, np.ndarray]] = None
        self._done = False

    def result(self) -> Dict[str, np.ndarray]:
        if not self._done:
            raise ArtifactError("request not yet executed; call "
                                "Engine.flush() first")
        return self._outputs


class Engine:
    """One compile -> artifact -> run pipeline over a fixed fabric geometry.

    Wraps a ``ShotRunner`` (owned or caller-provided) so existing cycle
    accounting, per-class mapping reuse, and simulation memoization apply
    unchanged; adds artifact compilation, the persistent cache, and the
    batched request scheduler.
    """

    def __init__(self, fabric: Optional[Fabric] = None, backend: str = "sim",
                 with_timing: bool = True,
                 runner: Optional[ShotRunner] = None,
                 cache: Optional[ArtifactCache] = None):
        if backend not in ("sim", "pallas"):
            raise ValueError(f"backend must be 'sim' or 'pallas', got "
                             f"{backend!r}")
        if runner is not None:
            self.runner = runner
            self.fabric = runner.fabric if fabric is None else fabric
        else:
            self.fabric = fabric or Fabric()
            self.runner = ShotRunner(with_timing=with_timing,
                                     fabric=self.fabric)
        self.backend = backend
        self.cache = cache if cache is not None else default_cache()
        self.stats = EngineStats()
        self._queue: List[Handle] = []

    # -- compile -----------------------------------------------------------
    def compile(self, fn_or_dfg, length: Optional[int] = None,
                **kw) -> CompiledArtifact:
        kw.setdefault("fabric", self.fabric)
        kw.setdefault("backend", self.backend)
        kw.setdefault("cache", self.cache)
        return compiler.compile(fn_or_dfg, length, **kw)

    # -- dispatch ----------------------------------------------------------
    def submit(self, artifact: CompiledArtifact,
               inputs: Dict[str, np.ndarray], *,
               streams_changed: Optional[int] = None,
               layout: Tuple[int, ...] = (),
               pe_config_words: int = 0) -> Handle:
        """Queue one request; execution happens at the next ``flush()``."""
        self._check(artifact)
        if streams_changed is None:
            g = artifact.dfg
            streams_changed = len(g.inputs) + len(g.outputs)
        h = Handle(artifact, inputs, streams_changed, layout, pe_config_words)
        self._queue.append(h)
        return h

    def flush(self) -> List[Handle]:
        """Execute all queued requests, batched by config class."""
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        # stable group-by: classes keep first-arrival order, requests keep
        # arrival order within their class
        class_rank: Dict[str, int] = {}
        for h in queue:
            class_rank.setdefault(h.artifact.config_class, len(class_rank))
        queue.sort(key=lambda h: class_rank[h.artifact.config_class])
        for h in queue:
            self._execute(h)
        self.stats.flushes += 1
        return queue

    def run(self, artifact: CompiledArtifact,
            inputs: Dict[str, np.ndarray], *,
            streams_changed: Optional[int] = None,
            layout: Tuple[int, ...] = (),
            pe_config_words: int = 0) -> Dict[str, np.ndarray]:
        """Naive per-request dispatch: execute now, assuming a cold fabric."""
        h = self.submit(artifact, inputs, streams_changed=streams_changed,
                        layout=layout, pe_config_words=pe_config_words)
        self._queue.pop()
        self.runner.invalidate_config()
        self._execute(h)
        return h.result()

    # -- internals ---------------------------------------------------------
    def _check(self, artifact: CompiledArtifact) -> None:
        geo = compiler.geometry_of(self.fabric)
        if artifact.geometry != geo:
            raise ArtifactError(
                f"{artifact.name}: artifact compiled for geometry "
                f"{artifact.geometry}, engine fabric is {geo}")

    def _execute(self, h: Handle) -> None:
        art = h.artifact
        before = self.runner.tally.config
        if art.backend == "pallas":
            # no cycle-accurate configuration model on this path: contribute
            # to neither paid nor naive, so stats never report savings that
            # batching didn't produce
            h._outputs = self._run_pallas(art, h.inputs)
            h._done = True
            self.stats.requests += 1
            return
        self.stats.config_cycles_naive += art.config_cycles()
        for shot in art.plan.shots:
            self.runner.seed_mapping(shot.key, shot.mapping)
        for (key, length, layout, n_banks), tr in art.timing_traces.items():
            self.runner.seed_trace(key, length, layout, tr)
        if art.n_shots == 1:
            shot = art.plan.shots[0]
            ins = {iname: np.asarray(h.inputs[iname], dtype=np.int32)
                   for iname, _ in shot.inputs}
            h._outputs = self.runner.run_shot(
                shot.key, shot.dfg, ins, streams_changed=h.streams_changed,
                pe_config_words=h.pe_config_words, layout=h.layout,
                config_class=art.config_class)
        else:
            h._outputs = art.plan.run(h.inputs, runner=self.runner)
        h._done = True
        self.stats.requests += 1
        self.stats.config_cycles_paid += self.runner.tally.config - before
        self._harvest_traces(art)

    def _harvest_traces(self, art: CompiledArtifact) -> None:
        """Persist timing traces the runner recorded for this artifact's
        shots: the first execution of a static-rate shot pays one cycle
        simulation, every later dispatch — in this process or any other —
        replays the trace from the artifact cache."""
        fresh = self.runner.fresh_traces()
        if not fresh:
            return
        shot_keys = {s.key for s in art.plan.shots}
        added = False
        for tkey, tr in fresh.items():
            if tkey[0] in shot_keys and tkey not in art.timing_traces:
                art.timing_traces[tkey] = tr
                added = True
        if added:
            self.cache.put(art)

    def _run_pallas(self, art: CompiledArtifact,
                    inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        g = art.dfg
        if art.n_shots != 1 or g.back_edges() or \
                any(n.is_reduction() for n in g.nodes.values()):
            raise ArtifactError(
                f"{art.name}: the pallas backend handles single-shot "
                f"acyclic non-reduction DFGs; use backend='sim'")
        import jax.numpy as jnp
        from repro.kernels.fabric_stream import fabric_stream
        jin = {k: jnp.asarray(v) for k, v in inputs.items()}
        return {k: np.asarray(v) for k, v in fabric_stream(g, jin).items()}

    # -- observability -----------------------------------------------------
    @property
    def tally(self) -> Tally:
        return self.runner.tally

    def pending(self) -> int:
        return len(self._queue)
