"""The execution engine: artifact dispatch with config-class batching.

The paper's central performance lever is amortization: multi-shot traffic
wins (115.96 vs 72.68 MOPs/mW, Table II) exactly when reconfiguration and
stream re-arm costs are shared across work. ``Engine`` applies that lever
at the request level:

  * ``run(artifact, inputs)`` — *naive per-request dispatch*. Between
    independent requests the fabric cannot be assumed to still hold the
    caller's configuration (another tenant may have claimed it), so every
    run pays a full configuration fetch plus re-arm.
  * ``submit(...)`` / ``flush()`` — *batched dispatch*. Queued requests
    are grouped by their artifact's config class (stable within a class,
    classes ordered by first arrival); consecutive shots sharing a fabric
    configuration pay only the re-arm preamble
    (``SYNC + 14*streams_changed + 5*config_words``) instead of a full
    reconfiguration. The scheduler may reorder *across* classes only —
    requests are independent by contract (data-dependent phases flush
    between submissions).

Backends differ only in their *value substrate* (``ShotRunner.value_fn``):
``sim`` computes values with the functional executor, ``pallas`` with the
fused streaming/reduction kernels (``kernels/fabric_reduce.run_dfg``).
Cycle accounting is identical — the timing simulation is value-independent
for static-rate shots (PR 4) and memoized per config class, so the pallas
path reports the same measured cycles as sim. Eligibility is declared, not
special-cased: every artifact carries its required capability features and
``Engine`` validates them against the backend's capability set
(``engine/capabilities.py``), raising diagnostics that name the offending
feature.

On the pallas backend, ``flush()`` additionally coalesces consecutive
same-artifact single-shot requests into one **lane-batched** padded Pallas
grid (``run_dfg_lanes``, mirroring the simulator's ``simulate_lanes``): a
config-class batch costs one kernel launch instead of N.

All cycle accounting lands in the shared ``ShotRunner`` tally;
``EngineStats`` additionally tracks what the same requests would have cost
one-by-one, so the batching savings are directly observable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core.fabric import Fabric
from repro.core.multishot import ShotRunner, Tally
from repro.engine import capabilities
from repro.engine.artifact import ArtifactError, CompiledArtifact
from repro.engine.cache import ArtifactCache, default_cache
from repro.engine import compiler


def _pallas_value_fn(g, inputs):
    """Value substrate of the pallas backend (lazy import: jax + the
    Pallas kernels only load when a pallas engine actually dispatches)."""
    from repro.kernels.fabric_reduce import run_dfg
    return run_dfg(g, inputs)


@dataclasses.dataclass
class EngineStats:
    """Batching observability: actual vs naive-dispatch configuration cost.

    Re-based on ``repro.obs`` (ISSUE 6) without breaking this public API:
    the dataclass fields stay authoritative and always update; when the
    obs metrics registry is enabled every increment is mirrored into the
    ``engine.*`` counters/gauges (see ``Engine._execute`` / ``flush``),
    and :meth:`publish` snapshots the whole struct into the registry so
    exporters see the same numbers clients read here.
    """

    requests: int = 0
    flushes: int = 0
    config_cycles_paid: int = 0       # what the batched schedule charged
    config_cycles_naive: int = 0      # what one-by-one dispatch would charge
    lane_batches: int = 0             # pallas grids serving > 1 request
    lane_requests: int = 0            # requests served inside those grids
    lane_batch_failures: int = 0      # grids that fell back to per-request

    @property
    def config_cycles_saved(self) -> int:
        return self.config_cycles_naive - self.config_cycles_paid

    def publish(self, registry=None,
                prefix: str = "engine.stats.") -> None:
        """Snapshot every field into the obs metrics registry as
        ``<prefix>*`` gauges (no-op when obs is disabled).

        The default prefix keeps the single-engine metric names of ISSUE
        6; a fleet (``repro.fleet``) publishes each fabric worker's stats
        under ``fleet.<fabric>.engine.`` so N engines never collide on
        one gauge."""
        registry = registry if registry is not None else obs.registry()
        if registry is None:
            return
        for f in dataclasses.fields(self):
            registry.gauge(f"{prefix}{f.name}").set(getattr(self, f.name))
        registry.gauge(f"{prefix}config_cycles_saved").set(
            self.config_cycles_saved)


class Handle:
    """Future-like result slot for a submitted request."""

    __slots__ = ("artifact", "inputs", "streams_changed", "layout",
                 "pe_config_words", "_outputs", "_done")

    def __init__(self, artifact: CompiledArtifact,
                 inputs: Dict[str, np.ndarray], streams_changed: int,
                 layout: Tuple[int, ...], pe_config_words: int):
        self.artifact = artifact
        self.inputs = inputs
        self.streams_changed = streams_changed
        self.layout = layout
        self.pe_config_words = pe_config_words
        self._outputs: Optional[Dict[str, np.ndarray]] = None
        self._done = False

    def result(self) -> Dict[str, np.ndarray]:
        if not self._done:
            raise ArtifactError("request not yet executed; call "
                                "Engine.flush() first")
        return self._outputs


class Engine:
    """One compile -> artifact -> run pipeline over a fixed fabric geometry.

    Wraps a ``ShotRunner`` (owned or caller-provided) so existing cycle
    accounting, per-class mapping reuse, and simulation memoization apply
    unchanged; adds artifact compilation, the persistent cache, and the
    batched request scheduler.
    """

    def __init__(self, fabric: Optional[Fabric] = None, backend: str = "sim",
                 with_timing: bool = True,
                 runner: Optional[ShotRunner] = None,
                 cache: Optional[ArtifactCache] = None,
                 mapper: Optional[str] = None):
        if backend not in capabilities.CAPS:
            raise ValueError(f"backend must be one of "
                             f"{capabilities.BACKENDS}, got {backend!r}")
        if runner is not None:
            self.runner = runner
            self.fabric = runner.fabric if fabric is None else fabric
        else:
            self.fabric = fabric or Fabric()
            self.runner = ShotRunner(with_timing=with_timing,
                                     fabric=self.fabric)
        # engine-resolved value substrate, bound to the runner only for
        # the duration of each dispatch (a ShotRunner may be shared by
        # engines of different backends — never mutate it permanently)
        from repro.core.executor import execute
        self._value_fn = _pallas_value_fn if backend == "pallas" else execute
        self.backend = backend
        self.cache = cache if cache is not None else default_cache()
        # None = resolve per compile from STRELA_MAPPER (so one Engine can
        # follow the env); a concrete value pins every compile it issues
        self.mapper = mapper
        self.stats = EngineStats()
        self._queue: List[Handle] = []
        self._flushing = False

    # -- compile -----------------------------------------------------------
    def compile(self, fn_or_dfg, length: Optional[int] = None,
                **kw) -> CompiledArtifact:
        kw.setdefault("fabric", self.fabric)
        kw.setdefault("backend", self.backend)
        kw.setdefault("cache", self.cache)
        if self.mapper is not None:
            kw.setdefault("mapper", self.mapper)
        return compiler.compile(fn_or_dfg, length, **kw)

    # -- dispatch ----------------------------------------------------------
    def prepare(self, artifact: CompiledArtifact,
                inputs: Dict[str, np.ndarray], *,
                streams_changed: Optional[int] = None,
                layout: Tuple[int, ...] = (),
                pe_config_words: int = 0) -> Handle:
        """Validate a request and build its :class:`Handle` WITHOUT
        queueing it — the entry point for callers that drive execution
        themselves (:meth:`iter_shots`, the ``repro.serve`` loop).

        All capability validation happens here, where the stream length is
        first known — a request that cannot run on this backend must fail
        before it is accepted anywhere, never mid-dispatch."""
        self._check(artifact)
        missing = [n for n in artifact.dfg.inputs if n not in inputs]
        if missing:
            raise ValueError(f"{artifact.name}: missing input stream(s) "
                             f"{missing}")
        if inputs:
            lengths = {int(np.asarray(v).shape[0]) for v in inputs.values()}
            if len(lengths) != 1:
                raise ValueError(
                    f"{artifact.name}: all input streams must share a "
                    f"length, got {sorted(lengths)}")
            if self.backend != "sim":
                # every shot of a plan executes at the request length:
                # partition cuts only at rate-1 signals, so a reduction's
                # shortened emission stream can never cross a shot
                # boundary (it drains to a final OUTPUT within its shot)
                (length,) = lengths
                for shot in artifact.plan.shots:
                    capabilities.check_stream_length(shot.dfg, length,
                                                     self.backend)
        if streams_changed is None:
            g = artifact.dfg
            streams_changed = len(g.inputs) + len(g.outputs)
        return Handle(artifact, inputs, streams_changed, layout,
                      pe_config_words)

    def submit(self, artifact: CompiledArtifact,
               inputs: Dict[str, np.ndarray], *,
               streams_changed: Optional[int] = None,
               layout: Tuple[int, ...] = (),
               pe_config_words: int = 0) -> Handle:
        """Queue one request; execution happens at the next ``flush()``.

        Re-entrancy contract (pinned by tests/test_engine.py): a
        ``submit()`` issued while a ``flush()`` is in progress — e.g. from
        a value-substrate callback — queues safely for the NEXT flush; it
        is never folded into the flush already running."""
        h = self.prepare(artifact, inputs, streams_changed=streams_changed,
                         layout=layout, pe_config_words=pe_config_words)
        self._queue.append(h)
        obs.set_gauge("engine.queue_depth", len(self._queue))
        return h

    def cancel(self, h: Handle) -> bool:
        """Remove a queued, not-yet-executed request. Returns whether the
        handle was actually queued (an executed or unknown handle is a
        no-op — results are never revoked)."""
        for i, q in enumerate(self._queue):
            if q is h:
                del self._queue[i]
                obs.set_gauge("engine.queue_depth", len(self._queue))
                return True
        return False

    def flush(self, on_batch=None) -> List[Handle]:
        """Execute all queued requests, batched by config class.

        On the pallas backend, consecutive same-artifact single-shot
        requests with equal stream lengths additionally dispatch as one
        lane-batched padded Pallas grid; cycle accounting still runs
        per-request through the runner (each lane occupies the model
        fabric for its own shot).

        ``on_batch``: optional batch-close hook — called once per
        config-class group, after every request of the group executed,
        as ``on_batch(config_class, handles)``. The ``repro.serve`` layer
        and tests use it to observe exactly how the scheduler grouped a
        flush without re-deriving the grouping.

        ``flush()`` is not re-entrant: a nested call (from a hook or a
        value substrate) raises ``ArtifactError`` naming the violation
        instead of double-dispatching the queue."""
        if self._flushing:
            raise ArtifactError(
                "re-entrant flush(): flush() called while a flush is "
                "already dispatching; submit() during a flush queues for "
                "the next one instead")
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        self._flushing = True
        obs.set_gauge("engine.queue_depth", 0)
        # stable group-by: classes keep first-arrival order, requests keep
        # arrival order within their class
        class_rank: Dict[str, int] = {}
        class_size: Dict[str, int] = {}
        for h in queue:
            cls = h.artifact.config_class
            class_rank.setdefault(cls, len(class_rank))
            class_size[cls] = class_size.get(cls, 0) + 1
        queue.sort(key=lambda h: class_rank[h.artifact.config_class])
        if obs.enabled():
            for n in class_size.values():
                obs.observe("engine.batch_size", n)
        current: List[Handle] = []       # the unit a raise would poison
        group: List[Handle] = []         # running config-class group (hook)
        with obs.span("schedule.flush", requests=len(queue),
                      classes=len(class_rank), backend=self.backend):
            try:
                i = 0
                while i < len(queue):
                    if on_batch is not None and group and \
                            group[0].artifact.config_class != \
                            queue[i].artifact.config_class:
                        on_batch(group[0].artifact.config_class, group)
                        group = []
                    batch = [queue[i]]
                    if self.backend == "pallas" and \
                            queue[i].artifact.n_shots == 1:
                        la = self._lane_lengths(queue[i])
                        j = i + 1
                        while j < len(queue) and \
                                self._lane_compatible(queue[i], queue[j], la):
                            batch.append(queue[j])
                            j += 1
                    outs_list = None
                    if len(batch) > 1:
                        current = batch
                        try:
                            outs_list = self._run_lanes(batch)
                        except Exception:
                            # the grid fails as a unit with no way to tell
                            # which lane is at fault: fall back to
                            # per-request dispatch so only the actually-bad
                            # request is affected — counted, so a systematic
                            # grid regression (batching silently lost) is
                            # observable in the stats
                            self.stats.lane_batch_failures += 1
                            obs.inc("engine.lane_batch_failures")
                            outs_list = None
                    if outs_list is not None:
                        self.stats.lane_batches += 1
                        self.stats.lane_requests += len(batch)
                        obs.inc("engine.lane_batches")
                        obs.observe("engine.lane_occupancy", len(batch))
                        for h, outs in zip(batch, outs_list):
                            current = [h]
                            self._execute(h, outs=outs)
                    else:
                        for h in batch:
                            current = [h]
                            self._execute(h)
                    group.extend(batch)
                    i += len(batch)
                if on_batch is not None and group:
                    on_batch(group[0].artifact.config_class, group)
            except Exception:
                # never strand accepted requests — but never retry the unit
                # that raised either (re-queuing the poisoned request would
                # wedge every flush behind it forever)
                poisoned = {id(h) for h in current}
                self._queue = [h for h in queue
                               if not h._done and id(h) not in poisoned] \
                    + self._queue
                obs.set_gauge("engine.queue_depth", len(self._queue))
                raise
            finally:
                self._flushing = False
        self.stats.flushes += 1
        obs.inc("engine.flushes")
        if obs.enabled():
            obs.set_gauge("engine.rearm_cycles_saved",
                          self.stats.config_cycles_saved)
            self.stats.publish()
        return queue

    def run(self, artifact: CompiledArtifact,
            inputs: Dict[str, np.ndarray], *,
            streams_changed: Optional[int] = None,
            layout: Tuple[int, ...] = (),
            pe_config_words: int = 0) -> Dict[str, np.ndarray]:
        """Naive per-request dispatch: execute now, assuming a cold fabric."""
        h = self.submit(artifact, inputs, streams_changed=streams_changed,
                        layout=layout, pe_config_words=pe_config_words)
        self._queue.pop()
        self.runner.invalidate_config()
        self._execute(h)
        return h.result()

    def iter_shots(self, h: Handle):
        """Execute a prepared request one shot at a time — the engine's
        **preemption points**.

        Yields ``(shot_index, n_shots)`` after each shot completes; between
        two ``next()`` calls the caller may dispatch arbitrary other work
        through this engine (the resumed shot then pays a reconfiguration,
        exactly as real preemption would). After exhaustion ``h.result()``
        holds the bit-exact outputs — intermediate shot streams live in the
        suspended generator, so interleaving never corrupts them. Cycle and
        stats accounting matches :meth:`flush` dispatching the same handle.
        """
        art = h.artifact
        paid = 0            # config cycles charged to THIS request's shots
        t0 = time.perf_counter() if obs.enabled() else 0.0
        self.stats.config_cycles_naive += art.config_cycles()
        for shot in art.plan.shots:
            self.runner.seed_mapping(shot.key, shot.mapping)
        for (key, length, layout, n_banks), tr in art.timing_traces.items():
            self.runner.seed_trace(key, length, layout, tr)
        plan = art.plan
        env = {(name, "out"): np.asarray(h.inputs[name], dtype=np.int32)
               for name in plan.dfg.inputs}
        results: Dict[str, np.ndarray] = {}
        n = plan.n_shots
        for i, shot in enumerate(plan.shots):
            prev_value_fn = self.runner.value_fn
            self.runner.value_fn = self._value_fn
            shot_before = self.runner.tally.config
            try:
                with obs.span(f"dispatch.{self.backend}", kernel=art.name,
                              config_class=art.config_class, shot=i,
                              shots=n):
                    if n == 1:
                        ins = {iname: np.asarray(h.inputs[iname],
                                                 dtype=np.int32)
                               for iname, _ in shot.inputs}
                        outs = self.runner.run_shot(
                            shot.key, shot.dfg, ins,
                            streams_changed=h.streams_changed,
                            pe_config_words=h.pe_config_words,
                            layout=h.layout, config_class=art.config_class)
                    else:
                        ins = {iname: env[sig] for iname, sig in shot.inputs}
                        outs = self.runner.run_shot(
                            shot.key, shot.dfg, ins,
                            streams_changed=len(shot.inputs) +
                            len(shot.outputs),
                            config_class=shot.key)
            finally:
                self.runner.value_fn = prev_value_fn
            # charge only this shot's config fetches — interleaved foreign
            # work between two yields must never bill this request
            paid += self.runner.tally.config - shot_before
            for oname, sig in shot.outputs:
                env[sig] = outs[oname]
            for orig, oname in shot.finals.items():
                results[orig] = outs[oname]
            if n == 1:
                h._outputs = outs
            yield i, n
        if n > 1:
            missing = [o for o in plan.dfg.outputs if o not in results]
            if missing:
                raise ArtifactError(
                    f"{art.name}: plan never produced {missing}")
            h._outputs = {o: results[o] for o in plan.dfg.outputs}
        h._done = True
        self.stats.requests += 1
        self.stats.config_cycles_paid += paid
        if t0:
            obs.observe("engine.request_latency_us",
                        (time.perf_counter() - t0) * 1e6)
            obs.inc("engine.requests")
            obs.inc("engine.config_cycles_paid", paid)
            obs.inc("engine.config_cycles_naive", art.config_cycles())
        self._harvest_traces(art)

    # -- internals ---------------------------------------------------------
    def _check(self, artifact: CompiledArtifact) -> None:
        geo = compiler.geometry_of(self.fabric)
        if artifact.geometry != geo:
            raise ArtifactError(
                f"{artifact.name}: artifact compiled for geometry "
                f"{artifact.geometry}, engine fabric is {geo}")
        # declared capability gate: diagnostics name the offending features
        capabilities.check_backend(artifact.features, self.backend,
                                   artifact.name)

    @staticmethod
    def _lane_lengths(h: Handle) -> set:
        return {np.asarray(v).shape[0] for v in h.inputs.values()}

    def _lane_compatible(self, a: Handle, b: Handle, la: set) -> bool:
        """Can ``b`` ride the same lane-batched grid as the batch head
        ``a`` (whose length set ``la`` the caller computed once)?"""
        if b.artifact.key != a.artifact.key or b.artifact.n_shots != 1:
            return False
        return self._lane_lengths(b) == la

    def _run_lanes(self, batch: List[Handle]) -> List[Dict[str, np.ndarray]]:
        """One padded Pallas grid for N same-artifact requests."""
        from repro.kernels.fabric_reduce import run_dfg_lanes
        g = batch[0].artifact.plan.shots[0].dfg
        ins = [{k: np.asarray(h.inputs[k], dtype=np.int32)
                for k in g.inputs} for h in batch]
        return run_dfg_lanes(g, ins)

    def _execute(self, h: Handle,
                 outs: Optional[Dict[str, np.ndarray]] = None) -> None:
        art = h.artifact
        before = self.runner.tally.config
        t0 = time.perf_counter() if obs.enabled() else 0.0
        self.stats.config_cycles_naive += art.config_cycles()
        for shot in art.plan.shots:
            self.runner.seed_mapping(shot.key, shot.mapping)
        for (key, length, layout, n_banks), tr in art.timing_traces.items():
            self.runner.seed_trace(key, length, layout, tr)
        prev_value_fn = self.runner.value_fn
        self.runner.value_fn = self._value_fn
        try:
            with obs.span(f"dispatch.{self.backend}", kernel=art.name,
                          config_class=art.config_class,
                          shots=art.n_shots):
                if art.n_shots == 1:
                    shot = art.plan.shots[0]
                    ins = {iname: np.asarray(h.inputs[iname], dtype=np.int32)
                           for iname, _ in shot.inputs}
                    h._outputs = self.runner.run_shot(
                        shot.key, shot.dfg, ins,
                        streams_changed=h.streams_changed,
                        pe_config_words=h.pe_config_words, layout=h.layout,
                        config_class=art.config_class, outs=outs)
                else:
                    h._outputs = art.plan.run(h.inputs, runner=self.runner)
        finally:
            self.runner.value_fn = prev_value_fn
        h._done = True
        self.stats.requests += 1
        paid = self.runner.tally.config - before
        self.stats.config_cycles_paid += paid
        if t0:
            obs.observe("engine.request_latency_us",
                        (time.perf_counter() - t0) * 1e6)
            obs.inc("engine.requests")
            obs.inc("engine.config_cycles_paid", paid)
            obs.inc("engine.config_cycles_naive", art.config_cycles())
        self._harvest_traces(art)

    def _harvest_traces(self, art: CompiledArtifact) -> None:
        """Persist timing traces the runner recorded for this artifact's
        shots: the first execution of a static-rate shot pays one cycle
        simulation, every later dispatch — in this process or any other —
        replays the trace from the artifact cache."""
        fresh = self.runner.fresh_traces()
        if not fresh:
            return
        shot_keys = {s.key for s in art.plan.shots}
        added = False
        for tkey, tr in fresh.items():
            if tkey[0] in shot_keys and tkey not in art.timing_traces:
                art.timing_traces[tkey] = tr
                added = True
        if added:
            self.cache.put(art)

    # -- observability -----------------------------------------------------
    @property
    def tally(self) -> Tally:
        return self.runner.tally

    def pending(self) -> int:
        return len(self._queue)
