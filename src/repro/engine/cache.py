"""Persistent artifact cache: memory layer over an on-disk store.

Replaces the in-memory-only compilation cache that used to live inside
``frontend/offload.py``: compiled artifacts survive the process, so the
expensive part of the pipeline (trace -> partition -> place & route, with
its randomized-restart search) is paid once per (kernel, length, geometry,
backend) *ever*, not once per process. Keys are content digests computed by
``engine/compiler.py`` (jaxpr hash or DFG structural hash, x length x
geometry x backend x schema version), so a key can never alias two
different compilation requests.

Layout: one ``<key>.pkl`` per artifact under the cache root. Writes are
atomic (tmp file + rename) so concurrent processes compiling the same
kernel race benignly. Corrupt or schema-stale files behave as misses and
are removed.

Root resolution order:
  1. explicit ``root=`` argument,
  2. ``$STRELA_CACHE_DIR``,
  3. ``~/.cache/strela/artifacts``.
``STRELA_CACHE=0`` in the environment disables the disk layer globally
(memory-only), for hermetic runs.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, Optional

from repro import obs
from repro.engine.artifact import ArtifactError, CompiledArtifact


def default_cache_root() -> str:
    env = os.environ.get("STRELA_CACHE_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "strela",
                        "artifacts")


def disk_cache_enabled() -> bool:
    return os.environ.get("STRELA_CACHE", "1") != "0"


class ArtifactCache:
    """Two-level artifact store: dict in front of a directory of pickles."""

    def __init__(self, root: Optional[str] = None,
                 memory_only: bool = False):
        # STRELA_CACHE=0 turns off the *implicit* disk layer; an explicit
        # root is a deliberate opt-in and keeps its disk store.
        self.root = root or default_cache_root()
        self.memory_only = memory_only or (root is None
                                           and not disk_cache_enabled())
        self._mem: Dict[str, CompiledArtifact] = {}
        self.hits = 0            # memory hits
        self.disk_hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.pkl")

    def get(self, key: str) -> Optional[CompiledArtifact]:
        art = self._mem.get(key)
        if art is not None:
            self.hits += 1
            obs.inc("artifact_cache.hit")
            return art
        if not self.memory_only:
            path = self._path(key)
            try:
                art = CompiledArtifact.load(path)
            except FileNotFoundError:
                art = None
            except Exception:
                # corrupt / stale entry: drop it and recompile
                try:
                    os.unlink(path)
                except OSError:
                    pass
                art = None
            if art is not None:
                if art.key != key:
                    art = None          # never serve a mislabeled artifact
                else:
                    self._mem[key] = art
                    self.disk_hits += 1
                    obs.inc("artifact_cache.disk_hit")
                    return art
        self.misses += 1
        obs.inc("artifact_cache.miss")
        return None

    def put(self, art: CompiledArtifact) -> None:
        self._mem[art.key] = art
        obs.inc("artifact_cache.put")
        if self.memory_only:
            return
        os.makedirs(self.root, exist_ok=True)
        blob = art.to_bytes()
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(art.key))
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def clear(self, disk: bool = False) -> None:
        self._mem.clear()
        if disk and not self.memory_only and os.path.isdir(self.root):
            for fn in os.listdir(self.root):
                if fn.endswith(".pkl"):
                    try:
                        os.unlink(os.path.join(self.root, fn))
                    except OSError:
                        pass

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "entries": len(self._mem)}


_default: Optional[ArtifactCache] = None


def default_cache() -> ArtifactCache:
    """Process-wide cache instance (re-resolved if the env changed)."""
    global _default
    if _default is None or _default.root != default_cache_root() \
            or _default.memory_only != (not disk_cache_enabled()):
        # no explicit root: STRELA_CACHE / STRELA_CACHE_DIR keep control
        _default = ArtifactCache()
    return _default
