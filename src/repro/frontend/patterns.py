"""Control/reduction idiom recognition for the frontend (paper Sec. III-C).

The fabric supports exactly two control patterns beyond elementwise data
flow, and this module lowers the jaxpr idioms that express them:

  * **reductions** — ``jnp.sum`` / ``jnp.prod`` / bitwise reductions over a
    whole stream, and 1-D ``jnp.dot``: lower to the ALU's immediate feedback
    accumulator (``acc_init`` + ``emit_every`` = stream length), the
    hardware mechanism behind mac1/mac3 (Fig. 7c);
  * **two-way ``lax.cond``** — lowers to BRANCH/MERGE pairs: every stream
    operand consumed by the branches is steered by a BRANCH node driven by
    the predicate, each branch sub-jaxpr is lowered on its leg (so only the
    taken side fires, unlike a mux that evaluates both), and each result is
    re-joined by a MERGE of the complementary legs. ``lax.cond`` needs a
    scalar predicate, so it is only reachable in element-mode traces (the
    tracer falls back automatically).

Handlers follow the tracer's calling convention:
``handler(lowerer, eqn, in_values) -> out_values``.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.core.isa import AluOp
from repro.frontend.tracer import (ConstVal, FrontendError, Lowerer, Value,
                                   Wire, _fold)

# reduction primitive -> (ALU op, accumulator init)
_REDUCE_OPS = {
    "reduce_sum": (AluOp.ADD, 0),
    "reduce_prod": (AluOp.MUL, 1),
    "reduce_or": (AluOp.OR, 0),
    "reduce_and": (AluOp.AND, -1),
    "reduce_xor": (AluOp.XOR, 0),
}


def _h_reduce(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    prim = eqn.primitive.name
    op, init = _REDUCE_OPS[prim]
    axes = tuple(eqn.params.get("axes", ()))
    in_shape = tuple(eqn.invars[0].aval.shape)
    (v,) = ins
    if not axes or not in_shape:
        return [v]                       # scalar-mode no-op reduction
    if len(in_shape) != 1 or axes != (0,):
        raise lw.unsupported(
            eqn, f"partial/multi-axis reduction over shape {in_shape} "
                 f"axes {axes}; only whole-stream 1-D reductions map to the "
                 f"ALU accumulator")
    if isinstance(v, ConstVal):
        acc = np.int64(init)
        from repro.core.executor import alu_eval
        for _ in range(lw.length):
            acc = np.int64(alu_eval(op, acc, v.value))
        return [ConstVal(_fold(acc))]
    return [lw.emit_alu(op, v, stem="acc", acc_init=init,
                        emit_every=lw.length)]


def _h_dot_general(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    shapes = [tuple(v.aval.shape) for v in eqn.invars]
    if (tuple(lc), tuple(rc)) != ((0,), (0,)) or lb or rb or \
            any(len(s) != 1 for s in shapes):
        raise lw.unsupported(
            eqn, f"dot_general over shapes {shapes}; only 1-D dot products "
                 f"(a single mac lane) lower to the fabric")
    a, b = ins
    prod = lw.alu(AluOp.MUL, a, b)
    if isinstance(prod, ConstVal):
        return [ConstVal(_fold(np.int64(prod.value) * lw.length))]
    return [lw.emit_alu(AluOp.ADD, prod, stem="acc", acc_init=0,
                        emit_every=lw.length)]


def _h_cond(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    branches = eqn.params["branches"]
    if len(branches) != 2:
        raise lw.unsupported(
            eqn, f"{len(branches)}-way cond (the fabric's Branch steers "
                 f"two complementary paths)")
    index, *operands = ins
    if isinstance(index, ConstVal):
        # statically-taken branch: inline it directly
        br = branches[1 if index.value else 0]
        return lw.lower_jaxpr(br.jaxpr, br.consts, operands)

    true_env: List[Value] = []
    false_env: List[Value] = []
    true_leg: Wire = None
    false_leg: Wire = None
    for v in operands:
        if isinstance(v, ConstVal):
            true_env.append(v)
            false_env.append(v)
            continue
        name = lw.fresh("br")
        lw.b.branch(name, v.node, index.node,
                    a_port=v.port, ctrl_port=index.port)
        t, f = Wire(name, "t"), Wire(name, "f")
        true_env.append(t)
        false_env.append(f)
        if true_leg is None:
            true_leg, false_leg = t, f
    if true_leg is None:
        raise lw.unsupported(
            eqn, "cond consumes no stream operands; nothing paces the "
                 "branch legs")

    t_outs = lw.lower_jaxpr(branches[1].jaxpr, branches[1].consts, true_env)
    f_outs = lw.lower_jaxpr(branches[0].jaxpr, branches[0].consts, false_env)

    outs: List[Value] = []
    for t, f in zip(t_outs, f_outs):
        if isinstance(t, ConstVal):
            t = lw.paced_const(true_leg, t.value)
        if isinstance(f, ConstVal):
            f = lw.paced_const(false_leg, f.value)
        name = lw.fresh("mg")
        lw.b.merge(name, t.node, f.node, a_port=t.port, b_port=f.port)
        outs.append(Wire(name))
    return outs


PATTERN_HANDLERS: Dict[str, Callable] = {
    **{prim: _h_reduce for prim in _REDUCE_OPS},
    "dot_general": _h_dot_general,
    "cond": _h_cond,
}
