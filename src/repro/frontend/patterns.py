"""Control/reduction idiom recognition for the frontend (paper Sec. III-C).

The fabric supports the control patterns of the elastic Branch/Merge
microarchitecture beyond elementwise data flow, and this module lowers the
jaxpr idioms that express them:

  * **reductions** — ``jnp.sum`` / ``jnp.prod`` / bitwise reductions over a
    whole stream, and 1-D ``jnp.dot``: lower to the ALU's immediate feedback
    accumulator (``acc_init`` + ``emit_every`` = stream length), the
    hardware mechanism behind mac1/mac3 (Fig. 7c);
  * **two-way ``lax.cond``** — lowers to BRANCH/MERGE pairs: every stream
    operand consumed by the branches is steered by a BRANCH node driven by
    the predicate, each branch sub-jaxpr is lowered on its leg (so only the
    taken side fires, unlike a mux that evaluates both), and each result is
    re-joined by a MERGE of the complementary legs. ``lax.cond`` needs a
    scalar predicate, so it is only reachable in element-mode traces (the
    tracer falls back automatically);
  * **``lax.while_loop`` (irregular, data-dependent loops)** — lowers to the
    gated loop schema of the paper's Fig. 4 elastic feedback: a demand-token
    *gate* admits one stream element into the loop at a time (preserving OMN
    output order), an entry MERGE joins the admitted value with the
    recirculating one, the loop predicate is evaluated on the merged carry
    and steers one BRANCH per loop variable — the taken leg recirculates
    through the body over a *recirculation back edge* (``init=None``, no
    initial token), the not-taken leg exits. The exit event mints the next
    demand token. ``lax.fori_loop`` arrives here when its trip count is
    data-dependent (JAX lowers it to ``while``);
  * **``lax.scan`` over the stream** — the loop-carried recurrence pattern
    (dither's error diffusion): carries become back edges with their initial
    value as the register init, the body fires once per element.
    ``lax.fori_loop`` with a *static* trip count arrives as a no-stream scan
    and is unrolled in place.

Handlers follow the tracer's calling convention:
``handler(lowerer, eqn, in_values) -> out_values``.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.core import dfg as D
from repro.core.isa import AluOp
from repro.frontend.tracer import (ConstVal, FinalWire, FrontendError,
                                   Lowerer, Value, Wire, _fold)

# static-trip loops (fori_loop / xs-less scan) are unrolled in place up to
# this many iterations; beyond that the graph would not place anyway
MAX_STATIC_UNROLL = 64

# reduction primitive -> (ALU op, accumulator init)
_REDUCE_OPS = {
    "reduce_sum": (AluOp.ADD, 0),
    "reduce_prod": (AluOp.MUL, 1),
    "reduce_or": (AluOp.OR, 0),
    "reduce_and": (AluOp.AND, -1),
    "reduce_xor": (AluOp.XOR, 0),
}


def _h_reduce(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    prim = eqn.primitive.name
    op, init = _REDUCE_OPS[prim]
    axes = tuple(eqn.params.get("axes", ()))
    in_shape = tuple(eqn.invars[0].aval.shape)
    (v,) = ins
    if not axes or not in_shape:
        return [v]                       # scalar-mode no-op reduction
    if len(in_shape) != 1 or axes != (0,):
        raise lw.unsupported(
            eqn, f"partial/multi-axis reduction over shape {in_shape} "
                 f"axes {axes}; only whole-stream 1-D reductions map to the "
                 f"ALU accumulator")
    if isinstance(v, ConstVal):
        acc = np.int64(init)
        from repro.core.executor import alu_eval
        for _ in range(lw.length):
            acc = np.int64(alu_eval(op, acc, v.value))
        return [ConstVal(_fold(acc))]
    return [lw.emit_alu(op, v, stem="acc", acc_init=init,
                        emit_every=lw.length)]


def _h_dot_general(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    shapes = [tuple(v.aval.shape) for v in eqn.invars]
    if (tuple(lc), tuple(rc)) != ((0,), (0,)) or lb or rb or \
            any(len(s) != 1 for s in shapes):
        raise lw.unsupported(
            eqn, f"dot_general over shapes {shapes}; only 1-D dot products "
                 f"(a single mac lane) lower to the fabric")
    a, b = ins
    prod = lw.alu(AluOp.MUL, a, b)
    if isinstance(prod, ConstVal):
        return [ConstVal(_fold(np.int64(prod.value) * lw.length))]
    return [lw.emit_alu(AluOp.ADD, prod, stem="acc", acc_init=0,
                        emit_every=lw.length)]


def _h_cond(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    branches = eqn.params["branches"]
    if len(branches) != 2:
        raise lw.unsupported(
            eqn, f"{len(branches)}-way cond (the fabric's Branch steers "
                 f"two complementary paths)")
    index, *operands = ins
    if isinstance(index, ConstVal):
        # statically-taken branch: inline it directly
        br = branches[1 if index.value else 0]
        return lw.lower_jaxpr(br.jaxpr, br.consts, operands)

    true_env: List[Value] = []
    false_env: List[Value] = []
    true_leg: Wire = None
    false_leg: Wire = None
    for v in operands:
        if isinstance(v, ConstVal):
            true_env.append(v)
            false_env.append(v)
            continue
        name = lw.fresh("br")
        lw.b.branch(name, v.node, index.node,
                    a_port=v.port, ctrl_port=index.port)
        t, f = Wire(name, "t"), Wire(name, "f")
        true_env.append(t)
        false_env.append(f)
        if true_leg is None:
            true_leg, false_leg = t, f
    if true_leg is None:
        raise lw.unsupported(
            eqn, "cond consumes no stream operands; nothing paces the "
                 "branch legs")

    t_outs = lw.lower_jaxpr(branches[1].jaxpr, branches[1].consts, true_env)
    f_outs = lw.lower_jaxpr(branches[0].jaxpr, branches[0].consts, false_env)

    outs: List[Value] = []
    for t, f in zip(t_outs, f_outs):
        if isinstance(t, ConstVal):
            t = lw.paced_const(true_leg, t.value)
        if isinstance(f, ConstVal):
            f = lw.paced_const(false_leg, f.value)
        name = lw.fresh("mg")
        lw.b.merge(name, t.node, f.node, a_port=t.port, b_port=f.port)
        outs.append(Wire(name))
    return outs


# ---------------------------------------------------------------------------
# irregular loops: lax.while_loop -> gated Branch/Merge recirculation
# ---------------------------------------------------------------------------

def _h_while(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    """Lower ``lax.while_loop`` onto the elastic loop schema (see module
    docstring). Loop variables are the cond/body closure operands (loop
    invariants, recirculated unchanged) followed by the carry."""
    p = eqn.params
    cond_cj, body_cj = p["cond_jaxpr"], p["body_jaxpr"]
    nc, nb = p["cond_nconsts"], p["body_nconsts"]
    n_carry = len(ins) - nc - nb
    entries = list(ins)

    wire_idx = [i for i, v in enumerate(entries) if isinstance(v, Wire)]
    if not wire_idx:
        raise lw.unsupported(
            eqn, "while loop consumes no stream operands; nothing paces "
                 "elements into the loop")

    # 1. demand gates: one per stream-derived loop input. A gate joins the
    # fresh element with a demand token minted by the previous element's
    # exit, so at most one element circulates at a time (output order).
    gates: Dict[int, Wire] = {}
    gate_nodes: List[str] = []
    for i in wire_idx:
        v = entries[i]
        if isinstance(v, FinalWire) or lw._rate.get(v.node, 1) != 1:
            raise lw.unsupported(
                eqn, f"loop operand {i} is a reduction output (a single "
                     f"emitted token); the loop gate needs one token per "
                     f"stream element")
        gname = lw.fresh("lgate")
        lw.b.alu(gname, AluOp.ADD, v.node, None, a_port=v.port)
        lw._rate[gname] = 1
        gates[i] = Wire(gname)
        gate_nodes.append(gname)
    pace = gates[wire_idx[0]]

    # Loop variables that circulate: stream-derived invariants (their token
    # must be re-presented each iteration) and every carry. Compile-time
    # constant invariants fold into PE constants inside cond/body instead.
    looped = [i for i, v in enumerate(entries)
              if isinstance(v, Wire) or i >= nc + nb]

    # 2. constant carry inits become paced constants off the admitted element
    entry_vals: Dict[int, Wire] = {}
    for i in looped:
        v = entries[i]
        entry_vals[i] = gates[i] if i in gates \
            else lw.paced_const(pace, v.value)

    # 3. entry merges: recirculating value (port a, attached below via a
    # recirculation back edge) has priority over the next fresh element
    merges: Dict[int, Wire] = {}
    for i in looped:
        ev = entry_vals[i]
        mname = lw.fresh("lmg")
        lw.b.merge(mname, None, ev.node, b_port=ev.port)
        lw._rate[mname] = 1
        merges[i] = Wire(mname)

    def var(i: int) -> Value:
        return merges[i] if i in merges else entries[i]

    # 4. the loop predicate fires once per iteration on the merged values
    cond_ins = [var(i) for i in range(nc)] + \
               [var(i) for i in range(nc + nb, len(entries))]
    (pred,) = lw.lower_jaxpr(cond_cj.jaxpr, cond_cj.consts, cond_ins)
    if isinstance(pred, ConstVal):
        raise lw.unsupported(
            eqn, f"loop predicate is the compile-time constant {pred.value}; "
                 f"a data-dependent loop must read its carry or an input")

    # 5. one BRANCH per circulating variable: taken leg iterates, the
    # not-taken leg exits the loop
    brs: Dict[int, str] = {}
    for i in looped:
        bname = lw.fresh("lbr")
        lw.b.branch(bname, merges[i].node, pred.node,
                    a_port=merges[i].port, ctrl_port=pred.port)
        brs[i] = bname

    def taken(i: int) -> Value:
        return Wire(brs[i], "t") if i in brs else entries[i]

    # 6. body on the taken legs (constant invariants pass straight through)
    body_ins = [taken(i) for i in range(nc, nc + nb)] + \
               [taken(i) for i in range(nc + nb, len(entries))]
    new_carries = lw.lower_jaxpr(body_cj.jaxpr, body_cj.consts, body_ins)

    # 7. recirculation back edges (no initial token): invariants straight
    # from their taken leg, carries from their body result
    t_pace = Wire(brs[looped[0]], "t")
    for i in looped:
        if i < nc + nb:
            lw.b.back_edge(brs[i], merges[i].node, "a", init=None,
                           src_port="t")
    for k, nv in enumerate(new_carries):
        if isinstance(nv, ConstVal):
            nv = lw.paced_const(t_pace, nv.value)
        lw.b.back_edge(nv.node, merges[nc + nb + k].node, "a", init=None,
                       src_port=nv.port)

    # 8. the exit event mints the next demand token (value 0, initial token
    # present so the first element is admitted)
    dem = lw.emit_alu(AluOp.MUL, Wire(brs[nc + nb], "f"), const_b=0,
                      stem="ldem")
    for gname in gate_nodes:
        lw.b.back_edge(dem.node, gname, "b", init=0)

    # 9. the while's results are the carries' exit legs
    return [Wire(brs[nc + nb + k], "f") for k in range(n_carry)]


# ---------------------------------------------------------------------------
# lax.scan: stream recurrences (back-edge carries) and static unrolling
# ---------------------------------------------------------------------------

def _h_scan(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    p = eqn.params
    closed = p["jaxpr"]
    ncon, ncar = p["num_consts"], p["num_carry"]
    length = int(p["length"])
    consts, inits, xs = ins[:ncon], ins[ncon:ncon + ncar], ins[ncon + ncar:]
    n_ys = len(eqn.outvars) - ncar
    if p.get("reverse"):
        raise lw.unsupported(
            eqn, "reverse scan; IMN streams only ascend (negative strides "
                 "would need a reversed stream copy)")

    if not xs:
        # fori_loop with a static trip count: unroll the body in place
        if length > MAX_STATIC_UNROLL:
            raise lw.unsupported(
                eqn, f"static {length}-iteration loop exceeds the "
                     f"{MAX_STATIC_UNROLL}x unroll budget")
        if n_ys:
            raise lw.unsupported(
                eqn, "unrolled static loop cannot emit per-iteration "
                     "outputs (no stream paces them)")
        vals: List[Value] = list(inits)
        for _ in range(length):
            vals = lw.lower_jaxpr(closed.jaxpr, closed.consts,
                                  list(consts) + vals)
        return vals

    # whole-stream recurrence: carries become loop-carried back edges
    if length != lw.length:
        raise lw.unsupported(
            eqn, f"scan over {length} elements inside a {lw.length}-element "
                 f"stream trace; only whole-stream scans map to back edges")
    for k, c in enumerate(consts):
        if not isinstance(c, ConstVal):
            raise lw.unsupported(
                eqn, f"loop-invariant scan operand {k} is a runtime value; "
                     f"only compile-time scalars fold into PE constants")
    for k, iv in enumerate(inits):
        if not isinstance(iv, ConstVal):
            raise lw.unsupported(
                eqn, f"carry {k} initial value is a runtime value; a back "
                     f"edge's register init must be a compile-time scalar")

    sents = [lw.fresh("@carry") for _ in range(ncar)]
    sent_set = set(sents)
    body_args: List[Value] = list(consts) + [Wire(s) for s in sents] + \
        list(xs)
    outs = lw.lower_jaxpr(closed.jaxpr, closed.consts, body_args)
    new_carries, ys = outs[:ncar], outs[ncar:]

    # a y that is the raw previous carry needs a pass-through node to own
    # the back edge (dither's error tap)
    ys = [lw.emit_alu(AluOp.ADD, y, const_b=0, stem="prev")
          if isinstance(y, Wire) and y.node in sent_set else y
          for y in ys]

    finals: List[Value] = []
    for k, nv in enumerate(new_carries):
        if isinstance(nv, ConstVal) or (isinstance(nv, Wire)
                                        and nv.node in sent_set):
            raise lw.unsupported(
                eqn, f"scan carry {k} is a constant or pass-through; fold "
                     f"the invariant out of the loop")
        init_val = _fold(inits[k].value)
        lw.b.edges = [
            D.Edge(nv.node, nv.port, e.dst, e.dst_port, True, init_val)
            if e.src == sents[k] else e
            for e in lw.b.edges]
        finals.append(FinalWire(nv.node, nv.port))
    return finals + ys


PATTERN_HANDLERS: Dict[str, Callable] = {
    **{prim: _h_reduce for prim in _REDUCE_OPS},
    "dot_general": _h_dot_general,
    "cond": _h_cond,
    "while": _h_while,
    "scan": _h_scan,
}
