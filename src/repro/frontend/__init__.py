"""repro.frontend — compile Python/JAX functions onto the STRELA fabric.

The automatic realization of the paper's Sec. VIII compiler guidelines:

  * :func:`trace`   — Python/JAX function -> ``core.dfg`` IR (tracer.py)
  * patterns        — reductions and lax.cond -> accumulator / Branch-Merge
  * :func:`plan`    — oversized DFG -> multi-shot plan (partition.py)
  * :func:`offload` — decorator: trace, cache, map, and dispatch to the
                      cycle-accurate simulator or the Pallas backend
"""
from repro.frontend.offload import (CompiledKernel, OffloadedFunction,
                                    RunInfo, offload)
from repro.frontend.partition import Plan, Shot, plan
from repro.frontend.tracer import (FrontendError, UnsupportedPrimitiveError,
                                   trace)

__all__ = [
    "CompiledKernel", "FrontendError", "OffloadedFunction", "Plan", "RunInfo",
    "Shot", "UnsupportedPrimitiveError", "offload", "plan", "trace",
]
