"""``@offload`` — run a plain Python/JAX function on the STRELA fabric.

::

    @offload                       # or @offload(backend="pallas", debug=True)
    def relu(x):
        return jnp.where(x > 0, x, 0)

    y = relu(np.arange(-4, 4, dtype=np.int32))   # traced, mapped, simulated

Each call: trace the function to a jaxpr, look the jaxpr hash up in the
compilation cache, and on a miss lower it to a DFG, place-and-route it (or
partition it into a multi-shot plan when it exceeds the fabric), then
dispatch:

  * ``backend="sim"`` (default) — the cycle-accurate ``elastic_sim``:
    numeric results straight off the simulated OMNs, II / cycle / op counts
    on ``kernel.last`` for perf work;
  * ``backend="pallas"`` — the fused Pallas kernels (throughput path):
    ``fabric_stream``-style streaming for elementwise/conditional graphs
    and ``fabric_reduce`` carry-state kernels for accumulator reductions.
    Eligibility is *feature detection* against the declared capability set
    (``engine/capabilities.py``): a kernel outside it (loop-carried state,
    recirculating while-loops, segmented reductions) fails at compile time
    with a diagnostic naming the offending feature. Single-shot pallas
    dispatch has no cycle-accurate measurement, so ``kernel.last.cycles``
    reports the engine's model estimate (config + re-arm + mapped II x
    length);
  * multi-shot plans run through ``ShotRunner`` (config + re-arm cycle
    accounting on ``kernel.last.tally``) — on the pallas backend the
    runner's *value substrate* is the fused kernel dispatcher, chaining
    per-shot pallas kernels through the IMN/OMN buffer handoff.

Compilation goes through the execution engine (``repro.engine``): the
result is a ``CompiledArtifact`` in the *persistent* artifact cache, keyed
on jaxpr hash x length x fabric geometry x backend — a warm cache survives
the process, so repeat traffic skips place & route entirely. ``fabric=``
targets a non-default geometry (e.g. ``Fabric(rows=6, cols=4)``).

``debug=True`` additionally executes the original JAX function and asserts
the fabric results match — the numpy-level reference check.

Closure semantics follow ``jax.jit``: values captured from the enclosing
scope are read at first trace (JAX caches the trace per function object);
rebinding them later does not recompile. Parameterize kernels through
arguments or build a fresh function per constant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.elastic_sim import SimResult, simulate
from repro.core.fabric import Fabric
from repro.core.mapper import Mapping
from repro.core.multishot import ShotRunner, Tally
from repro.frontend.tracer import FrontendError, trace

BACKENDS = ("sim", "pallas")


@dataclasses.dataclass
class RunInfo:
    """Cost observables of the most recent call."""

    backend: str
    n_shots: int
    sim: Optional[SimResult] = None       # single-shot sim backend
    tally: Optional[Tally] = None         # multi-shot plans
    est_cycles: Optional[int] = None      # model estimate (pallas backend)
    mapping: Optional[Mapping] = None     # placement behind ``sim``
    length: Optional[int] = None          # stream extent of the call

    @property
    def ii(self) -> float:
        if self.sim is None:
            raise FrontendError("II is only measured on the sim backend")
        return self.sim.steady_ii()

    @property
    def profile(self):
        """Per-PE/IMN/OMN utilization of the measured execution
        (``repro.obs.profiler.FabricProfile``) — sim backend only, where a
        cycle-accurate schedule exists to attribute."""
        if self.sim is None or self.mapping is None:
            raise FrontendError("profiling needs a measured simulation "
                                "(sim backend, single shot)")
        from repro.obs.profiler import profile_sim
        return profile_sim(self.mapping, self.sim, length=self.length)

    @property
    def cycles(self) -> int:
        """Measured cycles where a simulation ran; the engine's model-based
        estimate on the pallas backend — every backend reports a cost."""
        if self.sim is not None:
            return self.sim.cycles
        if self.tally is not None:
            return self.tally.total
        if self.est_cycles is not None:
            return self.est_cycles
        raise FrontendError("no timing recorded")


@dataclasses.dataclass
class CompiledKernel:
    """A lowered + mapped kernel: a cached engine artifact plus the jax
    output-structure info needed to repack results."""

    name: str
    length: int
    artifact: Any                   # engine.CompiledArtifact
    out_shapes: List[Tuple[int, ...]]
    treedef: Any
    element_mode: bool = False      # traced per-element (lax.cond kernels)

    @property
    def dfg(self) -> D.DFG:
        return self.artifact.dfg

    @property
    def plan(self) -> Any:          # frontend.partition.Plan
        return self.artifact.plan

    @property
    def mapping(self) -> Mapping:
        if self.plan.n_shots != 1:
            raise FrontendError(f"{self.name}: multi-shot plan has no single "
                                f"mapping")
        return self.plan.shots[0].mapping


class OffloadedFunction:
    """Callable wrapper produced by :func:`offload`."""

    def __init__(self, fn: Callable, backend: str = "sim",
                 debug: bool = False, name: Optional[str] = None,
                 mode: str = "auto", fabric: Optional[Fabric] = None,
                 cache: Optional[Any] = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.fn = fn
        self.backend = backend
        self.debug = debug
        self.name = name or getattr(fn, "__name__", "offloaded")
        self.mode = mode
        self.fabric = fabric or Fabric()
        self._acache = cache            # engine ArtifactCache (None = default)
        self._cache: Dict[str, CompiledKernel] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.last: Optional[RunInfo] = None
        self.__wrapped__ = fn
        self.__name__ = self.name

    # -- compilation --------------------------------------------------------
    def _arg_names(self) -> List[str]:
        import inspect
        return [p.name for p in
                inspect.signature(self.fn).parameters.values()
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]

    def compile(self, length: int) -> CompiledKernel:
        """Trace + lower + map for streams of ``length``.

        Two cache layers: a per-function dict holding the repack metadata,
        and the engine's persistent artifact cache underneath (shared across
        functions and across processes)."""
        import jax

        from repro.engine import cache as ecache
        from repro.engine import compiler as ecompiler

        geometry = ecompiler.geometry_of(self.fabric)
        key, out_shape, element_mode = ecompiler.fn_cache_key(
            self.fn, length, self.mode, self.backend, geometry,
            self._arg_names())
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        acache = self._acache if self._acache is not None \
            else ecache.default_cache()
        art = acache.get(key)
        if art is not None:
            self.cache_hits += 1            # persistent-cache hit: no P&R
        else:
            self.cache_misses += 1
            g = trace(self.fn, length, name=self.name, mode=self.mode)
            art = ecompiler.build_artifact(
                g, key, self.fabric, self.backend, name=self.name,
                length=length, element_mode=element_mode)
            acache.put(art)
        leaves, treedef = jax.tree_util.tree_flatten(out_shape)
        # an element-mode jaxpr describes one stream element: its scalar
        # outputs are full streams of ``length`` at run time
        shapes = [(length,) if element_mode else tuple(l.shape)
                  for l in leaves]
        ck = CompiledKernel(self.name, length, art, shapes, treedef,
                            element_mode)
        self._cache[key] = ck
        return ck

    # -- execution ----------------------------------------------------------
    def __call__(self, *args):
        arrays = [np.asarray(a, dtype=np.int32).reshape(-1) for a in args]
        if len(arrays) != len(self._arg_names()):
            raise TypeError(f"{self.name} expects {len(self._arg_names())} "
                            f"stream arguments, got {len(arrays)}")
        lengths = {a.shape[0] for a in arrays}
        if len(lengths) != 1:
            raise ValueError(f"{self.name}: all streams must share a length, "
                             f"got {sorted(lengths)}")
        (length,) = lengths
        ck = self.compile(length)
        inputs = dict(zip(ck.dfg.inputs, arrays))

        from repro import obs
        with obs.span("offload", kernel=self.name, backend=self.backend):
            if ck.plan.n_shots == 1:
                outs, info = self._run_single(ck, inputs)
            else:
                value_fn = None
                if self.backend == "pallas":
                    from repro.kernels.fabric_reduce import run_dfg \
                        as value_fn
                runner = ShotRunner(with_timing=True, fabric=self.fabric,
                                    value_fn=value_fn)
                outs = ck.plan.run(inputs, runner=runner)
                info = RunInfo(self.backend, ck.plan.n_shots,
                               tally=runner.tally, length=length)
        self.last = info
        result = self._pack(ck, outs)
        if self.debug:
            self._check(arrays, ck, result)
        return result

    def _run_single(self, ck: CompiledKernel, inputs):
        g = ck.dfg
        if self.backend == "pallas":
            # capability features were validated at compile time
            # (engine/capabilities.py, named diagnostics); dispatch goes to
            # the fused streaming/reduction kernels
            from repro.kernels.fabric_reduce import run_dfg
            outs = run_dfg(g, inputs)
            est = ck.artifact.model_cycles(ck.length)
            return outs, RunInfo("pallas", 1, est_cycles=est)
        sim = simulate(ck.mapping, inputs)
        return dict(sim.outputs), RunInfo("sim", 1, sim=sim,
                                          mapping=ck.mapping,
                                          length=ck.length)

    def _pack(self, ck: CompiledKernel, outs: Dict[str, np.ndarray]):
        import jax
        leaves = []
        for i, shape in enumerate(ck.out_shapes):
            arr = np.asarray(outs[f"out{i}"], dtype=np.int32)
            leaves.append(arr.reshape(()) if shape == () else arr)
        return jax.tree_util.tree_unflatten(ck.treedef, leaves)

    def _check(self, arrays, ck: CompiledKernel, result) -> None:
        import jax
        import jax.numpy as jnp
        fn = jax.vmap(self.fn) if ck.element_mode else self.fn
        ref = fn(*[jnp.asarray(a) for a in arrays])
        ref_leaves = jax.tree_util.tree_leaves(ref)
        got_leaves = jax.tree_util.tree_leaves(result)
        for i, (r, o) in enumerate(zip(ref_leaves, got_leaves)):
            r = np.asarray(r).astype(np.int32)
            if not np.array_equal(r.reshape(-1), np.asarray(o).reshape(-1)):
                raise FrontendError(
                    f"{self.name}: debug check failed on output {i}: "
                    f"fabric={np.asarray(o).reshape(-1)[:8]}... "
                    f"reference={r.reshape(-1)[:8]}...")

    def cache_info(self) -> Tuple[int, int, int]:
        return self.cache_hits, self.cache_misses, len(self._cache)


def offload(fn: Optional[Callable] = None, *, backend: str = "sim",
            debug: bool = False, name: Optional[str] = None,
            mode: str = "auto", fabric: Optional[Fabric] = None,
            cache: Optional[Any] = None):
    """Decorator: compile a Python int32-stream function onto the fabric.

    Usable bare (``@offload``) or parameterized
    (``@offload(backend="pallas", debug=True, fabric=Fabric(rows=6))``).
    """
    def wrap(f: Callable) -> OffloadedFunction:
        return OffloadedFunction(f, backend=backend, debug=debug, name=name,
                                 mode=mode, fabric=fabric, cache=cache)
    return wrap(fn) if fn is not None else wrap
