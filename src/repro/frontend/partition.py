"""Partition oversized traced DFGs into multi-shot plans (Sec. IV-B, strat. 3).

A traced graph that exceeds the 4x4 fabric (16 PEs, 4 IMNs, 4 OMNs) cannot
run as one shot. This module cuts it at *stream boundaries* — full-rate
signals whose values can round-trip through memory between fabric
executions — into an ordered list of shots, each a valid, mappable sub-DFG.
Execution goes through ``core.multishot.ShotRunner``: intermediate streams
live in the interleaved banks between shots, and the runner's config-class
accounting models the per-shot reconfiguration + stream re-arm cost exactly
as for the paper's hand-decomposed benchmarks (mm/conv2d/gemver).

Cut legality:
  * only rate-1 signals may cross a shot boundary (a reduction's output
    stream is ``length/emit_every`` tokens — re-injecting it would starve
    the joins downstream), and
  * back-edge strongly-connected components stay within one shot (loop
    state cannot round-trip through memory mid-stream).

The partitioner is greedy over clusters in topological order, verified by
the real place-and-route: a closed shot that fails ``map_dfg`` sheds
clusters until it maps (route-through PEs make pure node counting an
underestimate of fabric pressure).
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dfg as D
from repro.core.fabric import Fabric
from repro.core.mapper import Mapping, MappingError, map_dfg
from repro.core.multishot import ShotRunner
from repro.frontend.tracer import FrontendError

Sig = Tuple[str, str]      # (producer node in the original DFG, out port)


@dataclasses.dataclass
class Shot:
    """One fabric execution: a mappable sub-DFG plus its stream bindings."""

    key: str
    dfg: D.DFG
    mapping: Mapping
    inputs: List[Tuple[str, Sig]]     # shot INPUT node -> source signal
    outputs: List[Tuple[str, Sig]]    # shot OUTPUT node -> signal it carries
    finals: Dict[str, str]            # original output name -> shot OUTPUT


@dataclasses.dataclass
class Plan:
    """An ordered multi-shot decomposition of one traced DFG."""

    name: str
    dfg: D.DFG                        # the original (pre-partition) graph
    shots: List[Shot]

    @property
    def n_shots(self) -> int:
        return len(self.shots)

    def run(self, inputs: Dict[str, np.ndarray],
            runner: Optional[ShotRunner] = None,
            with_timing: bool = True) -> Dict[str, np.ndarray]:
        """Execute the plan; returns the original DFG's output streams."""
        r = runner or ShotRunner(with_timing=with_timing)
        for shot in self.shots:            # reuse compile-time mappings
            r.seed_mapping(shot.key, shot.mapping)
        env: Dict[Sig, np.ndarray] = {
            (name, "out"): np.asarray(inputs[name], dtype=np.int32)
            for name in self.dfg.inputs}
        results: Dict[str, np.ndarray] = {}
        for shot in self.shots:
            ins = {iname: env[sig] for iname, sig in shot.inputs}
            outs = r.run_shot(
                shot.key, shot.dfg, ins,
                streams_changed=len(shot.inputs) + len(shot.outputs),
                config_class=shot.key)
            for oname, sig in shot.outputs:
                env[sig] = outs[oname]
            for orig, oname in shot.finals.items():
                results[orig] = outs[oname]
        missing = [o for o in self.dfg.outputs if o not in results]
        if missing:
            raise FrontendError(f"{self.name}: plan never produced {missing}")
        return {o: results[o] for o in self.dfg.outputs}


# ---------------------------------------------------------------------------
# analysis helpers
# ---------------------------------------------------------------------------

def _functional(g: D.DFG) -> List[str]:
    return [n for n in g.topo_order()
            if g.nodes[n].kind in (D.ALU, D.CMP, D.MUX, D.BRANCH, D.MERGE)]

def _clusters(g: D.DFG, order: Sequence[str]) -> List[List[str]]:
    """Group functional nodes so loop components stay atomic: a back edge
    src->dst closes a cycle through every forward path dst ->* src, and all
    nodes on those paths carry loop state within one shot."""
    parent = {n: n for n in order}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    pos = {n: i for i, n in enumerate(order)}
    fwd: Dict[str, List[str]] = {n: [] for n in order}
    rev: Dict[str, List[str]] = {n: [] for n in order}
    for e in g.edges:
        if not e.back and e.src in pos and e.dst in pos:
            fwd[e.src].append(e.dst)
            rev[e.dst].append(e.src)

    def _reach(start: str, adj: Dict[str, List[str]]) -> set:
        seen = {start}
        stack = [start]
        while stack:
            for nxt in adj[stack.pop()]:
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen

    for e in g.back_edges():
        if e.src not in pos or e.dst not in pos:
            continue
        # the loop body: forward-reachable from the consumer AND reaching
        # the producer
        body = _reach(e.dst, fwd) & _reach(e.src, rev)
        body.update((e.src, e.dst))
        anchor = e.dst
        for n in body:
            union(anchor, n)
    groups: Dict[str, List[str]] = {}
    for n in order:
        groups.setdefault(find(n), []).append(n)
    return sorted(groups.values(), key=lambda grp: min(pos[n] for n in grp))


def _rates(g: D.DFG) -> Dict[Sig, Fraction]:
    """Token rate of every signal relative to the input streams.

    Nodes inside a data-dependent loop body (Branch/Merge recirculation)
    follow loop semantics instead of the cond semantics: the loop-exit
    BRANCH releases exactly one token per admitted element (full rate) and
    the entry MERGE passes the admitted element's rate through — so a plan
    may legally cut right after a loop, while the loop body itself stays
    atomic via the back-edge clustering."""
    loop_body = g.recirculation_nodes()
    rate: Dict[Sig, Fraction] = {}
    for n in g.topo_order():
        node = g.nodes[n]
        if node.kind == D.INPUT:
            rate[(n, "out")] = Fraction(1)
            continue
        ins = [rate.get((e.src, e.src_port), Fraction(1))
               for e in g.in_edges(n) if not e.back]
        base = min(ins) if ins else Fraction(1)
        if node.is_reduction():
            k = node.emit_every
            base = base / k if k > 1 else (Fraction(0) if k == 0 else base)
            # emit_every == length traces to Fraction(1/length) via k > 1
        if node.kind == D.BRANCH and n not in loop_body:
            # branch legs carry data-dependent sub-rate token streams (only
            # the taken side fires); a non-unit marker makes them — and
            # everything downstream until the complementary MERGE — illegal
            # cut points
            for p in ("t", "f"):
                rate[(n, p)] = base / 2
        elif node.kind == D.BRANCH:
            # loop branch: per element, the taken leg fires a data-dependent
            # number of times but the exit leg fires exactly once
            for p in ("t", "f"):
                rate[(n, p)] = base
        elif node.kind == D.MERGE and n not in loop_body:
            # the frontend only emits MERGEs joining complementary branch
            # legs, which restores the pre-branch rate
            rate[(n, "out")] = base * 2
        else:
            rate[(n, "out")] = base
    return rate


def _shot_io(g: D.DFG, members: Sequence[str]
             ) -> Tuple[List[Sig], List[Sig], List[str]]:
    """External input signals, cut output signals, and original OUTPUT
    nodes fed by ``members``."""
    mset = set(members)
    in_sigs: List[Sig] = []
    for n in members:
        for e in g.in_edges(n):
            if e.back:
                if e.src not in mset:
                    raise FrontendError(
                        f"{g.name}: loop-carried edge {e.src}->{e.dst} "
                        f"crosses a shot boundary; state cannot round-trip "
                        f"through memory")
                continue
            if e.src in mset:
                continue
            sig = (e.src, e.src_port)
            if sig not in in_sigs:
                in_sigs.append(sig)
    out_sigs: List[Sig] = []
    finals: List[str] = []
    for n in members:
        for e in g.out_edges(n):
            if e.back:
                continue
            if g.nodes[e.dst].kind == D.OUTPUT:
                finals.append(e.dst)
            elif e.dst not in mset:
                sig = (e.src, e.src_port)
                if sig not in out_sigs:
                    out_sigs.append(sig)
    return in_sigs, out_sigs, finals


def _cut_name(sig: Sig) -> str:
    node, port = sig
    return f"cut_{node}" if port == "out" else f"cut_{node}_{port}"


def _build_shot_dfg(g: D.DFG, members: Sequence[str], idx: int,
                    rate: Dict[Sig, Fraction]) -> Tuple[D.DFG, List[Tuple[str, Sig]],
                                                        List[Tuple[str, Sig]],
                                                        Dict[str, str]]:
    mset = set(members)
    in_sigs, out_sigs, finals = _shot_io(g, members)
    for sig in in_sigs + out_sigs:
        if g.nodes[sig[0]].kind != D.INPUT and rate.get(sig) != Fraction(1):
            raise FrontendError(
                f"{g.name}: cannot cut at signal {sig} (token rate "
                f"{rate.get(sig)}); only full-rate stream boundaries can "
                f"round-trip through memory between shots")
    b = D.DFG.build(f"{g.name}_s{idx}")
    name_of: Dict[Sig, str] = {}
    inputs: List[Tuple[str, Sig]] = []
    for sig in in_sigs:
        iname = sig[0] if g.nodes[sig[0]].kind == D.INPUT else _cut_name(sig)
        b.inp(iname)
        name_of[sig] = iname
        inputs.append((iname, sig))
    for n in members:
        b._add(dataclasses.replace(g.nodes[n]))
    for e in g.edges:
        if e.dst in mset:
            if e.src in mset:
                b.edges.append(D.Edge(e.src, e.src_port, e.dst, e.dst_port,
                                      e.back, e.init))
            else:
                src = name_of[(e.src, e.src_port)]
                b.edges.append(D.Edge(src, "out", e.dst, e.dst_port))
    outputs: List[Tuple[str, Sig]] = []
    finals_map: Dict[str, str] = {}
    for sig in out_sigs:
        oname = _cut_name(sig)
        b.outputs.append(oname)
        b._add(D.Node(oname, D.OUTPUT))
        b.edges.append(D.Edge(sig[0], sig[1], oname, "a"))
        outputs.append((oname, sig))
    for fout in finals:
        e = g.operand(fout, "a")
        b.outputs.append(fout)
        b._add(dataclasses.replace(g.nodes[fout]))
        b.edges.append(D.Edge(e.src, e.src_port, fout, "a"))
        outputs.append((fout, ("final", fout)))
        finals_map[fout] = fout
    shot_g = b.done()
    return shot_g, inputs, outputs, finals_map


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

def plan(g: D.DFG, fabric: Optional[Fabric] = None, restarts: int = 200,
         pe_limit: Optional[int] = None, mapper: Optional[str] = None,
         seed: Optional[int] = None) -> Plan:
    """Decompose ``g`` into mappable shots (a single shot when it fits).

    The shot-shedding *search* always maps greedily — feasibility probing
    must stay cheap — and when ``mapper`` resolves to ``"anneal"``
    (``STRELA_MAPPER``), only the finally-accepted shot mappings are
    annealed, each with its greedy mapping as the never-worse baseline."""
    from repro.core.mapper import default_mapper, default_seed
    fabric = fabric or Fabric()
    pe_limit = pe_limit if pe_limit is not None else fabric.rows * fabric.cols
    mapper = default_mapper() if mapper is None else mapper
    seed = default_seed() if seed is None else seed

    def _finalize(shot_g: D.DFG, m):
        if mapper == "anneal":
            from repro.core.opt_mapper import anneal_map
            return anneal_map(shot_g, fabric, seed=seed, baseline=m)
        return m

    # fast path: the whole graph in one shot
    if (len(g.inputs) <= fabric.n_imns and len(g.outputs) <= fabric.n_omns
            and g.n_pes_used() <= pe_limit):
        try:
            m = _finalize(g, map_dfg(g, fabric, seed=seed, restarts=restarts,
                                     optimize="greedy"))
            shot = Shot(key=g.name, dfg=g, mapping=m,
                        inputs=[(n, (n, "out")) for n in g.inputs],
                        outputs=[(o, ("final", o)) for o in g.outputs],
                        finals={o: o for o in g.outputs})
            return Plan(g.name, g, [shot])
        except MappingError:
            pass                        # fall through to partitioning

    rate = _rates(g)
    order = _functional(g)
    clusters = _clusters(g, order)
    shots: List[Shot] = []
    i = 0
    while i < len(clusters):
        # grow greedily while the cheap resource counts fit
        j = i + 1
        while j <= len(clusters):
            members = [n for cl in clusters[i:j] for n in cl]
            ins, outs, finals = _shot_io(g, members)
            if (len(members) > pe_limit or len(ins) > fabric.n_imns
                    or len(outs) + len(finals) > fabric.n_omns):
                break
            j += 1
        j = max(j - 1, i + 1)
        # close the shot; shed clusters until the cut is legal (no branch
        # legs / reduced-rate signals crossing) and it actually places & routes
        while True:
            members = [n for cl in clusters[i:j] for n in cl]
            try:
                shot_g, s_ins, s_outs, s_finals = _build_shot_dfg(
                    g, members, len(shots), rate)
                m = map_dfg(shot_g, fabric, seed=seed, restarts=restarts,
                            optimize="greedy")
                break
            except (FrontendError, MappingError) as e:
                if j - 1 <= i:
                    raise FrontendError(
                        f"{g.name}: shot {len(shots)} has no feasible "
                        f"decomposition at one cluster ({members}): {e}"
                    ) from e
                j -= 1
        shots.append(Shot(key=shot_g.name, dfg=shot_g, mapping=_finalize(
                              shot_g, m),
                          inputs=s_ins, outputs=s_outs, finals=s_finals))
        i = j

    # identity outputs (INPUT wired straight to OUTPUT) only make sense in
    # the single-shot fast path above
    for o in g.outputs:
        src = g.operand(o, "a").src
        if g.nodes[src].kind == D.INPUT:
            raise FrontendError(
                f"{g.name}: output {o} is a pass-through of input {src}; "
                f"not supported in a multi-shot plan")
    return Plan(g.name, g, shots)
