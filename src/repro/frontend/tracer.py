"""Trace Python/JAX functions into STRELA DFGs (the compiler frontend).

A kernel is an ordinary Python function over int32 streams::

    def relu(x):
        return jnp.where(x > 0, x, 0)

``trace(relu, length=N)`` runs ``jax.make_jaxpr`` over ``(N,)``-shaped int32
abstract values and lowers the resulting jaxpr equation-by-equation onto the
``core.dfg`` IR:

  * ``add/sub/mul/shift/and/or/xor``            -> ALU nodes
  * ``gt/lt/ge/le/eq/ne``                       -> CMP nodes (+ XOR-1 inverts)
  * ``select_n`` / ``jnp.where`` / ``max/min``  -> CMP + if/else MUX
  * scalar Python constants                     -> folded PE constants
  * ``reduce_sum`` / ``jnp.dot``                -> accumulator ALUs
    (see patterns.py)
  * two-way ``lax.cond``                        -> BRANCH/MERGE pairs
    (see patterns.py)
  * ``lax.while_loop`` / ``lax.fori_loop``      -> gated Branch/Merge loops
    with recirculation back edges (see patterns.py)
  * ``lax.scan`` over the stream               -> loop-carried back-edge
    recurrences (see patterns.py)

Anything else raises :class:`UnsupportedPrimitiveError` naming the offending
equation. Constant placement honours the hardware: a PE holds one constant
on operand *b*; constants on the left of non-commutative ops are rewritten
(``c - x`` becomes ``x * -1 + c``).

Two tracing modes share all of the lowering code:

  * **stream mode** (default): avals are ``(length,)`` int32 — elementwise
    ops and whole-stream reductions appear naturally;
  * **element mode**: avals are scalar ``()`` int32 — required for
    ``lax.cond`` (its predicate must be a scalar), at the cost of reductions
    (which need the stream extent). ``mode="auto"`` retries in element mode
    when stream-mode tracing dies inside ``lax.cond``.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import dfg as D
from repro.core.executor import wrap32
from repro.core.isa import AluOp, CmpOp


class FrontendError(Exception):
    """A traced function cannot be lowered onto the fabric."""


class UnsupportedPrimitiveError(FrontendError):
    """A jaxpr equation uses a primitive the fabric has no lowering for."""


@dataclasses.dataclass(frozen=True)
class Wire:
    """A value carried on a DFG signal: (producer node, output port)."""

    node: str
    port: str = "out"


@dataclasses.dataclass(frozen=True)
class FinalWire(Wire):
    """A scan's final carry: the producer emits every element but only the
    *last* token is the value (OMN last-value mode). Valid only as a kernel
    output; joining it with a stream elementwise is rejected at trace time."""


@dataclasses.dataclass(frozen=True)
class ConstVal:
    """A compile-time scalar constant (folds into a PE constant)."""

    value: int


Value = Union[Wire, ConstVal]

_COMMUTATIVE = {AluOp.ADD, AluOp.MUL, AluOp.AND, AluOp.OR, AluOp.XOR}

_SUPPORTED_NOTE = (
    "the STRELA fabric lowers int32 add/sub/mul/shift/bitwise ALU ops, "
    "eqz/gtz comparisons, select/where/max/min muxes, full-stream "
    "sum/prod/bitwise reductions, 1-D dot products, two-way lax.cond, "
    "data-dependent lax.while_loop / lax.fori_loop, and whole-stream "
    "lax.scan recurrences")


def _fold(x) -> int:
    return int(np.asarray(wrap32(x)).reshape(()))


class Lowerer:
    """Lowers one jaxpr (plus nested sub-jaxprs) into a DFGBuilder."""

    def __init__(self, name: str, length: int):
        self.name = name
        self.length = length
        self.b = D.DFG.build(name)
        self._counters: Dict[str, int] = {}
        # token rate per node: 1 = one token per stream element (full rate),
        # 0 = reduced (an accumulator emission). Joining the two starves the
        # elastic join in hardware, so it is rejected at trace time.
        self._rate: Dict[str, int] = {}

    def _join_rate(self, wires: Sequence[Optional[Wire]]) -> int:
        for w in wires:
            if isinstance(w, FinalWire):
                raise FrontendError(
                    f"{self.name}: a scan's final carry is a single "
                    f"end-of-stream value; it can only be returned as a "
                    f"kernel output, not consumed elementwise (re-using it "
                    f"needs a multi-shot plan with a re-armed PE constant)")
        rates = {self._rate.get(w.node, 1) for w in wires if w is not None}
        if len(rates) > 1:
            raise FrontendError(
                f"{self.name}: cannot join a reduction output (a single "
                f"emitted token) with a full-rate stream elementwise; "
                f"re-broadcasting a computed scalar needs a multi-shot plan "
                f"with a re-armed PE constant")
        return rates.pop() if rates else 1

    # -- naming / env helpers ----------------------------------------------
    def fresh(self, stem: str) -> str:
        k = self._counters.get(stem, 0)
        self._counters[stem] = k + 1
        return f"{stem}{k}"

    def unsupported(self, eqn, why: Optional[str] = None) -> "FrontendError":
        prim = eqn.primitive.name
        detail = f" ({why})" if why else ""
        return UnsupportedPrimitiveError(
            f"{self.name}: cannot lower primitive '{prim}'{detail} in "
            f"equation `{_eqn_str(eqn)}`; {_SUPPORTED_NOTE}")

    def value_of(self, atom, env: Dict[Any, Value]) -> Value:
        from jax._src.core import Literal
        if isinstance(atom, Literal):
            return ConstVal(_fold(atom.val))
        return env[atom]

    # -- node emission ------------------------------------------------------
    def emit_alu(self, op: AluOp, a: Wire, b: Optional[Wire] = None,
                 const_b: Optional[int] = None, *, stem: Optional[str] = None,
                 acc_init: Optional[int] = None, emit_every: int = 1) -> Wire:
        name = self.fresh(stem or op.name.lower())
        rate = self._join_rate([a, b])
        self.b.alu(name, op, a.node, b.node if b is not None else None,
                   const_b=const_b, acc_init=acc_init, emit_every=emit_every,
                   a_port=a.port, b_port=b.port if b is not None else "out")
        self._rate[name] = 0 if (acc_init is not None
                                 and emit_every != 1) else rate
        return Wire(name)

    def emit_cmp(self, op: CmpOp, a: Wire, b: Optional[Wire] = None,
                 const_b: Optional[int] = None) -> Wire:
        name = self.fresh("cmp")
        self._rate[name] = self._join_rate([a, b])
        self.b.cmp(name, op, a.node, b.node if b is not None else None,
                   const_b=const_b, a_port=a.port,
                   b_port=b.port if b is not None else "out")
        return Wire(name)

    def emit_mux(self, a: Wire, b: Optional[Wire], ctrl: Wire,
                 const_b: Optional[int] = None) -> Wire:
        name = self.fresh("mux")
        self._rate[name] = self._join_rate([a, b, ctrl])
        self.b.mux(name, a.node, b.node if b is not None else None, ctrl.node,
                   a_port=a.port, b_port=b.port if b is not None else "out",
                   ctrl_port=ctrl.port)
        if b is None:
            self.b.nodes[name].value = const_b
        return Wire(name)

    # -- arithmetic with constant discipline --------------------------------
    def alu(self, op: AluOp, a: Value, b: Value) -> Value:
        """Lower ``op(a, b)`` folding/commuting constants onto operand b."""
        from repro.core.executor import alu_eval
        if isinstance(a, ConstVal) and isinstance(b, ConstVal):
            return ConstVal(_fold(alu_eval(op, a.value, b.value)))
        if isinstance(b, ConstVal):
            return self.emit_alu(op, a, const_b=b.value)
        if isinstance(a, ConstVal):
            if op in _COMMUTATIVE:
                return self.emit_alu(op, b, const_b=a.value)
            if op == AluOp.SUB:
                # c - x  ->  x * -1 (+ c unless c == 0): the PE constant
                # lives on operand b, so the left-constant form is rewritten.
                neg = self.emit_alu(AluOp.MUL, b, const_b=_fold(-1))
                if a.value == 0:
                    return neg
                return self.emit_alu(AluOp.ADD, neg, const_b=a.value)
            raise FrontendError(
                f"{self.name}: constant on the left of non-commutative "
                f"{op.name} is not expressible as a PE constant")
        return self.emit_alu(op, a, b)

    def lnot(self, v: Value) -> Value:
        """Logical not of a 0/1 value (comparator output)."""
        if isinstance(v, ConstVal):
            return ConstVal(0 if v.value else 1)
        return self.emit_alu(AluOp.XOR, v, const_b=1, stem="not")

    def gtz(self, a: Value, b: Value) -> Value:
        """a > b as a CMP node (GTZ over a - b)."""
        if isinstance(a, ConstVal) and isinstance(b, ConstVal):
            return ConstVal(int(a.value > b.value))
        if isinstance(a, Wire) and isinstance(b, ConstVal):
            if b.value == 0:
                return self.emit_cmp(CmpOp.GTZ, a)
            return self.emit_cmp(CmpOp.GTZ, a, const_b=b.value)
        if isinstance(a, Wire) and isinstance(b, Wire):
            return self.emit_cmp(CmpOp.GTZ, a, b)
        # const > wire: compare the rewritten difference directly
        diff = self.alu(AluOp.SUB, a, b)
        return self.emit_cmp(CmpOp.GTZ, diff)

    def eqz(self, a: Value, b: Value) -> Value:
        if isinstance(a, ConstVal) and isinstance(b, ConstVal):
            return ConstVal(int(a.value == b.value))
        if isinstance(a, ConstVal):
            a, b = b, a
        if isinstance(b, ConstVal):
            if b.value == 0:
                return self.emit_cmp(CmpOp.EQZ, a)
            return self.emit_cmp(CmpOp.EQZ, a, const_b=b.value)
        return self.emit_cmp(CmpOp.EQZ, a, b)

    def select(self, pred: Value, on_false: Value, on_true: Value) -> Value:
        """if/else mux: ``pred ? on_true : on_false`` (select_n case order)."""
        if isinstance(pred, ConstVal):
            return on_true if pred.value else on_false
        if isinstance(on_true, Wire):
            if isinstance(on_false, Wire):
                return self.emit_mux(on_true, on_false, pred)
            return self.emit_mux(on_true, None, pred, const_b=on_false.value)
        if isinstance(on_false, Wire):
            # true case is the constant: invert the predicate so the wire
            # rides the mux's a input and the constant folds onto b.
            inv = self.lnot(pred)
            return self.emit_mux(on_false, None, inv, const_b=on_true.value)
        # both cases constant:  f + pred * (t - f)
        span = _fold(on_true.value - on_false.value)
        scaled = self.alu(AluOp.MUL, pred, ConstVal(span))
        return self.alu(AluOp.ADD, scaled, ConstVal(on_false.value))

    def maximum(self, a: Value, b: Value) -> Value:
        if isinstance(a, ConstVal) and isinstance(b, ConstVal):
            return ConstVal(max(a.value, b.value))
        if isinstance(a, ConstVal):
            a, b = b, a
        c = self.gtz(a, b)
        return self.select(c, b, a)

    def minimum(self, a: Value, b: Value) -> Value:
        if isinstance(a, ConstVal) and isinstance(b, ConstVal):
            return ConstVal(min(a.value, b.value))
        if isinstance(a, ConstVal):
            a, b = b, a
        c = self.gtz(a, b)
        return self.select(c, a, b)

    def paced_const(self, pace: Wire, value: int) -> Wire:
        """A constant token stream paced by ``pace`` (one token out per token
        in): x*0 + c. Needed when a lax.cond branch returns a constant."""
        zero = self.emit_alu(AluOp.MUL, pace, const_b=0, stem="pace")
        if value == 0:
            return zero
        return self.emit_alu(AluOp.ADD, zero, const_b=_fold(value))

    # -- jaxpr walking ------------------------------------------------------
    def lower_jaxpr(self, jaxpr, consts: Sequence[Any],
                    args: Sequence[Value]) -> List[Value]:
        env: Dict[Any, Value] = {}
        if len(jaxpr.constvars) != len(consts):
            raise FrontendError(f"{self.name}: constvar/const mismatch")
        for var, c in zip(jaxpr.constvars, consts):
            arr = np.asarray(c)
            if arr.ndim != 0:
                raise FrontendError(
                    f"{self.name}: captured non-scalar constant of shape "
                    f"{arr.shape}; only scalar closure constants fold into "
                    f"PE constants")
            env[var] = ConstVal(_fold(arr))
        for var, val in zip(jaxpr.invars, args):
            env[var] = val
        for eqn in jaxpr.eqns:
            self.lower_eqn(eqn, env)
        return [self.value_of(v, env) for v in jaxpr.outvars]

    def lower_eqn(self, eqn, env: Dict[Any, Value]) -> None:
        prim = eqn.primitive.name
        handler = _HANDLERS.get(prim)
        if handler is None:
            from repro.frontend import patterns
            handler = patterns.PATTERN_HANDLERS.get(prim)
        if handler is None:
            raise self.unsupported(eqn)
        outs = handler(self, eqn, [self.value_of(v, env) for v in eqn.invars])
        if len(outs) != len(eqn.outvars):
            raise AssertionError(f"handler for {prim} returned {len(outs)} "
                                 f"values for {len(eqn.outvars)} outvars")
        for var, val in zip(eqn.outvars, outs):
            env[var] = val

    # -- graph finishing ----------------------------------------------------
    def finish(self, out_vals: Sequence[Value],
               input_names: Sequence[str]) -> D.DFG:
        for i, val in enumerate(out_vals):
            if isinstance(val, ConstVal):
                raise FrontendError(
                    f"{self.name}: output {i} is the compile-time constant "
                    f"{val.value}; a kernel output must depend on a stream")
            self.b.out(f"out{i}", val.node, src_port=val.port)
            if isinstance(val, FinalWire):
                # scan final carry: OMN stores the last value (stride-0)
                self.b.nodes[f"out{i}"].emit_every = 0
        self._prune(input_names)
        return self.b.done()

    def _prune(self, input_names: Sequence[str]) -> None:
        """Drop nodes with no path to an OUTPUT (dead jaxpr code)."""
        b = self.b
        live = set(b.outputs)
        stack = list(b.outputs)
        rev: Dict[str, List[str]] = {}
        for e in b.edges:
            rev.setdefault(e.dst, []).append(e.src)
        while stack:
            n = stack.pop()
            for p in rev.get(n, ()):
                if p not in live:
                    live.add(p)
                    stack.append(p)
        dead_inputs = [n for n in input_names if n not in live]
        if dead_inputs:
            raise FrontendError(
                f"{self.name}: stream input(s) {dead_inputs} are never used "
                f"by the function; every IMN stream must reach an output")
        b.nodes = {n: nd for n, nd in b.nodes.items() if n in live}
        b.edges = [e for e in b.edges if e.src in live and e.dst in live]


# ---------------------------------------------------------------------------
# elementwise primitive handlers
# ---------------------------------------------------------------------------

def _simple_alu(op: AluOp):
    def h(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
        return [lw.alu(op, ins[0], ins[1])]
    return h


def _h_shift(op: AluOp):
    def h(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
        a, b = ins
        if isinstance(a, ConstVal) and isinstance(b, Wire):
            raise lw.unsupported(eqn, "constant shifted by a stream")
        return [lw.alu(op, a, b)]
    return h


def _h_neg(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    return [lw.alu(AluOp.MUL, ins[0], ConstVal(_fold(-1)))]


def _h_integer_pow(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    y = int(eqn.params["y"])
    (x,) = ins
    if y < 1 or y > 8:
        raise lw.unsupported(eqn, f"exponent {y} out of the unrolled range")
    acc = x
    for _ in range(y - 1):
        acc = lw.alu(AluOp.MUL, acc, x)
    return [acc]


def _h_square(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    return [lw.alu(AluOp.MUL, ins[0], ins[0])]


def _h_gt(lw, eqn, ins):
    return [lw.gtz(ins[0], ins[1])]


def _h_lt(lw, eqn, ins):
    return [lw.gtz(ins[1], ins[0])]


def _h_ge(lw, eqn, ins):
    return [lw.lnot(lw.gtz(ins[1], ins[0]))]


def _h_le(lw, eqn, ins):
    return [lw.lnot(lw.gtz(ins[0], ins[1]))]


def _h_eq(lw, eqn, ins):
    return [lw.eqz(ins[0], ins[1])]


def _h_ne(lw, eqn, ins):
    return [lw.lnot(lw.eqz(ins[0], ins[1]))]


def _h_select_n(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    if len(ins) != 3:
        raise lw.unsupported(eqn, f"{len(ins) - 1}-way select (fabric muxes "
                             f"are two-way)")
    pred, case_f, case_t = ins
    return [lw.select(pred, case_f, case_t)]


def _h_max(lw, eqn, ins):
    return [lw.maximum(ins[0], ins[1])]


def _h_min(lw, eqn, ins):
    return [lw.minimum(ins[0], ins[1])]


def _h_clamp(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    lo, x, hi = ins
    return [lw.minimum(lw.maximum(x, lo), hi)]


def _h_alias(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    return [ins[0]]


def _h_broadcast(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
    (v,) = ins
    if isinstance(v, ConstVal):
        return [v]
    src_shape = eqn.invars[0].aval.shape
    dst_shape = eqn.outvars[0].aval.shape
    if src_shape == dst_shape:
        return [v]
    raise lw.unsupported(
        eqn, f"broadcast of a runtime value from {src_shape} to {dst_shape} "
             f"(re-broadcasting a computed scalar needs a multi-shot plan "
             f"with a re-armed PE constant)")


def _h_inline_call(param_key: str):
    """Inline pjit/closed_call/custom_jvp-style sub-jaxprs."""
    def h(lw: Lowerer, eqn, ins: List[Value]) -> List[Value]:
        closed = eqn.params[param_key]
        if param_key == "call_jaxpr" and not hasattr(closed, "jaxpr"):
            # custom_jvp_call in some versions stores an open jaxpr
            return lw.lower_jaxpr(closed, (), ins)
        n_ins = len(ins)
        if eqn.primitive.name == "custom_jvp_call":
            # trailing invars may be jvp residuals; sub-jaxpr decides
            n_ins = len(closed.jaxpr.invars)
        return lw.lower_jaxpr(closed.jaxpr, closed.consts, ins[:n_ins])
    return h


_HANDLERS: Dict[str, Callable] = {
    "add": _simple_alu(AluOp.ADD),
    "sub": _simple_alu(AluOp.SUB),
    "mul": _simple_alu(AluOp.MUL),
    "and": _simple_alu(AluOp.AND),
    "or": _simple_alu(AluOp.OR),
    "xor": _simple_alu(AluOp.XOR),
    "shift_left": _h_shift(AluOp.SHL),
    "shift_right_arithmetic": _h_shift(AluOp.SHR),
    "neg": _h_neg,
    "integer_pow": _h_integer_pow,
    "square": _h_square,
    "gt": _h_gt,
    "lt": _h_lt,
    "ge": _h_ge,
    "le": _h_le,
    "eq": _h_eq,
    "ne": _h_ne,
    "select_n": _h_select_n,
    "max": _h_max,
    "min": _h_min,
    "clamp": _h_clamp,
    "convert_element_type": _h_alias,
    "stop_gradient": _h_alias,
    "copy": _h_alias,
    "broadcast_in_dim": _h_broadcast,
    "reshape": _h_alias,
    "pjit": _h_inline_call("jaxpr"),
    "closed_call": _h_inline_call("call_jaxpr"),
    "custom_jvp_call": _h_inline_call("call_jaxpr"),
}


def _eqn_str(eqn) -> str:
    try:
        s = str(eqn)
    except Exception:   # pragma: no cover - jaxpr printing is best-effort
        s = f"{eqn.primitive.name}(...)"
    s = " ".join(s.split())
    return s if len(s) <= 200 else s[:197] + "..."


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def trace(fn: Callable, length: int, *, name: Optional[str] = None,
          mode: str = "auto") -> D.DFG:
    """Trace ``fn`` over int32 streams of ``length`` into a validated DFG.

    ``mode``: "stream" traces over ``(length,)`` avals (reductions work),
    "element" over scalar avals (``lax.cond`` works), "auto" tries stream
    then falls back to element when tracing fails on a scalar-only
    primitive. Raises :class:`UnsupportedPrimitiveError` (with the offending
    equation) or :class:`FrontendError` for structural problems.
    """
    import jax
    import jax.numpy as jnp

    kname = name or getattr(fn, "__name__", "traced")
    try:
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
        arg_names = [p.name for p in params]
    except (TypeError, ValueError):
        raise FrontendError(f"{kname}: cannot inspect function signature")
    if not arg_names:
        raise FrontendError(f"{kname}: kernel takes no stream arguments")

    def _make_jaxpr(shape):
        avals = [jax.ShapeDtypeStruct(shape, jnp.int32) for _ in arg_names]
        return jax.make_jaxpr(fn)(*avals)

    if mode not in ("auto", "stream", "element"):
        raise ValueError(f"bad trace mode {mode!r}")
    closed = None
    if mode in ("auto", "stream"):
        try:
            closed = _make_jaxpr((length,))
        except TypeError:
            # lax.cond (and friends) demand scalar operands; in auto mode
            # retry the per-element trace, which lowers cond to Branch/Merge
            if mode == "stream":
                raise
    if closed is None:
        closed = _make_jaxpr(())

    lw = Lowerer(kname, length)
    args: List[Value] = []
    for aname in arg_names:
        lw.b.inp(aname)
        args.append(Wire(aname))
    outs = lw.lower_jaxpr(closed.jaxpr, closed.consts, args)
    return lw.finish(outs, arg_names)
