"""AdamW with cosine / WSD (warmup-stable-decay, MiniCPM) schedules.

Self-contained (no optax): states are simple pytrees so the checkpoint
layer and ZeRO-1 partitioning rules can treat them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(jax.tree_util.tree_map(zeros, params),
                          jax.tree_util.tree_map(zeros, params),
                          jnp.zeros((), jnp.int32))

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> Tuple[Any, AdamWState]:
        count = state.count + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)
        lr = self.lr(count)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * gf * gf
            step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(new_m, new_v, count)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, base_lr * cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.01) -> Callable:
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup,
    long flat stage, sharp (exponential-ish) decay tail."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        in_decay = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = base_lr * jnp.power(final_frac, in_decay)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, base_lr, dec))
    return lr


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads), norm
