"""Gradient compression for cross-pod data parallelism.

int8 block-quantized all-reduce with **error feedback** (residual carried
to the next step, so compression error does not bias convergence —
Seide et al. / Karimireddy et al.). Applied around the DP gradient
reduction: with 2 pods over DCI links this cuts the cross-pod gradient
traffic 4x (bf16 -> int8 payload + fp32 scale per block).

Used as a pure transform: the train step stays a single pjit program; XLA
reduces the int8 payload over the 'pod' axis (sum in int32).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_decompress(g: jax.Array, err: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback round: returns (g_hat, new_err) with
    g_hat = Q(g + err), new_err = (g + err) - g_hat."""
    target = g.astype(jnp.float32) + err
    q, scale = _quantize(target)
    g_hat = _dequantize(q, scale, g.shape, g.size)
    return g_hat.astype(g.dtype), target - g_hat


def init_error(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply(grads: Any, err_state: Any) -> Tuple[Any, Any]:
    """Compress every gradient leaf with error feedback."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e
