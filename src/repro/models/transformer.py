"""Dense / MoE decoder-only transformer (llama-family), scan-over-layers.

Covers: minicpm-2b, internlm2-20b, qwen1.5-4b, yi-9b (dense),
llama4-scout / granite (MoE via ``moe.py``), internvl2-76b (vlm: patch
embeddings prepended to the token embeddings — frontend stubbed per the
assignment).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.moe import moe_apply, moe_init
from repro.runtime.partition import shard


def _attn_cfg(cfg: ArchConfig) -> L.AttnCfg:
    return L.AttnCfg(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                     cfg.qkv_bias, cfg.rope_theta,
                     impl=cfg.attention_impl, chunk=cfg.attention_chunk)


def _layer_init(key, cfg: ArchConfig) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), cfg.jdtype),
         "ln2": jnp.ones((cfg.d_model,), cfg.jdtype),
         "attn": L.attn_init(k1, _attn_cfg(cfg), cfg.jdtype)}
    if cfg.moe is not None:
        p["moe"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.moe, cfg.jdtype)
    else:
        p["mlp"] = L.mlp_init(k2, L.MlpCfg(cfg.d_model, cfg.d_ff,
                                           cfg.activation), cfg.jdtype)
    return p


def init_params(key, cfg: ArchConfig) -> Dict:
    kl, ke, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p = {"embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
         "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
         "layers": layers}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab_padded, cfg.jdtype)
    return p


def _block(cfg: ArchConfig, lp: Dict, x: jax.Array, positions: jax.Array,
           cache: Optional[Tuple[jax.Array, jax.Array]] = None,
           cache_len: Optional[jax.Array] = None):
    h, new_cache = L.attention(lp["attn"], _attn_cfg(cfg),
                               L.rmsnorm(x, lp["ln1"]), positions,
                               cache, cache_len)
    x = x + h * cfg.residual_scale
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        h, aux = moe_apply(lp["moe"], cfg.moe, cfg.d_ff,
                           L.rmsnorm(x, lp["ln2"]), impl=cfg.moe_impl)
    else:
        h = L.mlp(lp["mlp"], L.MlpCfg(cfg.d_model, cfg.d_ff, cfg.activation),
                  L.rmsnorm(x, lp["ln2"]))
    x = x + h * cfg.residual_scale
    return x, new_cache, aux


def forward(params: Dict, cfg: ArchConfig,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            caches: Optional[Tuple[jax.Array, jax.Array]] = None,
            cache_len: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Optional[Tuple], jax.Array]:
    """Returns (logits, new_caches, aux_loss).

    tokens (B, S) and/or embeds (B, P, D) — vlm prepends patch embeds.
    caches: stacked (L, B, S_max, n_kv, hd) x2 for decode.
    """
    if tokens is not None:
        x = params["embed"][tokens]
        if cfg.tie_embeddings:
            x = x * (cfg.d_model ** 0.5) if cfg.arch_id.startswith("minicpm") else x
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    else:
        x = embeds
    B, S, _ = x.shape
    x = shard(x, P(("pod", "data"), None, None))
    base = cache_len if cache_len is not None else 0
    positions = base + jnp.arange(S)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S))

    block = _block
    if cfg.remat:
        block = jax.checkpoint(_block, static_argnums=(0,))

    if caches is None:
        def body(carry, lp):
            x, aux = carry
            x, _, a = block(cfg, lp, x, positions)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
        new_caches = None
    else:
        def body(carry, scanned):
            x, aux = carry
            lp, (ck, cv) = scanned
            x, nc, a = block(cfg, lp, x, positions, (ck, cv), cache_len)
            return (x, aux + a), nc
        (x, aux), new_caches = lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], caches))

    x = L.rmsnorm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    logits = shard(logits, P(("pod", "data"), None, "model"))
    return logits, new_caches, aux


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
