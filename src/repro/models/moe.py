"""Mixture-of-Experts layer with capacity-based sort dispatch (EP-ready).

Top-k routing -> sort-by-expert -> capacity-bounded scatter into an
(E, C, D) dispatch tensor sharded over the 'model' axis (expert parallel)
-> stacked-expert einsum -> weighted combine. Aux load-balancing loss per
Shazeer et al. Overflowed tokens are dropped (capacity_factor bounds them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoESpec
from repro.models import layers as L
from repro.runtime.partition import shard


def moe_init(key, d_model: int, d_ff: int, spec: MoESpec,
             dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 6)
    E = spec.n_experts
    scale = (2.0 / (d_model + d_ff)) ** 0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E), jnp.float32)
                   * 0.02).astype(jnp.float32),
        "w_experts_gate": (jax.random.normal(ks[1], (E, d_model, d_ff),
                                             jnp.float32) * scale).astype(dtype),
        "w_experts_up": (jax.random.normal(ks[2], (E, d_model, d_ff),
                                           jnp.float32) * scale).astype(dtype),
        "w_experts_down": (jax.random.normal(ks[3], (E, d_ff, d_model),
                                             jnp.float32) * scale).astype(dtype),
    }
    if spec.shared_expert:
        p["shared"] = L.mlp_init(ks[4], L.MlpCfg(d_model, d_ff), dtype)
    return p


def moe_apply(p: Dict, spec: MoESpec, d_ff: int, x: jax.Array,
              impl: str = "gspmd") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). impl: 'gspmd' (global scatter,
    baseline) or 'shard_map' (local dispatch + psum-combine EP — §Perf A2)."""
    from repro.runtime.partition import axis_size, current_mesh
    if impl == "shard_map" and current_mesh() is not None \
            and axis_size("model") > 1:
        return _moe_shard_map(p, spec, d_ff, x)
    B, S, D = x.shape
    N = B * S
    E, k = spec.n_experts, spec.top_k
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss: E * mean(density_e * mean_prob_e)
    density = jnp.mean(
        jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1), axis=0)
    aux = spec.aux_coef * E * jnp.mean(density * probs.mean(0))

    # ---- sort-based capacity dispatch ----
    C = max(int(spec.capacity_factor * N * k / E), 1)
    flat_e = gate_idx.reshape(-1)                            # (N*k,)
    flat_t = jnp.repeat(jnp.arange(N), k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within each expert's run
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(N * k) - starts[se]
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    dispatch = jnp.zeros((E, C, D), dtype=x.dtype)
    src = jnp.where(keep[:, None], xf[st], 0)
    dispatch = dispatch.at[se, pos_c].add(src)
    dispatch = shard(dispatch, P("model", "data", None))

    h_g = jnp.einsum("ecd,edf->ecf", dispatch, p["w_experts_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", dispatch, p["w_experts_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    h = shard(h, P("model", "data", None))
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_experts_down"])

    # ---- combine ----
    gathered = eout[se, pos_c]                               # (N*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * sw[:, None].astype(x.dtype)
    out = jnp.zeros((N, D), dtype=x.dtype).at[st].add(contrib)
    out = out.reshape(B, S, D)

    if "shared" in p:
        out = out + L.mlp(p["shared"], L.MlpCfg(D, d_ff), x)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (§Perf A2)
#
# The GSPMD scatter/gather formulation forces the partitioner to all-gather
# the full (N*k, D) dispatch source on every expert shard and to all-reduce
# full (N, D) buffers for the scatter-adds — TB-scale collectives per layer
# (granite baseline: 610 s collective term). Here tokens stay local to their
# data shard (they are already replicated across the model axis), each model
# shard dispatches *locally* to its own expert slice, and the only
# communication is one psum of the (N_loc, D) combined output over 'model' —
# identical in shape to a dense Megatron-TP MLP reduction.
# ---------------------------------------------------------------------------

def _moe_shard_map(p: Dict, spec: MoESpec, d_ff: int, x: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    from jax.experimental.shard_map import shard_map
    from repro.runtime.partition import axis_size, current_mesh
    mesh = current_mesh()
    names = tuple(mesh.axis_names)
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    msize = axis_size("model")
    E, k = spec.n_experts, spec.top_k
    E_pad = -(-E // msize) * msize
    E_loc = E_pad // msize
    B, S, D = x.shape

    def padE(w):
        return jnp.pad(w, ((0, E_pad - E),) + ((0, 0),) * (w.ndim - 1))

    wg, wu, wd = padE(p["w_experts_gate"]), padE(p["w_experts_up"]), \
        padE(p["w_experts_down"])
    router = p["router"]

    def local_fn(xl, router, wg, wu, wd):
        midx = jax.lax.axis_index("model")
        Bl, Sl, _ = xl.shape
        N = Bl * Sl
        xf = xl.reshape(N, D)
        logits = xf.astype(jnp.float32) @ router            # (N, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        density = jnp.mean(
            jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1), axis=0)
        aux = spec.aux_coef * E * jnp.mean(density * probs.mean(0))
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)

        C = max(int(spec.capacity_factor * N * k / E), 1)
        flat_e = gate_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(N), k)
        flat_w = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(se, length=E_pad)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(N * k) - starts[se]
        keep = pos < C
        pos_c = jnp.where(keep, pos, 0)

        dispatch = jnp.zeros((E_pad, C, D), dtype=x.dtype)
        src = jnp.where(keep[:, None], xf[st], 0)
        dispatch = dispatch.at[se, pos_c].add(src)
        mine = jax.lax.dynamic_slice_in_dim(dispatch, midx * E_loc, E_loc, 0)

        h_g = jnp.einsum("ecd,edf->ecf", mine, wg)
        h_u = jnp.einsum("ecd,edf->ecf", mine, wu)
        h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
        eout = jnp.einsum("ecf,efd->ecd", h, wd)             # (E_loc, C, D)

        lo = midx * E_loc
        in_range = (se >= lo) & (se < lo + E_loc) & keep
        rows = eout[jnp.clip(se - lo, 0, E_loc - 1), pos_c]  # (N*k, D)
        contrib = jnp.where(in_range[:, None], rows, 0) \
            * sw[:, None].astype(x.dtype)
        y = jnp.zeros((N, D), dtype=x.dtype).at[st].add(contrib)
        y = jax.lax.psum(y, "model")
        return y.reshape(Bl, Sl, D), aux

    from jax.sharding import PartitionSpec as Ps
    bspec = Ps(batch_axes if batch_axes else None, None, None)
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(bspec, Ps(None, None), Ps("model", None, None),
                  Ps("model", None, None), Ps("model", None, None)),
        out_specs=(bspec, Ps()),
        check_rep=False,
    )(x, router, wg, wu, wd)
    if "shared" in p:
        out = out + L.mlp(p["shared"], L.MlpCfg(D, d_ff), x)
    return out, aux
