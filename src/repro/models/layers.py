"""Shared transformer building blocks (pure-functional JAX).

Conventions:
  * params are pytrees of jnp arrays; layer stacks carry a leading L axis
    and run under ``lax.scan`` (keeps HLO size O(1) in depth — essential
    for compiling 80-layer configs against 512 host devices);
  * activations: (batch, seq, d_model); attention inner: (batch, seq,
    heads, head_dim);
  * sharding is injected via ``with_sharding_constraint`` using the axis
    names from ``repro.runtime.partition`` (no-ops outside a mesh);
  * dtype policy: parameters/activations bf16, reductions & softmax fp32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.runtime.partition import MODEL as MODEL_AXIS, axis_size, shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(x.dtype) * g


def layernorm(x: jax.Array, g: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps)).astype(x.dtype) * g + b


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    xr2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional bias — qwen-style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    impl: str = "full"        # full | chunked (online-softmax k-block scan)
    chunk: int = 1024


def attn_init(key, cfg: AttnCfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.head_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.head_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.head_dim,), dtype)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def attention(p: Params, cfg: AttnCfg, x: jax.Array,
              positions: jax.Array,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_len: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA attention. Training: kv_cache None. Decode: x is the new token
    block; kv_cache (k, v) of shape (b, S_max, n_kv, hd) is updated at
    ``cache_len``."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv_heads, cfg.head_dim)
    q = shard(q, P(("pod", "data"), None, "model", None))
    k = shard(k, P(("pod", "data"), None, "model", None))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_len,
                                             axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_len,
                                             axis=1)
        k_all, v_all = ck, cv
        new_cache = (ck, cv)
        kv_pos = (jnp.arange(ck.shape[1])[None, :]
                  if kv_positions is None else kv_positions)
        kv_len_mask = (jnp.arange(ck.shape[1])[None, :] < cache_len + s)
    else:
        k_all, v_all = k, v
        new_cache = None
        kv_pos = positions
        kv_len_mask = None

    # flat-head formulation: kv heads broadcast to the full head count so
    # every intermediate shards cleanly as (batch, 'model'-heads, q, k) —
    # the grouped 5-D form (kv x group) cannot shard 16-way when
    # kv*group != 16k and triggers SPMD full rematerialization.
    group = cfg.n_heads // cfg.n_kv_heads
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k_all, group, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_all, group, axis=2).astype(jnp.float32)
    # TP layout for attention intermediates: heads over 'model' when there
    # are at least as many heads as shards (GSPMD pads 24->32 etc.; §Perf A1
    # showed q-dim sharding triggers TB-scale backward all-gathers);
    # q-dim (sequence-parallel) only when heads < shards (whisper's 8).
    # Softmax reduces over k, which stays unsharded either way.
    msize = max(axis_size(MODEL_AXIS), 1)
    if cfg.n_heads % msize == 0 or cfg.n_heads >= msize:
        attn_spec = P(("pod", "data"), "model", None, None)
    else:
        attn_spec = P(("pod", "data"), None, "model", None)

    if cfg.impl == "chunked" and s > cfg.chunk:
        # §Perf B: flash-style online-softmax over k blocks — the SxS
        # logits/probs planes never exist at once, removing the dominant
        # HBM term of full-attention training/prefill at long sequence.
        # Cache-invalid key positions fold into the position mask.
        sk = kf.shape[1]
        kpos = jnp.broadcast_to(kv_pos, (b, sk)).astype(jnp.int32)
        if kv_len_mask is not None:
            kpos = jnp.where(jnp.broadcast_to(kv_len_mask, (b, sk)), kpos,
                             jnp.iinfo(jnp.int32).max)
        out = _chunked_attention(qf, kf, vf, positions, kpos,
                                 1.0 / (cfg.head_dim ** 0.5), cfg.chunk,
                                 attn_spec, cfg.causal)
        out = out.reshape(b, s, cfg.n_heads * cfg.head_dim).astype(x.dtype)
        return out @ p["wo"], new_cache

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / (cfg.head_dim ** 0.5)
    logits = shard(logits, attn_spec)
    if cfg.causal:
        qpos = positions[..., :, None] if positions.ndim == 2 else positions[:, None]
        causal_mask = (qpos[:, None, :, :] >= kv_pos[:, None, None, :])
        logits = jnp.where(causal_mask, logits, -jnp.inf)
    if kv_len_mask is not None:
        logits = jnp.where(kv_len_mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = shard(probs, attn_spec)
    # (§Perf B4 tried bf16 probs for the P·V contraction — REFUTED: the
    # explicit convert added a full pass under XLA's fusion, +2% memory
    # term. Kept fp32; see EXPERIMENTS.md §Perf.)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return out @ p["wo"], new_cache


def _chunked_attention(qf, kf, vf, qpos, kpos, scale: float, blk: int,
                       attn_spec: P, causal: bool) -> jax.Array:
    """Online-softmax attention, scanning key/value blocks (XLA analogue of
    kernels/flash_attention.py — compiles on every backend).

    qf (b,sq,h,d) fp32; kf/vf (b,sk,h,d) fp32 (kv heads pre-broadcast);
    qpos (b,sq); kpos (b,sk). Returns (b,sq,h,d) fp32.
    """
    b, sq, h, d = qf.shape
    sk = kf.shape[1]
    nb = -(-sk // blk)
    pad = nb * blk - sk
    kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=jnp.iinfo(
        jnp.int32).max)      # padded keys never attend
    ks = jnp.moveaxis(kf.reshape(b, nb, blk, h, d), 1, 0)
    vs = jnp.moveaxis(vf.reshape(b, nb, blk, h, d), 1, 0)
    kps = jnp.moveaxis(kpos.reshape(b, nb, blk), 1, 0)

    acc_spec = P(attn_spec[0], attn_spec[1], attn_spec[2], None)

    # remat the block body: without it, scan's backward pass stacks every
    # block's probs — re-materializing exactly the SxS traffic chunking is
    # meant to remove (§Perf B1 refuted the un-rematted version).
    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        kb, vb, kpb = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb) * scale
        s = shard(s, attn_spec)
        mask = kpb[:, None, None, :] < jnp.iinfo(jnp.int32).max
        if causal:
            mask = mask & (qpos[:, None, :, None] >= kpb[:, None, None, :])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p_ = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p_.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bkhd->bhqd", p_, vb)
        acc = shard(acc, acc_spec)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30)
    return jnp.moveaxis(out, 1, 2)            # (b, sq, h, d)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlpCfg:
    d_model: int
    d_ff: int
    activation: str = "swiglu"     # swiglu | gelu


def mlp_init(key, cfg: MlpCfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {"wg": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
                "wu": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
                "wd": dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype)}
    return {"wu": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "wd": dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype)}


def mlp(p: Params, cfg: MlpCfg, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu((x @ p["wg"]).astype(jnp.float32)).astype(x.dtype) \
            * (x @ p["wu"])
    else:
        h = jax.nn.gelu((x @ p["wu"]).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, P(("pod", "data"), None, "model"))
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# cross-entropy loss (vocab possibly sharded over 'model')
# ---------------------------------------------------------------------------

def xent_loss(logits: jax.Array, targets: jax.Array,
              vocab: Optional[int] = None) -> jax.Array:
    """Cross-entropy; columns >= ``vocab`` (embedding padding) are masked."""
    lf = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        cols = jnp.arange(logits.shape[-1])
        lf = jnp.where(cols < vocab, lf, -1e30)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def mask_padded_vocab(logits: jax.Array, vocab: int) -> jax.Array:
    if vocab >= logits.shape[-1]:
        return logits
    cols = jnp.arange(logits.shape[-1])
    return jnp.where(cols < vocab, logits, jnp.asarray(-1e30, logits.dtype))
