"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
applied every ``share_every`` layers (weight reuse — the memory trick of
Zamba), with the block input formed from [hidden, original embedding]
concatenation through a down-projection.

Decode state = per-layer SSM states + one KV cache per shared-block
application site; attention cost appears only at n_layers/share_every
points, keeping 524k-token decode deployable (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.runtime.partition import shard


def _attn_cfg(cfg: ArchConfig) -> L.AttnCfg:
    return L.AttnCfg(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                     cfg.qkv_bias, cfg.rope_theta)


def n_shared_sites(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.share_every


def init_params(key, cfg: ArchConfig) -> Dict:
    km, ks, ke, kc = jax.random.split(key, 4)
    layer_keys = jax.random.split(km, cfg.n_layers)

    def one(k):
        return {"norm": jnp.ones((cfg.d_model,), cfg.jdtype),
                "ssm": S.ssm_init(k, cfg, cfg.jdtype)}
    k1, k2 = jax.random.split(ks)
    shared = {
        "concat_proj": L.dense_init(kc, 2 * cfg.d_model, cfg.d_model,
                                    cfg.jdtype),
        "ln1": jnp.ones((cfg.d_model,), cfg.jdtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.jdtype),
        "attn": L.attn_init(k1, _attn_cfg(cfg), cfg.jdtype),
        "mlp": L.mlp_init(k2, L.MlpCfg(cfg.d_model, cfg.d_ff,
                                       cfg.activation), cfg.jdtype),
    }
    return {"embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
            "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
            "layers": jax.vmap(one)(layer_keys),
            "shared": shared}


def _shared_block(cfg, sp, x, x0, positions, cache, cache_len):
    h = jnp.concatenate([x, x0], axis=-1) @ sp["concat_proj"]
    a, new_cache = L.attention(sp["attn"], _attn_cfg(cfg),
                               L.rmsnorm(h, sp["ln1"]), positions,
                               cache, cache_len)
    h = h + a
    h = h + L.mlp(sp["mlp"], L.MlpCfg(cfg.d_model, cfg.d_ff, cfg.activation),
                  L.rmsnorm(h, sp["ln2"]))
    return x + h, new_cache


def forward(params: Dict, cfg: ArchConfig, tokens: jax.Array,
            states=None, caches=None, cache_len=None):
    """states: stacked per-layer SSM states; caches: stacked per-site KV.
    Both None for training."""
    x = params["embed"][tokens]
    x = shard(x, P(("pod", "data"), None, None))
    x0 = x
    B, S_len = tokens.shape
    base = cache_len if cache_len is not None else 0
    positions = base + jnp.arange(S_len)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S_len))

    k = cfg.share_every
    sites = n_shared_sites(cfg)
    sp = params["shared"]

    def mamba_block(lp, x, st):
        h, nst = S.ssm_forward(lp["ssm"], cfg, L.rmsnorm(x, lp["norm"]), st)
        return x + h, nst

    if cfg.remat:
        mamba_block = jax.checkpoint(mamba_block)

    # group layers: [k mamba layers] + shared block, repeated `sites` times
    new_states = [] if states is not None else None
    new_caches = [] if caches is not None else None
    for g in range(sites):
        lp_g = jax.tree_util.tree_map(lambda a: a[g * k:(g + 1) * k],
                                      params["layers"])
        if states is None:
            def body(x, lp):
                x, _ = mamba_block(lp, x, None)
                return x, None
            x, _ = lax.scan(body, x, lp_g)
        else:
            st_g = jax.tree_util.tree_map(lambda a: a[g * k:(g + 1) * k],
                                          states)
            def body(x, scanned):
                lp, st = scanned
                x, nst = mamba_block(lp, x, st)
                return x, nst
            x, nst_g = lax.scan(body, x, (lp_g, st_g))
            new_states.append(nst_g)
        cache_g = (jax.tree_util.tree_map(lambda a: a[g], caches)
                   if caches is not None else None)
        x, nc = _shared_block(cfg, sp, x, x0, positions, cache_g, cache_len)
        if caches is not None:
            new_caches.append(nc)

    x = L.rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T
    logits = shard(logits, P(("pod", "data"), None, "model"))
    ns = (jax.tree_util.tree_map(lambda *t: jnp.concatenate(t, 0),
                                 *new_states) if new_states else None)
    nc = (jax.tree_util.tree_map(lambda *t: jnp.stack(t, 0), *new_caches)
          if new_caches else None)
    return logits, (ns, nc), jnp.zeros((), jnp.float32)


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int):
    states = S.init_lm_states(cfg, batch)
    sites = n_shared_sites(cfg)
    kv = (jnp.zeros((sites, batch, max_len, cfg.n_kv_heads, cfg.hd),
                    cfg.jdtype),
          jnp.zeros((sites, batch, max_len, cfg.n_kv_heads, cfg.hd),
                    cfg.jdtype))
    return states, kv
