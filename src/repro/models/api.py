"""Unified model API: one bundle per architecture family.

Provides, for every assigned arch:
  * ``init_params(key)``
  * ``loss(params, batch)``                       (training forward)
  * ``prefill(params, batch)``                    (build decode state)
  * ``decode_step(params, state, tokens, len)``   (one new token, KV cache)
  * ``input_specs(shape)`` / ``state_specs(shape)``  — ShapeDtypeStructs for
    the multi-pod dry-run (no allocation).

Batch layout (all int32 tokens):
  dense/moe : {tokens (B,S), targets (B,S)}
  vlm       : {tokens (B,S-P), targets (B,S-P), patches (B,P,D)}
  audio     : {tokens (B,S), targets (B,S), frames (B,T,D)}
  ssm/hybrid: {tokens (B,S), targets (B,S)}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.layers import mask_padded_vocab, xent_loss

I32 = jnp.int32


@dataclasses.dataclass
class ModelAPI:
    cfg: ArchConfig
    init_params: Callable
    loss: Callable                       # (params, batch) -> (loss, aux)
    prefill: Callable                    # (params, batch) -> (logits, state)
    decode_step: Callable                # (params, state, tokens, cache_len)
    input_specs: Callable                # (ShapeCfg) -> batch specs
    state_specs: Callable                # (ShapeCfg) -> decode-state specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_model(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _build_transformer(cfg)
    if fam == "ssm":
        return _build_ssm(cfg)
    if fam == "hybrid":
        return _build_hybrid(cfg)
    if fam == "audio":
        return _build_encdec(cfg)
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# dense / moe / vlm
# ---------------------------------------------------------------------------

def _build_transformer(cfg: ArchConfig) -> ModelAPI:
    is_vlm = cfg.family == "vlm"
    Pn = cfg.n_patches if is_vlm else 0

    def loss(params, batch):
        logits, _, aux = transformer.forward(
            params, cfg, tokens=batch["tokens"],
            embeds=batch.get("patches"))
        txt = logits[:, Pn:, :]
        return xent_loss(txt, batch["targets"], cfg.vocab) + aux, aux

    def prefill(params, batch):
        B, S = batch["tokens"].shape
        total = S + Pn
        caches = transformer.init_caches(cfg, B, batch["max_len"]
                                         if isinstance(batch, dict)
                                         and "max_len" in batch else total)
        logits, caches, _ = transformer.forward(
            params, cfg, tokens=batch["tokens"],
            embeds=batch.get("patches"), caches=caches,
            cache_len=jnp.zeros((), I32))
        return logits[:, -1], caches

    def decode_step(params, state, tokens, cache_len):
        logits, state, _ = transformer.forward(
            params, cfg, tokens=tokens, caches=state, cache_len=cache_len)
        return mask_padded_vocab(logits[:, -1], cfg.vocab), state

    def input_specs(shape: ShapeCfg):
        B = shape.global_batch
        if shape.kind == "train":
            S = shape.seq_len - Pn
            d = {"tokens": _sds((B, S), I32), "targets": _sds((B, S), I32)}
            if is_vlm:
                d["patches"] = _sds((B, Pn, cfg.d_model), cfg.jdtype)
            return d
        if shape.kind == "prefill":
            S = shape.seq_len - Pn
            d = {"tokens": _sds((B, S), I32)}
            if is_vlm:
                d["patches"] = _sds((B, Pn, cfg.d_model), cfg.jdtype)
            return d
        return {"tokens": _sds((B, 1), I32)}      # decode

    def state_specs(shape: ShapeCfg):
        B = shape.global_batch
        sh = (cfg.n_layers, B, shape.seq_len, cfg.n_kv_heads, cfg.hd)
        return (_sds(sh, cfg.jdtype), _sds(sh, cfg.jdtype))

    return ModelAPI(cfg, lambda key: transformer.init_params(key, cfg),
                    loss, prefill, decode_step, input_specs, state_specs)


# ---------------------------------------------------------------------------
# ssm (mamba2)
# ---------------------------------------------------------------------------

def _build_ssm(cfg: ArchConfig) -> ModelAPI:
    def loss(params, batch):
        logits, _, aux = ssm.lm_forward(params, cfg, batch["tokens"])
        return xent_loss(logits, batch["targets"], cfg.vocab) + aux, aux

    def prefill(params, batch):
        # SSM prefill processes the prompt in training mode then refreshes
        # decode states by a short scan; structurally we expose the chunked
        # forward (states materialize during decode_step lowering).
        logits, _, _ = ssm.lm_forward(params, cfg, batch["tokens"])
        B = batch["tokens"].shape[0]
        return logits[:, -1], ssm.init_lm_states(cfg, B)

    def decode_step(params, state, tokens, cache_len):
        logits, state, _ = ssm.lm_forward(params, cfg, tokens, states=state)
        return mask_padded_vocab(logits[:, -1], cfg.vocab), state

    def input_specs(shape: ShapeCfg):
        B = shape.global_batch
        if shape.kind == "train":
            return {"tokens": _sds((B, shape.seq_len), I32),
                    "targets": _sds((B, shape.seq_len), I32)}
        if shape.kind == "prefill":
            return {"tokens": _sds((B, shape.seq_len), I32)}
        return {"tokens": _sds((B, 1), I32)}

    def state_specs(shape: ShapeCfg):
        B = shape.global_batch
        s = cfg.ssm
        dI, H, convd, N = ssm.dims(cfg)
        return (_sds((cfg.n_layers, B, s.d_conv - 1, convd), cfg.jdtype),
                _sds((cfg.n_layers, B, H, s.head_dim, N), jnp.float32))

    return ModelAPI(cfg, lambda key: ssm.init_lm(key, cfg), loss, prefill,
                    decode_step, input_specs, state_specs)


# ---------------------------------------------------------------------------
# hybrid (zamba2)
# ---------------------------------------------------------------------------

def _build_hybrid(cfg: ArchConfig) -> ModelAPI:
    def loss(params, batch):
        logits, _, aux = hybrid.forward(params, cfg, batch["tokens"])
        return xent_loss(logits, batch["targets"], cfg.vocab) + aux, aux

    def prefill(params, batch):
        logits, _, _ = hybrid.forward(params, cfg, batch["tokens"])
        B = batch["tokens"].shape[0]
        return logits[:, -1], hybrid.init_decode_state(
            cfg, B, batch["tokens"].shape[1] + 8)

    def decode_step(params, state, tokens, cache_len):
        states, caches = state
        logits, (ns, nc), _ = hybrid.forward(params, cfg, tokens,
                                             states=states, caches=caches,
                                             cache_len=cache_len)
        return mask_padded_vocab(logits[:, -1], cfg.vocab), (ns, nc)

    def input_specs(shape: ShapeCfg):
        B = shape.global_batch
        if shape.kind == "train":
            return {"tokens": _sds((B, shape.seq_len), I32),
                    "targets": _sds((B, shape.seq_len), I32)}
        if shape.kind == "prefill":
            return {"tokens": _sds((B, shape.seq_len), I32)}
        return {"tokens": _sds((B, 1), I32)}

    def state_specs(shape: ShapeCfg):
        B = shape.global_batch
        s = cfg.ssm
        dI, H, convd, N = ssm.dims(cfg)
        sites = hybrid.n_shared_sites(cfg)
        states = (_sds((cfg.n_layers, B, s.d_conv - 1, convd), cfg.jdtype),
                  _sds((cfg.n_layers, B, H, s.head_dim, N), jnp.float32))
        kv = (_sds((sites, B, shape.seq_len, cfg.n_kv_heads, cfg.hd),
                   cfg.jdtype),
              _sds((sites, B, shape.seq_len, cfg.n_kv_heads, cfg.hd),
                   cfg.jdtype))
        return (states, kv)

    return ModelAPI(cfg, lambda key: hybrid.init_params(key, cfg), loss,
                    prefill, decode_step, input_specs, state_specs)


# ---------------------------------------------------------------------------
# audio (whisper enc-dec)
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ArchConfig) -> ModelAPI:
    T = cfg.encdec.enc_len

    def loss(params, batch):
        enc_out = encdec.encode(params, cfg, batch["frames"])
        logits, _, aux = encdec.decode(params, cfg, batch["tokens"], enc_out)
        return xent_loss(logits, batch["targets"], cfg.vocab) + aux, aux

    def prefill(params, batch):
        enc_out = encdec.encode(params, cfg, batch["frames"])
        B, S = batch["tokens"].shape
        caches = encdec.init_caches(cfg, B, S)
        logits, caches, _ = encdec.decode(params, cfg, batch["tokens"],
                                          enc_out, caches,
                                          jnp.zeros((), I32))
        return logits[:, -1], (enc_out, caches)

    def decode_step(params, state, tokens, cache_len):
        enc_out, caches = state
        logits, caches, _ = encdec.decode(params, cfg, tokens, enc_out,
                                          caches, cache_len)
        return mask_padded_vocab(logits[:, -1], cfg.vocab), (enc_out, caches)

    def input_specs(shape: ShapeCfg):
        B = shape.global_batch
        if shape.kind in ("train",):
            return {"tokens": _sds((B, shape.seq_len), I32),
                    "targets": _sds((B, shape.seq_len), I32),
                    "frames": _sds((B, T, cfg.d_model), cfg.jdtype)}
        if shape.kind == "prefill":
            return {"tokens": _sds((B, shape.seq_len), I32),
                    "frames": _sds((B, T, cfg.d_model), cfg.jdtype)}
        return {"tokens": _sds((B, 1), I32)}

    def state_specs(shape: ShapeCfg):
        B = shape.global_batch
        sh = (cfg.n_layers, B, shape.seq_len, cfg.n_kv_heads, cfg.hd)
        return (_sds((B, T, cfg.d_model), cfg.jdtype),
                (_sds(sh, cfg.jdtype), _sds(sh, cfg.jdtype)))

    return ModelAPI(cfg, lambda key: encdec.init_params(key, cfg), loss,
                    prefill, decode_step, input_specs, state_specs)
