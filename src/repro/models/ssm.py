"""Mamba-2 (SSD — state-space duality) layer, chunked scan formulation.

The strongest match for the paper's technique (DESIGN.md §4): SSD *is* a
streaming recurrence with loop-carried state — per head h with state
(P x N):    H_t = a_t * H_{t-1} + dt_t * (B_t ⊗ x_t) ;  y_t = C_t · H_t

Training uses the chunked dual form (Dao & Gu 2024): within a chunk the
quadratic 'attention-like' term runs on the MXU; across chunks a
``lax.scan`` carries the state — the same split as the fabric's
one-shot-body + loop-carried-feedback structure.

Decode carries (conv_state, ssm_state) and costs O(1) per token — which is
why mamba2/zamba2 are the only archs that run the 524k-decode cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, SSMSpec
from repro.runtime.partition import MODEL, shard


def dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim, s.d_state


def ssm_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict:
    s = cfg.ssm
    dI, H, convd, N = dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * dI + 2 * s.n_groups * N + H
    scale = (2.0 / (cfg.d_model + d_in_proj)) ** 0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (cfg.d_model, d_in_proj),
                                      jnp.float32) * scale).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, convd), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((convd,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "Dp": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((dI,), dtype),
        "out_proj": (jax.random.normal(ks[2], (dI, cfg.d_model), jnp.float32)
                     * scale).astype(dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    dI, H, _, N = dims(cfg)
    G = s.n_groups
    z, xBC, dt = jnp.split(zxbcdt, [dI, 2 * dI + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d over seq. xBC (B,S,C), w (K,C).
    Returns (out, new_state) — state holds the last K-1 inputs."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state
    full = jnp.concatenate([pad, xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    new_state = full[:, -(K - 1):]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xBC.dtype), new_state


def ssm_forward(p: Dict, cfg: ArchConfig, x: jax.Array,
                state: Optional[Tuple[jax.Array, jax.Array]] = None):
    """x: (B,S,D). state: (conv_state (B,K-1,convd), ssm (B,H,P,N)) for
    decode; None for training (chunked scan from zero state)."""
    s = cfg.ssm
    dI, H, convd, N = dims(cfg)
    Phd = s.head_dim
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    conv_state = state[0] if state is not None else None
    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                       conv_state)
    xs, Bc, Cc = jnp.split(xBC, [dI, dI + s.n_groups * N], axis=-1)
    xs = xs.reshape(B, S, H, Phd)
    xs = shard(xs, P(("pod", "data"), None, "model", None))
    Bc = Bc.reshape(B, S, s.n_groups, N)
    Cc = Cc.reshape(B, S, s.n_groups, N)
    # broadcast groups to heads
    rep = H // s.n_groups
    Bh = jnp.repeat(Bc, rep, axis=2)          # (B,S,H,N)
    Ch = jnp.repeat(Cc, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    dA = -jnp.exp(p["A_log"])[None, None, :] * dt                 # <= 0

    if state is None:
        y, last_state = _chunked_ssd(xs, Bh, Ch, dt, dA, s.chunk)
    else:
        h_prev = state[1]
        a = jnp.exp(dA)[..., None, None]      # (B,S,H,1,1)
        if B == 1:
            # single-request long-context decode: the data axis would be
            # idle; shard the head-channel (P) dim over it so the state
            # update/read distributes across the whole pod (§Perf C1)
            xs = shard(xs, P(None, None, MODEL, "data"))
            h_prev = shard(h_prev, P(None, MODEL, "data", None))
        # decode path: S is small (usually 1) — plain scan over S
        def step(h, t):
            ht = a[:, t, :, :, :] * h + (dt[:, t, :, None, None]
                                         * xs[:, t, :, :, None]
                                         * Bh[:, t, :, None, :])
            yt = jnp.einsum("bhpn,bhn->bhp", ht, Ch[:, t])
            return ht, yt
        last_state, ys = lax.scan(step, h_prev, jnp.arange(S))
        y = jnp.moveaxis(ys, 0, 1)            # (B,S,H,P)
    y = y + p["Dp"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, dI).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)
         ).astype(x.dtype) * p["norm_g"]
    out = y @ p["out_proj"]
    new_state = (new_conv_state, last_state)
    return out, new_state


def _chunked_ssd(xs, Bh, Ch, dt, dA, Q: int):
    """Chunked dual form. xs (B,S,H,P), Bh/Ch (B,S,H,N), dt/dA (B,S,H).
    Returns y (B,S,H,P) fp32 and the final state (B,H,P,N)."""
    Bsz, S, H, Phd = xs.shape
    N = Bh.shape[-1]
    nC = S // Q
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    r = lambda t: t.reshape(Bsz, nC, Q, *t.shape[2:])
    xc, Bc, Cc = r(xs.astype(jnp.float32)), r(Bh.astype(jnp.float32)), \
        r(Ch.astype(jnp.float32))
    dtc, dAc = r(dt), r(dA)
    L = jnp.cumsum(dAc, axis=2)                       # (B,nC,Q,H)
    # intra-chunk (attention-like) term; clamp masked (acausal) positions
    # BEFORE exp — exp(+big) at masked slots otherwise turns into 0*inf=NaN
    # in the backward pass (the classic where-grad trap).
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]  # (B,nC,Q,Q,H) log decay
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, diff, -1e30))
    decay = jnp.where(mask, decay, 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc) * decay
    y_diag = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", scores, dtc, xc)
    # chunk summary states: S_c = sum_j exp(L_last - L_j) dt_j B_j x_j^T
    tail = jnp.exp(L[:, :, -1:, :] - L)               # (B,nC,Q,H)
    S_c = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn", tail, dtc, Bc, xc)
    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(L[:, :, -1, :])             # (B,nC,H)
    def step(h, inp):
        s_c, g = inp                                  # (B,H,P,N), (B,H)
        h_new = g[:, :, None, None] * h + s_c
        return h_new, h
    h0 = jnp.zeros((Bsz, H, Phd, N), jnp.float32)
    hT, h_prevs = lax.scan(step,
                           h0,
                           (jnp.moveaxis(S_c, 1, 0),
                            jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # (B,nC,H,P,N) pre-chunk
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(L), Cc, h_prevs)
    y = (y_diag + y_inter).reshape(Bsz, S, H, Phd)
    return y, hT


def init_state(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    dI, H, convd, N = dims(cfg)
    return (jnp.zeros((batch, s.d_conv - 1, convd), cfg.jdtype),
            jnp.zeros((batch, H, s.head_dim, N), jnp.float32))


# ---------------------------------------------------------------------------
# full Mamba-2 language model (embed + scan of SSD blocks + tied head)
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig) -> Dict:
    from repro.models import layers as L
    kl, ke = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)

    def one(k):
        return {"norm": jnp.ones((cfg.d_model,), cfg.jdtype),
                "ssm": ssm_init(k, cfg, cfg.jdtype)}
    return {"embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
            "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
            "layers": jax.vmap(one)(layer_keys)}


def lm_forward(params: Dict, cfg: ArchConfig,
               tokens: jax.Array,
               states: Optional[Tuple[jax.Array, jax.Array]] = None):
    """states: stacked per-layer (conv_state, ssm_state) for decode."""
    from repro.models import layers as L
    x = params["embed"][tokens]
    x = shard(x, P(("pod", "data"), None, None))

    def block(lp, x, st):
        h, new_st = ssm_forward(lp["ssm"], cfg, L.rmsnorm(x, lp["norm"]), st)
        return x + h, new_st

    if cfg.remat:
        block = jax.checkpoint(block)

    if states is None:
        def body(x, lp):
            x, _ = block(lp, x, None)
            return x, None
        x, _ = lax.scan(body, x, params["layers"])
        new_states = None
    else:
        def body(x, scanned):
            lp, st = scanned
            x, nst = block(lp, x, st)
            return x, nst
        x, new_states = lax.scan(body, x, (params["layers"], states))

    x = L.rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T
    logits = shard(logits, P(("pod", "data"), None, "model"))
    return logits, new_states, jnp.zeros((), jnp.float32)


def init_lm_states(cfg: ArchConfig, batch: int):
    conv, ssm_st = init_state(cfg, batch)
    Lc = cfg.n_layers
    return (jnp.broadcast_to(conv[None], (Lc, *conv.shape)).copy(),
            jnp.broadcast_to(ssm_st[None], (Lc, *ssm_st.shape)).copy())
