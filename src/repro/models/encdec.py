"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Encoder: non-causal self-attention over precomputed frame embeddings
(``input_specs`` supplies them, per the assignment). Decoder: causal
self-attention + cross-attention + MLP, tied output embedding, learned
positions, pre-LN LayerNorm (whisper uses LN, not RMS).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.partition import shard

MAX_DEC_POS = 32768 + 8          # decode_32k support


def _attn_cfg(cfg: ArchConfig, causal: bool) -> L.AttnCfg:
    return L.AttnCfg(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                     causal=causal, rope_theta=cfg.rope_theta)


def _ln_init(cfg):
    return {"g": jnp.ones((cfg.d_model,), cfg.jdtype),
            "b": jnp.zeros((cfg.d_model,), cfg.jdtype)}


def init_params(key, cfg: ArchConfig) -> Dict:
    ke, kd, kt, kp, kp2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.encdec.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": _ln_init(cfg), "ln2": _ln_init(cfg),
                "attn": L.attn_init(k1, _attn_cfg(cfg, False), cfg.jdtype),
                "mlp": L.mlp_init(k2, L.MlpCfg(cfg.d_model, cfg.d_ff,
                                               "gelu"), cfg.jdtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": _ln_init(cfg), "ln2": _ln_init(cfg),
                "ln3": _ln_init(cfg),
                "self_attn": L.attn_init(k1, _attn_cfg(cfg, True), cfg.jdtype),
                "cross_attn": L.attn_init(k2, _attn_cfg(cfg, False),
                                          cfg.jdtype),
                "mlp": L.mlp_init(k3, L.MlpCfg(cfg.d_model, cfg.d_ff,
                                               "gelu"), cfg.jdtype)}

    return {
        "embed": L.embed_init(kt, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
        "pos_embed": L.embed_init(kp, MAX_DEC_POS, cfg.d_model, cfg.jdtype),
        "enc_pos_embed": L.embed_init(kp2, cfg.encdec.enc_len, cfg.d_model,
                                      cfg.jdtype),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "enc_final_ln": _ln_init(cfg),
        "dec_final_ln": _ln_init(cfg),
    }


def _ln(x, p):
    return L.layernorm(x, p["g"], p["b"])


def encode(params: Dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, D) stub conv-frontend output."""
    T = frames.shape[1]
    x = frames + params["enc_pos_embed"][:T][None]
    x = shard(x, P(("pod", "data"), None, None))
    positions = jnp.broadcast_to(jnp.arange(T)[None], frames.shape[:2])

    def body(x, lp):
        h, _ = L.attention(lp["attn"], _attn_cfg(cfg, False),
                           _ln(x, lp["ln1"]), positions)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.MlpCfg(cfg.d_model, cfg.d_ff, "gelu"),
                      _ln(x, lp["ln2"]))
        return x, None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_final_ln"])


def _cross_attention(p, cfg, x, enc_out):
    """Simple full cross-attention (encoder KV are static per request)."""
    b, s, _ = x.shape
    q = L._split_heads(x @ p["wq"], cfg.n_heads, cfg.hd)
    k = L._split_heads(enc_out @ p["wk"], cfg.n_kv_heads, cfg.hd)
    v = L._split_heads(enc_out @ p["wv"], cfg.n_kv_heads, cfg.hd)
    group = cfg.n_heads // cfg.n_kv_heads
    kf = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf)
    from repro.runtime.partition import MODEL as _MA, axis_size
    if cfg.n_heads % max(axis_size(_MA), 1) == 0:
        aspec = P(("pod", "data"), "model", None, None)
    else:
        aspec = P(("pod", "data"), None, "model", None)
    logits = shard(logits, aspec)
    probs = jax.nn.softmax(logits / (cfg.hd ** 0.5), axis=-1)
    probs = shard(probs, aspec)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.reshape(b, s, cfg.n_heads * cfg.hd).astype(x.dtype) @ p["wo"]


def decode(params: Dict, cfg: ArchConfig, tokens: jax.Array,
           enc_out: jax.Array,
           caches: Optional[Tuple] = None,
           cache_len: Optional[jax.Array] = None):
    B, S = tokens.shape
    base = cache_len if cache_len is not None else 0
    x = params["embed"][tokens] + params["pos_embed"][
        base + jnp.arange(S)][None]
    x = shard(x, P(("pod", "data"), None, None))
    positions = jnp.broadcast_to(base + jnp.arange(S)[None], (B, S)
                                 ).astype(jnp.int32)

    def block(lp, x, cache):
        h, nc = L.attention(lp["self_attn"], _attn_cfg(cfg, True),
                            _ln(x, lp["ln1"]), positions, cache, cache_len)
        x = x + h
        x = x + _cross_attention(lp["cross_attn"], cfg, _ln(x, lp["ln2"]),
                                 enc_out)
        x = x + L.mlp(lp["mlp"], L.MlpCfg(cfg.d_model, cfg.d_ff, "gelu"),
                      _ln(x, lp["ln3"]))
        return x, nc

    if caches is None:
        def body(x, lp):
            x, _ = block(lp, x, None)
            return x, None
        x, _ = lax.scan(body, x, params["dec_layers"])
        new_caches = None
    else:
        def body(x, scanned):
            lp, c = scanned
            x, nc = block(lp, x, c)
            return x, nc
        x, new_caches = lax.scan(body, x, (params["dec_layers"], caches))

    x = _ln(x, params["dec_final_ln"])
    logits = x @ params["embed"].T
    logits = shard(logits, P(("pod", "data"), None, "model"))
    return logits, new_caches, jnp.zeros((), jnp.float32)


def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return (jnp.zeros(shape, cfg.jdtype), jnp.zeros(shape, cfg.jdtype))
