"""GPipe-style pipeline parallelism over a mesh axis (default: 'pod').

The multi-pod mesh's leading axis defaults to data parallelism, but
cross-pod links (DCI) are far slower than ICI — for models whose gradient
all-reduce would saturate them, pipelining the *layers* across pods sends
only microbatch activations over the slow links instead of full gradients.

Implementation: ``shard_map`` over the stage axis; the layer stack is
sharded by stage (L/n_stages layers each); microbatches flow through a
schedule of ``n_micro + n_stages - 1`` slots with ``lax.ppermute`` boundary
transfers. Forward-only code — ``jax.grad`` differentiates through
ppermute (its transpose is the reverse permute), giving 'backward-by-
autodiff' pipelining with the same schedule reversed, GPipe-style (bubble
fraction (S-1)/(M+S-1)).

Used by opting a transformer config into ``pipeline_stages > 1``; exercised
and verified against serial execution in ``tests/test_pipeline.py``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.runtime.partition import current_mesh


def pipeline_forward(layer_fn: Callable[[Any, jax.Array], jax.Array],
                     stacked_params: Any,
                     x: jax.Array,
                     n_microbatches: int,
                     axis: str = "pod") -> jax.Array:
    """Run ``layer_fn`` over a stage-sharded layer stack.

    layer_fn(params_slice_for_one_layer, x) -> x  (applied per layer)
    stacked_params: pytree with leading layer axis L (L % n_stages == 0)
    x: (B, ...) global batch (B % n_microbatches == 0)
    """
    mesh = current_mesh()
    if mesh is None or axis not in mesh.axis_names:
        # no stage axis available: run serially (single-host debug)
        def body(h, lp):
            return layer_fn(lp, h), None
        out, _ = lax.scan(body, x, stacked_params)
        return out
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches
    L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)

    def stage_local(params_local, x_local):
        """Runs on one stage. params_local: (L/n_stages, ...) layer slice.
        x_local: full batch on every stage (replicated over `axis`)."""
        sidx = lax.axis_index(axis)
        mbs = x_local.reshape(n_microbatches, mb, *x_local.shape[1:])

        def apply_stage(h):
            def body(hh, lp):
                return layer_fn(lp, hh), None
            out, _ = lax.scan(body, h, params_local)
            return out

        n_slots = n_microbatches + n_stages - 1
        carry_in = jnp.zeros_like(mbs[0])
        outputs = jnp.zeros_like(mbs)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def slot(t, state):
            carry_in, outputs = state
            # stage 0 injects microbatch t (when in range)
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = mbs[mb_idx]
            h_in = jnp.where(sidx == 0, inject, carry_in)
            h_out = apply_stage(h_in)
            # last stage banks its result for microbatch t-(n_stages-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            valid = (t - (n_stages - 1) >= 0) & (sidx == n_stages - 1)
            outputs = lax.dynamic_update_slice(
                outputs,
                jnp.where(valid, h_out, outputs[out_idx])[None],
                (out_idx,) + (0,) * (outputs.ndim - 1))
            carry_next = lax.ppermute(h_out, axis, perm)
            return (carry_next, outputs)

        carry_in, outputs = lax.fori_loop(0, n_slots, slot,
                                          (carry_in, outputs))
        # every stage holds `outputs`, but only the last stage's is real:
        # zero the others and psum so all stages return the same value
        outputs = jnp.where(sidx == n_stages - 1, outputs,
                            jnp.zeros_like(outputs))
        outputs = lax.psum(outputs, axis)
        return outputs.reshape(B, *x_local.shape[1:])

    pspec = jax.tree_util.tree_map(
        lambda l: P(axis, *([None] * (l.ndim - 1))), stacked_params)
    out = shard_map(stage_local, mesh=mesh,
                    in_specs=(pspec, P(*([None] * x.ndim))),
                    out_specs=P(*([None] * x.ndim)),
                    check_rep=False)(stacked_params, x)
    return out
