"""Partitioning rules: DP / TP / EP / SP sharding specs for params,
optimizer state, activations and caches.

Mesh axes (launch/mesh.py):
    single-pod:  ("data", "model")            = (16, 16)
    multi-pod:   ("pod", "data", "model")     = (2, 16, 16)

Default layout (Megatron-style TP over 'model', DP over 'pod'+'data'):
  * attention/MLP in-projections: output dim over 'model'
  * out-projections: input dim over 'model'
  * embeddings / lm head: vocab over 'model'
  * MoE expert stacks: expert dim over 'model' (EP)
  * activations: batch over ('pod','data'); heads / ff over 'model'
  * KV caches: batch over ('pod','data'), kv heads over 'model'
  * optimizer moments: parameter spec + ZeRO-1 extra sharding of the
    leading (layer-stack) axis over 'data' where divisible.

GSPMD handles non-divisible dimensions by padding, so configs whose head
counts don't divide 16 (qwen 20H, minicpm 36H) still compile; balance is a
perf-iteration concern (§Perf), not a correctness one.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# axis aliases
BATCH_AXES = ("pod", "data")
MODEL = "model"


def current_mesh() -> Optional[jax.sharding.Mesh]:
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def _mesh_axis_names() -> tuple:
    m = current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def filter_spec(spec: P, names: tuple) -> P:
    """Drop axis names not present in ``names`` (lets the same spec serve
    1-device CPU, single-pod and multi-pod meshes)."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            keep = tuple(a for a in entry if a in names)
            out.append(keep if keep else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def _filter_spec(spec: P) -> Optional[P]:
    names = _mesh_axis_names()
    if not names:
        return None
    return filter_spec(spec, names)


def axis_size(name: str) -> int:
    """Size of a mesh axis in the ambient mesh (1 if absent)."""
    m = current_mesh()
    if m is None:
        return 1
    return dict(zip(m.axis_names, m.devices.shape)).get(name, 1)


def shard(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context."""
    fspec = _filter_spec(spec)
    if fspec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, fspec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# parameter partitioning rules (by param-tree path name conventions)
# ---------------------------------------------------------------------------

_RULES = (
    # (name match, spec for the trailing dims — leading stack axis prepended)
    ("wq", P(None, MODEL)),
    ("wk", P(None, MODEL)),
    ("wv", P(None, MODEL)),
    ("wo", P(MODEL, None)),
    ("wg", P(None, MODEL)),
    ("wu", P(None, MODEL)),
    ("wd", P(MODEL, None)),
    ("bq", P(MODEL)),
    ("bk", P(MODEL)),
    ("bv", P(MODEL)),
    ("w_experts_up", P(MODEL, None, None)),      # (E, D, F): EP over experts
    ("w_experts_gate", P(MODEL, None, None)),
    ("w_experts_down", P(MODEL, None, None)),
    ("router", P(None, MODEL)),
    ("embed", P(MODEL, None)),                   # (V, D): vocab-sharded
    ("lm_head", P(None, MODEL)),                 # (D, V)
    ("in_proj", P(None, MODEL)),                 # mamba projections
    ("out_proj", P(MODEL, None)),
    ("conv_w", P(None, MODEL)),                  # (ksize, channels)
    ("pos_embed", P(None, None)),
)


# production mesh axis sizes (dryrun/train target); GSPMD pads *internal*
# shardings, but pjit *input* shardings must divide evenly, so specs are
# validated against these sizes + the leaf shape and repaired when needed.
MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def _entry_size(entry, sizes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(entry, 1)


def fix_spec(spec: P, shape, sizes=None) -> P:
    """Drop spec entries whose mesh extent doesn't divide the dim; if the
    'model' axis was dropped, re-place it on the largest divisible free dim
    (e.g. granite's 40-expert stack moves EP's 'model' onto the FF dim)."""
    sizes = sizes or MESH_SIZES
    entries = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    dropped_model = False
    for i, e in enumerate(entries):
        if e is None:
            continue
        if shape[i] % _entry_size(e, sizes) != 0:
            has_model = e == MODEL or (isinstance(e, (tuple, list))
                                       and MODEL in e)
            dropped_model = dropped_model or has_model
            entries[i] = None
    flat = [a for e in entries if e is not None
            for a in (e if isinstance(e, (tuple, list)) else (e,))]
    if dropped_model and MODEL not in flat:
        cands = sorted((i for i, e in enumerate(entries)
                        if e is None and shape[i] % sizes[MODEL] == 0),
                       key=lambda i: -shape[i])
        if cands:
            entries[cands[0]] = MODEL
    return P(*entries)


def spec_for(path: str, ndim: int, stacked: bool,
             shape=None) -> P:
    """Sharding spec for a parameter given its tree path (+shape repair)."""
    leaf = path.split("/")[-1]
    spec = P(*([None] * ndim))
    for name, rule in _RULES:
        if leaf == name or leaf.startswith(name):
            entries = list(rule)
            # pad/truncate to the param rank (minus stack axis)
            want = ndim - (1 if stacked else 0)
            while len(entries) < want:
                entries.append(None)
            entries = entries[:want]
            if stacked:
                entries = [None] + entries
            spec = P(*entries)
            break
    if shape is not None:
        spec = fix_spec(spec, shape)
    return spec


def tree_paths(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten a params dict-tree into path->leaf."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_paths(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def param_specs(params: Any, stacked_prefixes: tuple = ("layers",)) -> Any:
    """PartitionSpec tree matching ``params``'s structure."""
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        stacked = any(prefix.startswith(sp) or f"/{sp}" in f"/{prefix}"
                      for sp in stacked_prefixes)
        return spec_for(prefix, tree.ndim, stacked, tuple(tree.shape))
    return walk(params)


def batch_specs(batch_tree: Any, global_batch: int) -> Any:
    """Shardings for input batches: batch dim over ('pod','data')."""
    baxes = BATCH_AXES if global_batch > 1 else None

    def spec(x):
        entries = [baxes] + [None] * (x.ndim - 1)
        return P(*entries)
    return jax.tree_util.tree_map(spec, batch_tree)


def decode_state_specs(cfg, shape, state_tree: Any) -> Any:
    """Sharding specs for decode state (KV caches / SSM states).

    Long-context single-request decode (global_batch == 1) shards the KV
    *sequence* over 'data' (sequence parallelism); otherwise batch goes
    over ('pod','data') and kv-heads/channels over 'model'.
    """
    fam = cfg.family
    long_seq = shape.global_batch == 1
    b = None if long_seq else BATCH_AXES
    msize = MESH_SIZES[MODEL]

    def kv_spec(x):
        # (L_or_sites, B, S, n_kv, hd): kv heads over 'model' when they
        # divide, else head_dim over 'model' (row-parallel attention);
        # single-request long-context shards the KV sequence over 'data'.
        seq = "data" if long_seq else None
        if cfg.n_kv_heads % msize == 0:
            return P(None, b, seq, MODEL, None)
        return P(None, b, seq, None, MODEL)

    def spec_leaf(x):
        nd = x.ndim
        if nd == 5 and fam in ("dense", "moe", "vlm", "audio", "hybrid"):
            return kv_spec(x)
        if fam in ("ssm", "hybrid"):
            if nd == 4:            # conv state (L, B, K-1, convd)
                return P(None, b, None, MODEL)
            if nd == 5:            # ssm state (L, B, H, P, N)
                return P(None, b, MODEL, None, None)
        if nd == 3:                # enc_out (B, T, D)
            return P(b, None, None)
        return P(*([None] * nd))

    def walk(t):
        if isinstance(t, tuple):
            return tuple(walk(v) for v in t)
        if isinstance(t, list):
            return [walk(v) for v in t]
        if isinstance(t, dict):
            return {k: walk(v) for k, v in t.items()}
        return spec_leaf(t)

    # ssm states distinguish conv (nd=4) vs ssm (nd=5) — fix family quirk.
    # Long-context single-request decode additionally shards the SSM state's
    # head-channel dim over 'data' (with batch=1 the data axis is otherwise
    # idle and every data row replicates the whole recurrence — §Perf C1).
    ssm_spec = (P(None, b, MODEL, "data", None) if long_seq
                else P(None, b, MODEL, None, None))
    if fam == "ssm":
        conv, ssm_st = state_tree
        return (P(None, b, None, MODEL), ssm_spec)
    if fam == "hybrid":
        (conv, ssm_st), (kc, vc) = state_tree
        return ((P(None, b, None, MODEL), ssm_spec),
                (kv_spec(kc), kv_spec(vc)))
    return walk(state_tree)


def zero1_specs(params: Any, data_axis: str = "data",
                stacked_prefixes: tuple = ("layers",)) -> Any:
    """Optimizer-moment specs (ZeRO-1): the parameter spec plus an extra
    sharding of some free, evenly-divisible dim over the data axis —
    preferring the leading (layer-stack) axis, falling back to any other
    dim. Tensors with no divisible free dim stay at the parameter spec
    (only small norms/scalars in practice)."""
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        stacked = any(prefix.startswith(sp) or f"/{sp}" in f"/{prefix}"
                      for sp in stacked_prefixes)
        shape = tuple(tree.shape)
        base = spec_for(prefix, tree.ndim, stacked, shape)
        entries = list(base) + [None] * (tree.ndim - len(base))
        dsize = MESH_SIZES[data_axis]
        # candidate dims: prefer dim 0, then largest
        order = [0] + sorted(range(1, tree.ndim), key=lambda i: -shape[i])
        for i in order:
            if i < len(entries) and entries[i] is None \
                    and shape[i] % dsize == 0:
                entries[i] = data_axis
                break
        return P(*entries)
    return walk(params)
