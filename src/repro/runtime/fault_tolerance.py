"""Fault tolerance & elasticity runtime (training-loop side).

Mechanisms (DESIGN.md §5) — what runs at 1000+ nodes:
  * **checkpoint/restart** — `TrainSupervisor.maybe_save` + auto-resume
    (mesh-agnostic checkpoints; see `checkpoint/ckpt.py`);
  * **heartbeats** — each host publishes a monotonic step heartbeat;
    `HealthMonitor.stalled()` flags hosts whose heartbeat lags the fleet
    (dead node or crashed process);
  * **straggler mitigation** — per-step wall-time EWMA; steps slower than
    `straggler_factor` x median trigger (a) logging, (b) optional
    micro-restart of the input pipeline (the usual culprit off-TPU), and
    the data pipeline's counter-based RNG lets a backup host recompute any
    row without coordination;
  * **elastic re-mesh** — on permanent node loss, restore the latest
    checkpoint onto a smaller mesh: `elastic_remesh()` re-shards a host
    checkpoint onto any new mesh (demonstrated in tests with 8 -> 4 hosts).

In this repository the cluster control plane is simulated (single host),
but every interface is the real one: heartbeats are files, monitors are
pure functions of them, and re-meshing uses the production restore path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------

class Heartbeat:
    """File-per-host heartbeat (stands in for the cluster KV store)."""

    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"host_{host_id:05d}.hb")
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int, t: Optional[float] = None) -> None:
        """Publish one liveness record. ``t`` overrides the wall stamp
        for deterministic tests (defaults to ``time.time()``)."""
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step,
                       "t": time.time() if t is None else float(t)}, f)
        os.replace(tmp, self.path)

    def clear(self) -> None:
        """Retire this host: remove its heartbeat file so the monitor
        stops judging it (a drained fleet fabric is *retired*, not
        stalled — it must not keep tripping the monitor forever)."""
        try:
            os.unlink(self.path)
        except FileNotFoundError:
            pass


class HealthMonitor:
    """Flags hosts whose heartbeat lags the fleet.

    Two lag signals, independently gated:

      * **wall timeout** — no beat for more than ``timeout_s``;
      * **step lag** — the host's step trails the fleet max by more than
        ``step_lag``. Pass ``step_lag=None`` to disable: fleet fabric
        workers legitimately diverge in dispatch count (a fabric pinned
        to a rare config class beats less often), so the serving-side
        monitor judges on wall silence only.
    """

    def __init__(self, directory: str, timeout_s: float = 120.0,
                 step_lag: Optional[int] = 5):
        self.dir = directory
        self.timeout_s = timeout_s
        self.step_lag = step_lag

    def read(self) -> Dict[int, Dict]:
        out = {}
        if not os.path.isdir(self.dir):
            return out
        for name in os.listdir(self.dir):
            if name.endswith(".hb"):
                try:
                    with open(os.path.join(self.dir, name)) as f:
                        out[int(name[5:10])] = json.load(f)
                except (json.JSONDecodeError, ValueError):
                    continue
        return out

    def states(self, now: Optional[float] = None) -> Dict[int, str]:
        """Per-host health verdicts: ``{host_id: 'live' | 'stalled'}``.
        A host with no heartbeat file simply does not appear (retired or
        never started)."""
        beats = self.read()
        if not beats:
            return {}
        now = now if now is not None else time.time()
        max_step = max(b["step"] for b in beats.values())
        out = {}
        for host, b in beats.items():
            lagged = self.step_lag is not None and \
                b["step"] < max_step - self.step_lag
            out[host] = "stalled" if (now - b["t"] > self.timeout_s
                                      or lagged) else "live"
        return out

    def stalled(self, now: Optional[float] = None) -> List[int]:
        return sorted(h for h, s in self.states(now).items()
                      if s == "stalled")


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerDetector:
    factor: float = 2.0
    window: int = 50

    def __post_init__(self):
        self._times: List[float] = []
        self.events: List[Dict] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = float(np.median(self._times))
        is_straggler = len(self._times) >= 10 and dt > self.factor * med
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "median": med})
        return is_straggler


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

def elastic_remesh(host_tree: Any, new_mesh: jax.sharding.Mesh,
                   specs: Any) -> Any:
    """Re-shard a host-memory checkpoint onto a (possibly different) mesh.

    Because checkpoints are stored in global layout, scaling from N to M
    hosts is just a device_put with the new mesh's NamedShardings.
    """
    def put(x, spec):
        if x is None:
            return None
        return jax.device_put(
            x, jax.sharding.NamedSharding(new_mesh, spec))
    return jax.tree_util.tree_map(put, host_tree, specs,
                                  is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------

class TrainSupervisor:
    """Glues checkpointing, heartbeats and straggler handling to the loop."""

    def __init__(self, ckpt, hb_dir: str, host_id: int = 0,
                 save_every: int = 100, straggler_factor: float = 2.0):
        self.ckpt = ckpt
        self.hb = Heartbeat(hb_dir, host_id)
        self.monitor = HealthMonitor(hb_dir)
        self.straggler = StragglerDetector(straggler_factor)
        self.save_every = save_every
        self._last_t: Optional[float] = None

    def on_step(self, step: int, state: Any, extra: Optional[Dict] = None
                ) -> Dict:
        now = time.time()
        info: Dict[str, Any] = {}
        if self._last_t is not None:
            info["straggler"] = self.straggler.record(step, now - self._last_t)
        self._last_t = now
        self.hb.beat(step)
        if step > 0 and step % self.save_every == 0:
            self.ckpt.save_async(step, state, extra)
            info["saved"] = True
        stalled = self.monitor.stalled(now)
        if stalled:
            info["stalled_hosts"] = stalled
        return info

    def resume_or_init(self, template: Any):
        step = self.ckpt.latest_step()
        if step is None:
            return None, 0, {}
        return self.ckpt.restore(template, step)
