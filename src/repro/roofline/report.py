"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json."""
from __future__ import annotations

import json
import sys
from typing import List


def fmt_bytes(b: float) -> str:
    if b >= 2 ** 40:
        return f"{b / 2**40:.2f}TiB"
    if b >= 2 ** 30:
        return f"{b / 2**30:.2f}GiB"
    if b >= 2 ** 20:
        return f"{b / 2**20:.1f}MiB"
    return f"{b / 2**10:.0f}KiB"


def roofline_table(recs: List[dict], mesh: str = "16x16") -> str:
    """§Roofline: single-pod baselines, one row per (arch x shape)."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful frac | mem/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — |")
            continue
        rl = r["roofline"]
        uf = rl.get("useful_fraction")
        mem = r.get("memory", {}).get("peak_bytes_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"{rl['bottleneck']} | "
            f"{uf:.2f} |" .replace("None", "—") if uf is not None else
            f"| {r['arch']} | {r['shape']} | ... | — |")
        lines[-1] = (
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"{rl['bottleneck']} | "
            f"{(uf if uf is not None else float('nan')):.2f} | "
            f"{fmt_bytes(mem)} |")
    return "\n".join(lines)


def dryrun_table(recs: List[dict]) -> str:
    """§Dry-run: both meshes, compile status + memory + collective volume."""
    lines = [
        "| arch | shape | mesh | status | lower s | compile s | mem/dev | "
        "collective bytes (global) | HLO flops (global) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped ({r['reason'][:40]}…) | — | — | — | — | — |")
            continue
        mem = r.get("memory", {}).get("peak_bytes_per_device", 0)
        coll = r.get("collectives", {}).get("total_bytes", 0)
        fl = r.get("roofline", {}).get("flops", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s', 0):.1f} | {r.get('compile_s', 0):.1f} | "
            f"{fmt_bytes(mem)} | {fmt_bytes(coll)} | {fl:.3g} |")
    return "\n".join(lines)


def interesting_cells(recs: List[dict]) -> dict:
    """Hillclimb candidates: worst useful-fraction, most collective-bound."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    def coll_ratio(r):
        rl = r["roofline"]
        t = max(rl["compute_s"], rl["memory_s"], rl["collective_s"], 1e-12)
        return rl["collective_s"] / t
    def waste(r):
        uf = r["roofline"].get("useful_fraction") or 0.0
        step = max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                   r["roofline"]["collective_s"])
        ideal = r["roofline"]["model_flops"] / (
            r["roofline"]["chips"] * 197e12)
        return ideal / step if step else 0.0    # roofline fraction of ideal
    worst = min(ok, key=waste)
    most_coll = max(ok, key=coll_ratio)
    return {"worst_roofline": (worst["arch"], worst["shape"], waste(worst)),
            "most_collective": (most_coll["arch"], most_coll["shape"],
                                coll_ratio(most_coll)),
            "fractions": sorted(((r["arch"], r["shape"], round(waste(r), 4))
                                 for r in ok), key=lambda t: t[2])}


if __name__ == "__main__":
    recs = json.load(open(sys.argv[1] if len(sys.argv) > 1
                          else "results/dryrun.json"))
    print(dryrun_table(recs))
    print()
    print(roofline_table(recs))
    print()
    info = interesting_cells(recs)
    print("worst roofline fraction:", info["worst_roofline"])
    print("most collective-bound:", info["most_collective"])
    for t in info["fractions"][:10]:
        print("  low-fraction:", t)
