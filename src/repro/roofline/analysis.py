"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = sum over collective ops of operand bytes /
                 (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD optimized HLO text (``compiled.as_text()``),
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. Ops inside loops/scans are scaled by
the surrounding trip count when XLA's cost analysis exposes it through
FLOPs (cost_analysis already includes loop trip counts; the HLO text parse
multiplies by scan trip counts extracted from while-loop bounds).

Hardware model: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per-chip aggregate egress on the bottleneck axis).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all arrays in an HLO shape string like
    'f32[128,256]' or '(bf16[2,4], f32[8])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _trip_count(body_name: str, text: str) -> int:
    """Best-effort trip count for a while-body fusion (scan over layers)."""
    # XLA names scan loops like "while.N"; trip counts show up in the
    # buffer-assignment comments or the condition comparison constant.
    m = re.search(
        rf"{re.escape(body_name)}[\s\S]{{0,2000}}?compare\([^)]*\), "
        rf"direction=LT[\s\S]{{0,200}}?constant\((\d+)\)", text)
    return int(m.group(1)) if m else 1


@dataclasses.dataclass
class CollectiveStats:
    by_type: Dict[str, int]
    total_bytes: int
    op_count: int


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in the optimized HLO,
    scaling ops inside while-loop bodies by the loop trip count."""
    by_type: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    count = 0

    # map computation name -> trip count for while bodies
    trip_of: Dict[str, int] = {}
    for m in re.finditer(r"while\((.*?)\).*?body=([%\w.\-]+)", hlo_text):
        body = m.group(2).lstrip("%")
        trip_of.setdefault(body, 0)
    # extract trip counts from known scan pattern: the condition compares
    # an induction variable against a constant
    for body in trip_of:
        cond = body.replace("body", "cond")
        cm = re.search(
            rf"%?{re.escape(cond)}[\s\S]{{0,4000}}?direction=LT",
            hlo_text)
        tm = re.search(
            rf"%?{re.escape(cond)}[\s\S]{{0,4000}}?s32\[\] constant\((\d+)\)",
            hlo_text)
        trip_of[body] = int(tm.group(1)) if (cm and tm) else 1

    # attribute each op line to its enclosing computation
    current_comp = ""
    for line in hlo_text.splitlines():
        comp_m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", line)
        if comp_m and "{" in line:
            current_comp = comp_m.group(1)
        for cname in _COLLECTIVES:
            token = f" {cname}("
            alt = f"{cname}-start("
            if token in line or alt in line or line.strip().startswith(cname):
                # operand bytes: shapes on the LHS of the assignment
                lhs = line.split("=")[0]
                nbytes = _shape_bytes(lhs)
                if nbytes == 0:
                    nbytes = _shape_bytes(line)
                mult = trip_of.get(current_comp, 1)
                by_type[cname] += nbytes * max(mult, 1)
                count += 1
                break
    return CollectiveStats(by_type, sum(by_type.values()), count)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None

    def useful_fraction(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """How close the dominant term is to the best achievable given the
        other two (1.0 = perfectly overlapped balanced execution)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline_from_costs(flops: float, hbm_bytes: float,
                        collective_bytes: float, chips: int,
                        model_flops: Optional[float] = None) -> Roofline:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    coll_s = collective_bytes / (chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops, hbm_bytes, collective_bytes, chips, compute_s,
                    memory_s, coll_s, bottleneck, model_flops)


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6·N·D for one training step (fwd+bwd)."""
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    """2·N per generated token (weights read once, fwd only)."""
    return 2.0 * n_params_active * tokens
