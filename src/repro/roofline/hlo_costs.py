"""Cost extraction from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend does not scale
while-loop bodies by their trip counts, which makes it useless for
scan-over-layers models (it undercounts an 80-layer model 80x). This
module re-derives the roofline inputs directly from the HLO text:

  * **FLOPs** — every ``dot`` contributes 2 * prod(output dims) *
    prod(contracting dims); loop bodies are scaled by their trip count
    (parsed from the loop condition's comparison constant), nested loops
    multiply; dots inside fusion computations are counted via recursion.
  * **HBM bytes** — post-fusion HLO ops are the memory-transfer boundaries:
    each non-trivial op contributes its output bytes plus its operands'
    bytes (fusion internals excluded — they live in registers/VMEM).
  * **Collective bytes** — by type, with the same loop scaling.

All quantities are whole-program (global); per-chip division happens in
``analysis.roofline_from_costs``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# the opcode is the first lowercase-word-followed-by-'(' on the RHS (types
# are always followed by '[', so shapes — even tuple shapes — never match)
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_HEAD_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _arrays(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _ARR_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _arrays(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str          # everything after the opening paren


@dataclasses.dataclass
class _Comp:
    name: str
    ops: List[_Op]


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id",
               "reshape", "while", "conditional", "call"}


class HLOCosts:
    def __init__(self, text: str):
        self.comps: Dict[str, _Comp] = {}
        self.defs: Dict[Tuple[str, str], _Op] = {}   # (comp, op name) -> op
        self.entry: Optional[str] = None
        self._parse(text)
        self._flops_memo: Dict[str, float] = {}
        self._bytes_memo: Dict[str, float] = {}
        self._coll_memo: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[_Comp] = None
        for raw in text.splitlines():
            line = _COMMENT_RE.sub("", raw)
            stripped = line.rstrip()
            if stripped.endswith("{") and "->" in line:
                cm = _COMP_HEAD_RE.match(line)
                if cm:
                    cur = _Comp(cm.group(2), [])
                    self.comps[cur.name] = cur
                    if cm.group(1):
                        self.entry = cur.name
                continue
            if cur is None:
                continue
            if stripped == "}":
                cur = None
                continue
            am = _ASSIGN_RE.match(line)
            if not am:
                continue
            rhs = am.group(2)
            om = _OPCODE_RE.search(rhs)
            if not om:
                continue
            op = _Op(am.group(1), rhs[:om.start()].strip(), om.group(1),
                     rhs[om.end():])
            cur.ops.append(op)
            self.defs[(cur.name, op.name)] = op

    # ------------------------------------------------------------------
    def _operands(self, op: _Op, comp: str) -> List[_Op]:
        """Operand defs (only the argument list, not attribute refs)."""
        args = op.rest.split("),")[0]
        out = []
        for m in _NAME_RE.finditer(args):
            d = self.defs.get((comp, m.group(1)))
            if d is not None:
                out.append(d)
        return out

    def _attr_comp(self, op: _Op, attr: str) -> Optional[str]:
        m = re.search(rf"{attr}=%?([\w.\-]+)", op.rest)
        return m.group(1) if m else None

    def _fusion_io_bytes(self, op: _Op, comp_name: str) -> float:
        """HBM bytes of one fusion: output + operand reads, where operands
        that are only dynamic-sliced/gathered inside the fusion count at
        their *slice* size (scan-over-layers parameter stacks would
        otherwise be charged at full size every trip — a 48-80x
        overcount)."""
        operands = self._operands(op, comp_name)
        callee = self._attr_comp(op, "calls")
        ccomp = self.comps.get(callee) if callee else None
        if ccomp is None:
            return float(_shape_bytes(op.shape)) + sum(
                _shape_bytes(o.shape) for o in operands)
        param_idx: Dict[str, int] = {}
        for cop in ccomp.ops:
            if cop.opcode == "parameter":
                m = re.match(r"(\d+)", cop.rest)
                if m:
                    param_idx[cop.name] = int(m.group(1))
        # alias map: convert/bitcast/copy/reshape of a param is transparent
        # (XLA's CPU backend wraps in-place stack updates in full-tensor
        # convert pairs that a TPU compile aliases away)
        alias: Dict[str, str] = {p: p for p in param_idx}

        def root(name: str) -> Optional[str]:
            return alias.get(name)

        for cop in ccomp.ops:
            if cop.opcode in ("convert", "bitcast", "copy", "reshape"):
                ins = self._operands(cop, ccomp.name)
                if len(ins) == 1 and root(ins[0].name) is not None:
                    alias[cop.name] = alias[ins[0].name]

        slice_of: Dict[str, float] = {}
        consumed_other: Dict[str, bool] = {}
        dus_update_bytes = 0.0
        has_dus_of_param = False
        for cop in ccomp.ops:
            if cop.opcode in ("convert", "bitcast", "copy", "reshape") \
                    and cop.name in alias:
                continue                      # transparent alias hop
            if cop.opcode in ("dynamic-slice", "gather", "slice"):
                ins = self._operands(cop, ccomp.name)
                if ins and root(ins[0].name) is not None:
                    nm = root(ins[0].name)
                    slice_of[nm] = slice_of.get(nm, 0.0) + _shape_bytes(
                        cop.shape)
                    ins = ins[1:]
                for o in ins:
                    r = root(o.name)
                    if r is not None:
                        consumed_other[r] = True
            elif cop.opcode == "dynamic-update-slice":
                # in-place update: traffic = the update slice, not the full
                # destination (XLA aliases scan stacking buffers) — the
                # destination param is free, the update operand's size counts
                ins = self._operands(cop, ccomp.name)
                if ins and root(ins[0].name) is not None:
                    has_dus_of_param = True
                    if len(ins) > 1:
                        dus_update_bytes += _shape_bytes(ins[1].shape)
                        for o in ins[2:]:
                            r = root(o.name)
                            if r is not None:
                                consumed_other[r] = True
                else:
                    for o in ins:
                        r = root(o.name)
                        if r is not None:
                            consumed_other[r] = True
            else:
                for o in self._operands(cop, ccomp.name):
                    r = root(o.name)
                    if r is not None:
                        consumed_other[r] = True
        # output bytes: if this fusion is an in-place stack update, charge
        # the written slice rather than the whole stacked output
        total = dus_update_bytes if has_dus_of_param \
            else float(_shape_bytes(op.shape))
        for pname, idx in param_idx.items():
            if pname in slice_of and not consumed_other.get(pname):
                total += slice_of[pname]
            elif has_dus_of_param and pname not in consumed_other \
                    and pname not in slice_of:
                continue            # the aliased DUS destination: free
            elif idx < len(operands):
                total += _shape_bytes(operands[idx].shape)
        return total

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        const_vals: Dict[str, int] = {}
        for op in comp.ops:
            if op.opcode == "constant":
                m = re.match(r"(\d+)", op.rest)
                if m:
                    const_vals[op.name] = int(m.group(1))
        for op in comp.ops:
            if op.opcode == "compare" and "direction=LT" in op.rest:
                for m in _NAME_RE.finditer(op.rest.split("),")[0]):
                    if m.group(1) in const_vals:
                        return const_vals[m.group(1)]
        # fall back: any constant in the cond
        return max(const_vals.values(), default=1)

    # ------------------------------------------------------------------
    def flops(self, comp_name: Optional[str] = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._flops_memo:
            return self._flops_memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += self._dot_flops(op, comp_name)
            elif op.opcode == "fusion":
                callee = self._attr_comp(op, "calls")
                if callee:
                    total += self.flops(callee)
            elif op.opcode == "while":
                body = self._attr_comp(op, "body")
                cond = self._attr_comp(op, "condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    total += self.flops(body) * max(trips, 1)
            elif op.opcode in ("call", "conditional", "custom-call"):
                callee = self._attr_comp(op, "calls") or \
                    self._attr_comp(op, "to_apply")
                if callee:
                    total += self.flops(callee)
        self._flops_memo[comp_name] = total
        return total

    def _dot_flops(self, op: _Op, comp: str) -> float:
        out_arrays = _arrays(op.shape)
        if not out_arrays:
            return 0.0
        out_elems = 1
        for d in out_arrays[0][1]:
            out_elems *= d
        # contracting dims from the lhs operand
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        operands = self._operands(op, comp)
        if not m or not operands:
            return 2.0 * out_elems
        lhs_arrays = _arrays(operands[0].shape)
        if not lhs_arrays:
            return 2.0 * out_elems
        lhs_dims = lhs_arrays[0][1]
        contract = 1
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
        return 2.0 * out_elems * contract

    # ------------------------------------------------------------------
    def hbm_bytes(self, comp_name: Optional[str] = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._bytes_memo:
            return self._bytes_memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "while":
                body = self._attr_comp(op, "body")
                cond = self._attr_comp(op, "condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    total += self.hbm_bytes(body) * max(trips, 1)
                continue
            if op.opcode in ("call", "conditional"):
                callee = self._attr_comp(op, "calls") or \
                    self._attr_comp(op, "to_apply")
                if callee:
                    total += self.hbm_bytes(callee)
                continue
            if op.opcode in _SKIP_BYTES:
                continue
            # op output + operand reads (fusion internals excluded: only the
            # fusion op itself appears here; dynamic-sliced stack operands
            # count at slice size — see _fusion_io_bytes)
            if op.opcode == "fusion":
                total += self._fusion_io_bytes(op, comp_name)
            elif op.opcode == "dynamic-update-slice":
                ins = self._operands(op, comp_name)
                if len(ins) > 1:     # in-place: write the slice only
                    total += 2.0 * _shape_bytes(ins[1].shape)
                else:
                    total += _shape_bytes(op.shape)
            else:
                total += _shape_bytes(op.shape)
                for operand in self._operands(op, comp_name):
                    total += _shape_bytes(operand.shape)
        self._bytes_memo[comp_name] = total
        return total

    # ------------------------------------------------------------------
    def top_bytes(self, n: int = 15) -> List[Tuple[float, str, str]]:
        """Largest HBM-byte contributors (bytes x loop trips, per chip) —
        the §Perf diagnosis tool: tells you WHICH tensor traffic dominates."""
        out: List[Tuple[float, str, str]] = []

        def walk(comp_name: str, mult: float):
            comp = self.comps.get(comp_name)
            if comp is None:
                return
            for op in comp.ops:
                if op.opcode == "while":
                    body = self._attr_comp(op, "body")
                    cond = self._attr_comp(op, "condition")
                    trips = max(self._trip_count(cond) if cond else 1, 1)
                    if body:
                        walk(body, mult * trips)
                    continue
                if op.opcode in ("call", "conditional"):
                    callee = self._attr_comp(op, "calls") or \
                        self._attr_comp(op, "to_apply")
                    if callee:
                        walk(callee, mult)
                    continue
                if op.opcode in _SKIP_BYTES:
                    continue
                if op.opcode == "fusion":
                    b = self._fusion_io_bytes(op, comp_name)
                elif op.opcode == "dynamic-update-slice":
                    ins = self._operands(op, comp_name)
                    b = 2.0 * _shape_bytes(ins[1].shape) if len(ins) > 1 \
                        else _shape_bytes(op.shape)
                else:
                    b = _shape_bytes(op.shape)
                    for operand in self._operands(op, comp_name):
                        b += _shape_bytes(operand.shape)
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                out.append((b * mult, op.opcode,
                            meta.group(1)[:90] if meta else op.name[:60]))

        walk(self.entry, 1.0)
        out.sort(key=lambda t: -t[0])
        return out[:n]

    def top_collectives(self, n: int = 12) -> List[Tuple[float, str, str]]:
        """Largest collectives (bytes x trips, per chip) with provenance."""
        out: List[Tuple[float, str, str]] = []

        def walk(comp_name: str, mult: float):
            comp = self.comps.get(comp_name)
            if comp is None:
                return
            for op in comp.ops:
                base = op.opcode.replace("-start", "")
                if base in COLLECTIVES:
                    meta = re.search(r'op_name="([^"]*)"', op.rest)
                    out.append((_shape_bytes(op.shape) * mult, base,
                                meta.group(1)[:90] if meta else op.name[:60]))
                elif op.opcode == "while":
                    body = self._attr_comp(op, "body")
                    cond = self._attr_comp(op, "condition")
                    trips = max(self._trip_count(cond) if cond else 1, 1)
                    if body:
                        walk(body, mult * trips)
                elif op.opcode in ("fusion", "call", "conditional"):
                    callee = self._attr_comp(op, "calls") or \
                        self._attr_comp(op, "to_apply")
                    if callee:
                        walk(callee, mult)

        walk(self.entry, 1.0)
        out.sort(key=lambda t: -t[0])
        return out[:n]

    # ------------------------------------------------------------------
    def collective_bytes(self, comp_name: Optional[str] = None
                         ) -> Dict[str, float]:
        comp_name = comp_name or self.entry
        if comp_name in self._coll_memo:
            return self._coll_memo[comp_name]
        comp = self.comps.get(comp_name)
        out = {c: 0.0 for c in COLLECTIVES}
        if comp is None:
            return out
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                out[base] += _shape_bytes(op.shape)
            elif op.opcode == "while":
                body = self._attr_comp(op, "body")
                cond = self._attr_comp(op, "condition")
                trips = max(self._trip_count(cond) if cond else 1, 1)
                if body:
                    for k, v in self.collective_bytes(body).items():
                        out[k] += v * trips
            elif op.opcode in ("fusion", "call", "conditional"):
                callee = self._attr_comp(op, "calls") or \
                    self._attr_comp(op, "to_apply")
                if callee:
                    for k, v in self.collective_bytes(callee).items():
                        out[k] += v
        self._coll_memo[comp_name] = out
        return out
