"""fabric_reduce — reductions and lane-batched streams as fused Pallas
kernels, plus ``run_dfg``: the capability-gated DFG dispatcher the engine's
pallas backend calls.

Extends the one-shot streaming adaptation (``fabric_stream``, DESIGN.md §2)
to the two kernel classes that previously fell back to the simulator:

  * **accumulator reductions** (running-sum trees from the frontend's
    ``patterns.py``, mac1/mac3/mac2x dot products): the DFG's elementwise
    prologue evaluates on (block_rows, 128) VMEM tiles exactly as in
    ``fabric_stream``; each reduction node then tile-reduces its operand
    (associative ops only — the capability matrix keeps SHL/SHR
    accumulators on the sequential simulator) and folds the partial into a
    **carry block** that persists across sequential grid steps — the TPU
    image of the PE's immediate-feedback accumulator register. Padding
    lanes are masked to the op's identity element, and the single emission
    (``emit_every`` 0 or the stream length) lands in a (1, 1) output block.

  * **lane batching** (mirroring PR 4's ``simulate_lanes``): N same-mapping
    requests stack lane-major into one padded grid — lane k owns grid steps
    [k*bpl, (k+1)*bpl) — and carries reset at lane boundaries, so one
    ``pallas_call`` serves a whole config-class batch from
    ``Engine.submit``/``flush``.

Everything runs under ``interpret=True`` on CPU (the hermetic CI
configuration); on a TPU the same lowering compiles via Mosaic.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import dfg as D
from repro.core.isa import AluOp
from repro.engine.capabilities import (CapabilityError, check_backend,
                                       check_stream_length, dfg_features)
from repro.kernels import ref
from repro.kernels.fabric_stream import LANES

I32 = np.int32

# identity element per associative reduction op (padding lanes fold to it)
_IDENTITY = {AluOp.ADD: 0, AluOp.SUB: 0, AluOp.XOR: 0, AluOp.OR: 0,
             AluOp.AND: -1, AluOp.MUL: 1}


def default_interpret() -> bool:
    """The kernels' interpret-mode policy (single source of truth — the
    benchmarks record this in their rows): interpret off the accelerator,
    compile via Mosaic on a TPU."""
    return jax.default_backend() == "cpu"


def _tile_reduce(op: AluOp, x: jax.Array) -> jax.Array:
    """Reduce one masked tile to a scalar partial (int32, wrapping)."""
    if op in (AluOp.ADD, AluOp.SUB):
        return jnp.sum(x, dtype=jnp.int32)
    if op == AluOp.MUL:
        return jnp.prod(x, dtype=jnp.int32)
    fn = {AluOp.AND: jnp.bitwise_and, AluOp.OR: jnp.bitwise_or,
          AluOp.XOR: jnp.bitwise_xor}[op]
    return jax.lax.reduce(x, jnp.int32(_IDENTITY[op]),
                          lambda a, b: fn(a, b), tuple(range(x.ndim)))


def _combine(op: AluOp, carry: jax.Array, part: jax.Array) -> jax.Array:
    """Fold a tile partial into the running carry (associativity lets the
    tile order stand in for the element order)."""
    if op == AluOp.ADD:
        return carry + part
    if op == AluOp.SUB:
        return carry - part        # acc - x0 - x1 - ... = acc - sum(x)
    if op == AluOp.MUL:
        return carry * part
    fn = {AluOp.AND: jnp.bitwise_and, AluOp.OR: jnp.bitwise_or,
          AluOp.XOR: jnp.bitwise_xor}[op]
    return fn(carry, part)


def _emit_body(g: D.DFG, in_names: List[str], full_names: List[str],
               red_names: List[str], bpl: int, length: int,
               block_rows: int):
    """Kernel body: elementwise prologue on the tile, reduction carries
    across grid steps, carry reset at lane boundaries."""

    def body(*refs):
        ins = refs[:len(in_names)]
        full_refs = refs[len(in_names):len(in_names) + len(full_names)]
        red_refs = refs[len(in_names) + len(full_names):]
        arrays = {name: r[...] for name, r in zip(in_names, ins)}
        stream_outs, red_ins, _ = ref.eval_dfg_streams(g, arrays)
        for name, r in zip(full_names, full_refs):
            r[...] = stream_outs[name].astype(r.dtype)
        if not red_names:
            return
        i = pl.program_id(0)
        j = jax.lax.rem(i, bpl)            # tile index within this lane
        row = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 1)
        idx = j * (block_rows * LANES) + row * LANES + col
        valid = idx < length               # mask the per-lane padding tail
        for rname, rref in zip(red_names, red_refs):
            node = g.nodes[rname]
            x = jnp.where(valid, red_ins[rname], _IDENTITY[node.op])
            part = _tile_reduce(node.op, x)

            @pl.when(j == 0)
            def _(rref=rref, node=node):   # new lane: reset the carry
                rref[0, 0] = jnp.int32(node.acc_init)

            rref[0, 0] = _combine(node.op, rref[0, 0], part)

    return body


def fabric_reduce_lanes(g: D.DFG, inputs_list: List[Dict[str, np.ndarray]],
                        block_rows: int = 8,
                        interpret: Optional[bool] = None
                        ) -> List[Dict[str, np.ndarray]]:
    """Run N same-DFG requests as one lane-batched fused Pallas kernel.

    Handles elementwise chains, select-reducible Branch/Merge conditionals,
    and single-emission reductions; callers gate eligibility through
    :func:`run_dfg_lanes`. Results are bit-exact against the functional
    executor per lane (the 5-way conformance contract).
    """
    if interpret is None:
        interpret = default_interpret()
    in_names = list(g.inputs)
    out_names = list(g.outputs)
    n_lanes = len(inputs_list)
    lengths = {int(np.asarray(v).shape[0])
               for ins in inputs_list for v in ins.values()}
    if len(lengths) != 1:
        raise CapabilityError(
            f"{g.name}: a lane-batched pallas grid needs equal stream "
            f"lengths across lanes, got {sorted(lengths)}")
    (length,) = lengths
    check_stream_length(g, length)

    # classify outputs: reduction-fed (one (1,1) carry block per reduction
    # node) vs full-rate streams (tile blocks)
    red_of: Dict[str, str] = {}
    for o in out_names:
        e = g.operand(o, "a")
        if g.nodes[e.src].is_reduction():
            red_of[o] = e.src
    full_names = [o for o in out_names if o not in red_of]
    red_names = sorted(set(red_of.values()))

    if length == 0:
        return [{o: np.zeros(0, dtype=I32) for o in out_names}
                for _ in inputs_list]

    tile = block_rows * LANES
    padded = pl.cdiv(length, tile) * tile
    bpl = padded // tile                   # tiles (grid steps) per lane

    def stack(name: str) -> jax.Array:
        lanes = []
        for ins in inputs_list:
            x = jnp.asarray(np.asarray(ins[name]), dtype=jnp.int32)
            lanes.append(jnp.pad(x, (0, padded - length)))
        return jnp.concatenate(lanes).reshape(-1, LANES)

    ins2d = [stack(name) for name in in_names]
    block = (block_rows, LANES)
    in_specs = [pl.BlockSpec(block, lambda i: (i, 0)) for _ in in_names]
    out_specs = [pl.BlockSpec(block, lambda i: (i, 0)) for _ in full_names]
    out_shapes = [jax.ShapeDtypeStruct((n_lanes * padded // LANES, LANES),
                                       jnp.int32) for _ in full_names]
    # one carry/emission block per reduction node, revisited by every grid
    # step of its lane (sequential TPU grids make the accumulation sound)
    out_specs += [pl.BlockSpec((1, 1), lambda i: (i // bpl, 0))
                  for _ in red_names]
    out_shapes += [jax.ShapeDtypeStruct((n_lanes, 1), jnp.int32)
                   for _ in red_names]

    fn = pl.pallas_call(
        _emit_body(g, in_names, full_names, red_names, bpl, length,
                   block_rows),
        grid=(n_lanes * bpl,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )
    outs = fn(*ins2d)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    full_vals = {name: np.asarray(o).reshape(n_lanes, padded)
                 for name, o in zip(full_names, outs)}
    red_vals = {name: np.asarray(o)
                for name, o in zip(red_names, outs[len(full_names):])}

    results: List[Dict[str, np.ndarray]] = []
    for k in range(n_lanes):
        lane: Dict[str, np.ndarray] = {}
        for o in out_names:
            if o in red_of:
                lane[o] = red_vals[red_of[o]][k].astype(I32)
            else:
                v = full_vals[o][k][:length].astype(I32)
                if g.nodes[o].emit_every == 0 and v.size:
                    v = v[-1:]             # OMN stride-0 'last value' mode
                lane[o] = v
        results.append(lane)
    return results


# ---------------------------------------------------------------------------
# the capability-gated dispatcher (what the engine's pallas backend calls)
# ---------------------------------------------------------------------------

def run_dfg_lanes(g: D.DFG, inputs_list: List[Dict[str, np.ndarray]],
                  block_rows: int = 8,
                  interpret: Optional[bool] = None
                  ) -> List[Dict[str, np.ndarray]]:
    """Dispatch N same-DFG requests to the fused Pallas substrate.

    Raises :class:`CapabilityError` naming every feature outside the
    pallas capability set (engine/capabilities.py)."""
    check_backend(dfg_features(g), "pallas", g.name)
    return fabric_reduce_lanes(g, inputs_list, block_rows=block_rows,
                               interpret=interpret)


def run_dfg(g: D.DFG, inputs: Dict[str, np.ndarray],
            block_rows: int = 8,
            interpret: Optional[bool] = None) -> Dict[str, np.ndarray]:
    """Single-request dispatch (one lane)."""
    return run_dfg_lanes(g, [inputs], block_rows=block_rows,
                         interpret=interpret)[0]
