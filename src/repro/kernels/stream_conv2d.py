"""stream_conv2d — 3x3 convolution as a 3-tap row-streaming Pallas kernel.

TPU adaptation of the paper's conv2d multi-shot plan (one shot per filter
row, partial-sum plane between shots). On TPU the three shots fuse into one
kernel: the grid walks output-row blocks; for each output row the three
image rows stream through VMEM (three BlockSpecs on the same array with
row-offset index maps = the paper's three shifted IMN streams), and the
in-row taps become static lane slices. The partial-sum plane never touches
HBM — it lives in registers across the fused taps, which is exactly the
improvement one-shot fusion buys over the fabric's memory-resident partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(r0_ref, r1_ref, r2_ref, k_ref, o_ref, *, w_out: int):
    k = k_ref[...]
    rows = (r0_ref[...], r1_ref[...], r2_ref[...])
    acc = jnp.zeros_like(o_ref[...], dtype=jnp.float32)
    for r in range(3):
        row = rows[r].astype(jnp.float32)
        for c in range(3):
            acc += k[r, c] * jax.lax.dynamic_slice_in_dim(row, c, w_out, axis=1)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_conv2d(img: jax.Array, kern: jax.Array, *, block_rows: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """'valid' 3x3 convolution. img (H, W) -> (H-2, W-2), fp32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    H, W = img.shape
    Ho, Wo = H - 2, W - 2
    Hop = pl.cdiv(Ho, block_rows) * block_rows
    # pad rows so every block of output rows has its three input rows
    imgp = jnp.pad(img.astype(jnp.float32), ((0, Hop - Ho), (0, 0)))
    grid = (Hop // block_rows,)

    in_specs = [
        pl.BlockSpec((block_rows, W), lambda i: (i, 0)),           # rows r+0
        pl.BlockSpec((block_rows, W), lambda i: (i, 0), ),         # r+1 (indexed below)
        pl.BlockSpec((block_rows, W), lambda i: (i, 0), ),
        pl.BlockSpec((3, 3), lambda i: (0, 0)),
    ]
    out = pl.pallas_call(
        functools.partial(_conv_kernel, w_out=Wo),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, Wo), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hop, Wo), jnp.float32),
        interpret=interpret,
    )(imgp, jnp.roll(imgp, -1, axis=0), jnp.roll(imgp, -2, axis=0),
      kern.astype(jnp.float32))
    return out[:Ho]
