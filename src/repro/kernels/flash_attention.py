"""flash_attention — tiled online-softmax attention (LM-stack hot spot).

The attention analogue of the paper's streaming argument: K/V stream
through VMEM in blocks (the IMN role) while running max/denominator/
accumulator live in VMEM scratch (the fabric's loop-carried state), so the
(seq x seq) logits matrix never materializes in HBM.

Grid: (heads, q_blocks, k_blocks), k innermost/'arbitrary'; the causal mask
is applied per-tile from iota; fully-masked k-tiles are skipped via
``pl.when`` (the elastic 'no token, no firing' rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

NEG_INF = -1e30


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q,k,v: (heads, seq, head_dim) with kv heads already broadcast."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    h, sq, d = q.shape
    _, sk, _ = k.shape
    scale = 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, sk)
    sqp = pl.cdiv(sq, bq) * bq
    skp = pl.cdiv(sk, bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0)))
    # padded k columns must never win the softmax
    k_steps = skp // bk
    grid = (h, sqp // bq, k_steps)

    scratch = ([pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, d), jnp.float32)] if _HAS_PLTPU else [])
    kwargs = {}
    if _HAS_PLTPU and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    # mask padded keys by folding them into the causal/key-range mask:
    # since padded ki >= sk and all real qi <= sq-1 < skp, padded columns
    # are masked in causal mode; for non-causal, mask via key index.
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        return _masked_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                              acc_ref, bq=bq, bk=bk, k_steps=k_steps,
                              scale=scale, causal=causal, sk=sk,
                              q_off=sk - sq)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bq, d), lambda h_, i, j: (h_, i, 0)),
                  pl.BlockSpec((1, bk, d), lambda h_, i, j: (h_, j, 0)),
                  pl.BlockSpec((1, bk, d), lambda h_, i, j: (h_, j, 0))],
        out_specs=pl.BlockSpec((1, bq, d), lambda h_, i, j: (h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sqp, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(qp, kp, vp)
    return out[:, :sq]


def _masked_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                   bq: int, bk: int, k_steps: int, scale: float,
                   causal: bool, sk: int, q_off: int = 0):
    kb = pl.program_id(2)
    qb = pl.program_id(1)
    # index grids hoisted out of pl.when (interpret mode cannot lower
    # program_id inside a conditional branch). q_off aligns queries to the
    # END of the key range (standard decode convention: with sq < sk, query
    # i attends keys <= i + sk - sq, matching the jnp.tril(k=sk-sq) oracle).
    qi = q_off + qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = ki < sk
        if causal:
            mask = mask & (qi >= ki)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        @pl.when(kb * bk <= q_off + qb * bq + bq - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(kb == k_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)
