"""Pure-jnp reference oracles for every Pallas kernel.

These define *what* each kernel computes; the Pallas implementations are
asserted allclose against them (interpret mode on CPU, shapes/dtypes swept
by hypothesis in the tests).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import dfg as D
from repro.core.isa import AluOp, CmpOp


# ---------------------------------------------------------------------------
# fabric_stream: acyclic DFG evaluated elementwise over streams
# ---------------------------------------------------------------------------

def dfg_node_eval(op: AluOp, a, b):
    if op == AluOp.ADD:
        return a + b
    if op == AluOp.SUB:
        return a - b
    if op == AluOp.MUL:
        return a * b
    if op == AluOp.SHL:
        return jnp.left_shift(a, jnp.bitwise_and(b, 31))
    if op == AluOp.SHR:
        return jnp.right_shift(a, jnp.bitwise_and(b, 31))
    if op == AluOp.AND:
        return jnp.bitwise_and(a, b)
    if op == AluOp.OR:
        return jnp.bitwise_or(a, b)
    if op == AluOp.XOR:
        return jnp.bitwise_xor(a, b)
    if op == AluOp.NOP:
        return a
    raise ValueError(op)


def eval_dfg_streams(g: D.DFG, inputs: Dict[str, jax.Array]):
    """Evaluate the acyclic part of a DFG over whole streams, speculatively.

    The TPU adaptation of elastic control flow: every Branch leg is
    computed on *all* lanes (speculative execution) and a boolean validity
    mask rides alongside each wire — a Branch splits its mask by the
    predicate, a Merge rejoins complementary legs with a masked select.
    This handles arbitrary select-reducible leg pipelines (ops on the legs
    between Branch and Merge), which the fabric's Fork-Sender/JOIN logic
    sequences one token at a time.

    Reduction (accumulator) nodes are *not* folded here — a tile-level
    caller owns the carry state (``kernels/fabric_reduce.py``). Returns

      (stream_outs, red_ins, red_out_of)

    where ``stream_outs`` maps each full-rate OUTPUT name to its value
    stream, ``red_ins`` maps each reduction node to its per-element operand
    stream, and ``red_out_of`` maps reduction-fed OUTPUT names to their
    reduction node. Loop-carried graphs are out of scope (they stay on the
    sequential simulator — see engine/capabilities.py).
    """
    if g.back_edges():
        raise ValueError(f"{g.name}: loop-carried back edge — streaming "
                         f"evaluation handles acyclic DFGs only")
    # structural select-reducibility proof (shared with the compile-time
    # capability gate, engine/capabilities.py — data is opaque at trace
    # time): a MERGE whose legs are not complementary paths of one
    # predicate wire is arrival-ordered and must raise, never silently
    # evaluate as a select. Memoized on the DFG — this function is a
    # Pallas kernel body, re-traced per grid step.
    offender = g.__dict__.get("_select_offender", False)
    if offender is False:
        from repro.engine.capabilities import select_conds
        offender = select_conds(g)[1]
        g.__dict__["_select_offender"] = offender
    if offender is not None:
        raise ValueError(
            f"{g.name}: MERGE '{offender}' joins wires that are not "
            f"complementary legs of one branch predicate (not "
            f"select-reducible) — use backend='sim'")
    vals: Dict[tuple, jax.Array] = {}
    masks: Dict[tuple, jax.Array] = {}
    outs: Dict[str, jax.Array] = {}
    red_ins: Dict[str, jax.Array] = {}
    red_out_of: Dict[str, str] = {}
    full = jnp.ones(jnp.shape(next(iter(inputs.values()))), dtype=bool)

    for name in g.topo_order():
        n = g.nodes[name]

        def operand(port):
            e = g.operand(name, port)
            if e is None:
                return None, None
            key = (e.src, e.src_port)
            return vals[key], masks[key]

        if n.kind == D.INPUT:
            vals[(name, "out")] = inputs[name]
            masks[(name, "out")] = full
        elif n.kind == D.CONST:
            vals[(name, "out")] = jnp.asarray(n.value, dtype=jnp.int32)
            masks[(name, "out")] = full
        elif n.kind == D.ALU and n.is_reduction():
            a, _ = operand("a")
            if n.value is not None:       # paced counter: acc' = op(acc, c)
                a = jnp.full(jnp.shape(a), n.value, dtype=jnp.int32)
            red_ins[name] = a
        elif n.kind == D.ALU:
            a, ma = operand("a")
            b, mb = operand("b")
            if b is None:
                b, mb = jnp.asarray(n.value, dtype=a.dtype), ma
            vals[(name, "out")] = dfg_node_eval(n.op, a, b)
            masks[(name, "out")] = ma & mb
        elif n.kind == D.CMP:
            a, ma = operand("a")
            b, mb = operand("b")
            if b is not None:
                a, ma = a - b, ma & mb
            elif n.value is not None:
                a = a - jnp.asarray(n.value, dtype=a.dtype)
            r = (a == 0) if n.op == CmpOp.EQZ else (a > 0)
            vals[(name, "out")] = r.astype(jnp.int32)
            masks[(name, "out")] = ma
        elif n.kind == D.MUX:
            a, ma = operand("a")
            b, mb = operand("b")
            c, mc = operand("ctrl")
            if b is None:
                b, mb = jnp.asarray(n.value, dtype=a.dtype), ma
            vals[(name, "out")] = jnp.where(c != 0, a, b)
            masks[(name, "out")] = ma & mb & mc
        elif n.kind == D.BRANCH:
            a, ma = operand("a")
            c, mc = operand("ctrl")
            m = ma & mc
            vals[(name, "t")], masks[(name, "t")] = a, m & (c != 0)
            vals[(name, "f")], masks[(name, "f")] = a, m & (c == 0)
        elif n.kind == D.MERGE:
            a, ma = operand("a")
            b, mb = operand("b")
            # complementary-leg contract, proven structurally up front by
            # select_conds: exactly one side is valid per lane
            vals[(name, "out")] = jnp.where(ma, a, b)
            masks[(name, "out")] = ma | mb
        elif n.kind == D.OUTPUT:
            e = g.operand(name, "a")
            if g.nodes[e.src].is_reduction():
                red_out_of[name] = e.src
            else:
                outs[name] = vals[(e.src, e.src_port)]
    return outs, red_ins, red_out_of


def eval_dfg_elementwise(g: D.DFG, inputs: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Evaluate an acyclic, reduction-free DFG over whole streams (the
    ``fabric_stream`` kernel body). Reductions carry state across tiles
    and lower through ``fabric_reduce`` instead — rejected here by name."""
    for n in g.nodes.values():
        if n.is_reduction():
            raise ValueError(
                f"{g.name}: accumulator reduction node '{n.name}' "
                f"[reduction] — lower via kernels/fabric_reduce.py, "
                f"not fabric_stream")
    outs, _, _ = eval_dfg_streams(g, inputs)
    return outs


# ---------------------------------------------------------------------------
# stream_matmul / stream_conv2d / flash_attention
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def conv2d_3x3(img: jax.Array, kern: jax.Array) -> jax.Array:
    """'valid' 3x3 convolution (correlation, matching the fidelity layer)."""
    H, W = img.shape
    out = jnp.zeros((H - 2, W - 2), dtype=jnp.float32)
    for r in range(3):
        for c in range(3):
            out = out + kern[r, c] * img[r:H - 2 + r, c:W - 2 + c].astype(jnp.float32)
    return out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None) -> jax.Array:
    """Reference attention: q,k,v of shape (heads, seq, head_dim); GQA is
    resolved (kv heads broadcast) before the call."""
    h, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32)).astype(q.dtype)
