"""Pure-jnp reference oracles for every Pallas kernel.

These define *what* each kernel computes; the Pallas implementations are
asserted allclose against them (interpret mode on CPU, shapes/dtypes swept
by hypothesis in the tests).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import dfg as D
from repro.core.isa import AluOp, CmpOp


# ---------------------------------------------------------------------------
# fabric_stream: acyclic DFG evaluated elementwise over streams
# ---------------------------------------------------------------------------

def dfg_node_eval(op: AluOp, a, b):
    if op == AluOp.ADD:
        return a + b
    if op == AluOp.SUB:
        return a - b
    if op == AluOp.MUL:
        return a * b
    if op == AluOp.SHL:
        return jnp.left_shift(a, jnp.bitwise_and(b, 31))
    if op == AluOp.SHR:
        return jnp.right_shift(a, jnp.bitwise_and(b, 31))
    if op == AluOp.AND:
        return jnp.bitwise_and(a, b)
    if op == AluOp.OR:
        return jnp.bitwise_or(a, b)
    if op == AluOp.XOR:
        return jnp.bitwise_xor(a, b)
    if op == AluOp.NOP:
        return a
    raise ValueError(op)


def eval_dfg_elementwise(g: D.DFG, inputs: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Evaluate an acyclic, branch-resolved DFG over whole streams.

    BRANCH/MERGE pairs must be reducible to selects (complementary
    predicates) — the pattern the fabric supports; loop-carried kernels are
    out of scope here (they lower to lax.scan, not a streaming kernel).
    """
    if g.back_edges():
        raise ValueError("fabric_stream handles acyclic DFGs only")
    vals: Dict[tuple, jax.Array] = {}
    outs: Dict[str, jax.Array] = {}
    for name in g.topo_order():
        n = g.nodes[name]
        def operand(port):
            e = g.operand(name, port)
            return None if e is None else vals[(e.src, e.src_port)]
        if n.kind == D.INPUT:
            vals[(name, "out")] = inputs[name]
        elif n.kind == D.CONST:
            vals[(name, "out")] = jnp.asarray(n.value, dtype=jnp.int32)
        elif n.kind == D.ALU:
            if n.is_reduction():
                raise ValueError("reductions lower to stream_matmul-style "
                                 "accumulation, not fabric_stream")
            a = operand("a")
            b = operand("b")
            if b is None:
                b = jnp.asarray(n.value, dtype=a.dtype)
            vals[(name, "out")] = dfg_node_eval(n.op, a, b)
        elif n.kind == D.CMP:
            a = operand("a")
            b = operand("b")
            if b is not None:
                a = a - b
            elif n.value is not None:
                a = a - jnp.asarray(n.value, dtype=a.dtype)
            r = (a == 0) if n.op == CmpOp.EQZ else (a > 0)
            vals[(name, "out")] = r.astype(jnp.int32)
        elif n.kind == D.MUX:
            a, c = operand("a"), operand("ctrl")
            b = operand("b")
            if b is None:
                b = jnp.asarray(n.value, dtype=a.dtype)
            vals[(name, "out")] = jnp.where(c != 0, a, b)
        elif n.kind == D.BRANCH:
            a, c = operand("a"), operand("ctrl")
            # value networks; the predicate travels alongside for the MERGE
            vals[(name, "t")] = a
            vals[(name, "f")] = a
            vals[(name, "_pred")] = c
        elif n.kind == D.MERGE:
            ea = g.operand(name, "a")
            eb = g.operand(name, "b")
            pa = vals.get((ea.src, "_pred"))
            pb = vals.get((eb.src, "_pred"))
            pred = pa if pa is not None else pb
            if pred is None:
                raise ValueError("MERGE without branch predicates is not "
                                 "select-reducible")
            a, b = vals[(ea.src, ea.src_port)], vals[(eb.src, eb.src_port)]
            take_a = pred != 0 if ea.src_port == "t" else pred == 0
            vals[(name, "out")] = jnp.where(take_a, a, b)
        elif n.kind == D.OUTPUT:
            e = g.operand(name, "a")
            outs[name] = vals[(e.src, e.src_port)]
    return outs


# ---------------------------------------------------------------------------
# stream_matmul / stream_conv2d / flash_attention
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def conv2d_3x3(img: jax.Array, kern: jax.Array) -> jax.Array:
    """'valid' 3x3 convolution (correlation, matching the fidelity layer)."""
    H, W = img.shape
    out = jnp.zeros((H - 2, W - 2), dtype=jnp.float32)
    for r in range(3):
        for c in range(3):
            out = out + kern[r, c] * img[r:H - 2 + r, c:W - 2 + c].astype(jnp.float32)
    return out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None) -> jax.Array:
    """Reference attention: q,k,v of shape (heads, seq, head_dim); GQA is
    resolved (kv heads broadcast) before the call."""
    h, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32)).astype(q.dtype)
