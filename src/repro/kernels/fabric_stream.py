"""fabric_stream — the one-shot STRELA engine as a fused Pallas TPU kernel.

TPU adaptation of the paper's one-shot mapping strategy (DESIGN.md §2):

  * each IMN/OMN affine stream  ->  a ``BlockSpec`` over a 1-D stream laid
    out as (blocks, 8, 128) tiles (sublane x lane), so the HBM->VMEM copy
    pipeline plays the role of the elastic handshake (latency tolerance);
  * the mapped DFG body          ->  the kernel body: the topologically
    ordered node list is emitted as VPU ops over the whole tile, i.e. the
    16-PE spatial pipeline becomes 8x128-lane SIMD;
  * one-shot semantics           ->  one fused kernel: the entire DFG
    makes a single HBM round-trip per stream element, exactly the paper's
    no-scratchpad streaming argument;
  * unrolling (strategy 2)       ->  covered by the lane dimension (every
    tile processes 1024 elements of every lane simultaneously).

Only acyclic, reduction-free DFGs lower here; accumulator reductions and
lane-batched dispatch lower through ``fabric_reduce.py`` (which reuses
this module's tile layout), and loop-carried kernels stay on the
sequential simulator — see the backend capability matrix in DESIGN.md §11.
Branch/Merge conditionals evaluate speculatively with validity masks
(``ref.eval_dfg_streams``), covering arbitrary select-reducible leg
pipelines, not just branch-adjacent merges.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dfg as D
from repro.core.isa import AluOp, CmpOp
from repro.kernels import ref

LANES = 128
SUBLANES = 8
TILE = LANES * SUBLANES        # stream elements per grid step per sublane grp


def _emit_body(g: D.DFG, in_names: List[str], out_names: List[str]):
    """Build the Pallas kernel body evaluating the DFG on one VMEM tile."""

    def body(*refs):
        ins = refs[:len(in_names)]
        outs = refs[len(in_names):]
        arrays = {name: r[...] for name, r in zip(in_names, ins)}
        vals = ref.eval_dfg_elementwise(g, arrays)
        for name, r in zip(out_names, outs):
            r[...] = vals[name].astype(r.dtype)

    return body


def fabric_stream(g: D.DFG, inputs: Dict[str, jax.Array],
                  block_rows: int = 8,
                  interpret: bool | None = None) -> Dict[str, jax.Array]:
    """Run an acyclic DFG over 1-D int32 streams with a fused Pallas kernel.

    ``block_rows``: sublane rows per tile (8 -> 1024-element tiles); the
    perf-iteration knob corresponding to the paper's unroll factor.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    in_names = list(g.inputs)
    out_names = list(g.outputs)
    (length,) = {int(inputs[n].shape[0]) for n in in_names}
    tile = block_rows * LANES
    padded = pl.cdiv(length, tile) * tile
    grid = (padded // tile,)

    def pad2d(x):
        x = jnp.asarray(x, dtype=jnp.int32)
        x = jnp.pad(x, (0, padded - length))
        return x.reshape(-1, LANES)

    ins2d = [pad2d(inputs[n]) for n in in_names]
    block = (block_rows, LANES)
    in_specs = [pl.BlockSpec(block, lambda i: (i, 0)) for _ in in_names]
    out_specs = [pl.BlockSpec(block, lambda i: (i, 0)) for _ in out_names]
    out_shapes = [jax.ShapeDtypeStruct((padded // LANES, LANES), jnp.int32)
                  for _ in out_names]

    fn = pl.pallas_call(
        _emit_body(g, in_names, out_names),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )
    outs = fn(*ins2d)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    return {name: o.reshape(-1)[:length] for name, o in zip(out_names, outs)}
