"""Public jit'd wrappers around the Pallas kernels (CPU falls back to
interpret mode automatically; ``use_pallas=False`` selects the XLA path,
which is what the dry-run models lower by default)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import dfg as D
from repro.kernels import ref
from repro.kernels.fabric_stream import fabric_stream
from repro.kernels.flash_attention import flash_attention
from repro.kernels.stream_conv2d import stream_conv2d
from repro.kernels.stream_matmul import stream_matmul


def fabric_elementwise(g: D.DFG, inputs: Dict[str, jax.Array],
                       use_pallas: bool = True,
                       block_rows: int = 8) -> Dict[str, jax.Array]:
    """One-shot DFG over streams: Pallas fused kernel or jnp reference."""
    if use_pallas:
        return fabric_stream(g, inputs, block_rows=block_rows)
    arrays = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in inputs.items()}
    return ref.eval_dfg_elementwise(g, arrays)


def matmul(a: jax.Array, b: jax.Array, use_pallas: bool = True, **kw) -> jax.Array:
    if use_pallas:
        return stream_matmul(a, b, **kw)
    return ref.matmul(a, b)


def conv2d_3x3(img: jax.Array, kern: jax.Array, use_pallas: bool = True,
               **kw) -> jax.Array:
    if use_pallas:
        return stream_conv2d(img, kern, **kw)
    return ref.conv2d_3x3(img, kern)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              use_pallas: bool = True, **kw) -> jax.Array:
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, **kw)
    return ref.flash_attention(q, k, v, causal=causal)
