"""stream_matmul — the multi-shot engine: K-chunked MXU matmul.

TPU adaptation of mapping strategy 3 (multi-shot kernels): the paper splits
a matmul into per-row-triple shots, re-arming stream bases between shots;
here every (m, n) output tile is produced by iterating the k-grid axis —
the re-configuration between shots becomes the per-step ``index_map``
offset change, amortized by the Pallas pipeline exactly as the paper
amortizes reconfiguration over long streams.

Grid: (M/bm, N/bn, K/bk), k innermost with ``arbitrary`` semantics; a VMEM
scratch accumulator carries partial sums across k-steps (the paper's
memory-resident partial plane), and the output is written once on the last
k step. Block shapes default to MXU-aligned 128x128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific extras are unavailable on CPU builds
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def stream_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool | None = None,
                  out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with fp32 MXU accumulation. Shapes padded to block multiples."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    Mp, Np, Kp = (pl.cdiv(M, bm) * bm, pl.cdiv(N, bn) * bn, pl.cdiv(K, bk) * bk)
    a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)

    # VMEM scratch accumulator (interpret mode on CPU supports these too)
    scratch_shapes = [pltpu.VMEM((bm, bn), jnp.float32)] if _HAS_PLTPU else []

    kwargs = {}
    if _HAS_PLTPU and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **kwargs,
    )(a, b)
    return out[:M, :N]
