"""STRELA-JAX: reproduction of 'STRELA: STReaming ELAstic CGRA Accelerator
for Embedded Systems' (Vázquez et al., 2024) + its TPU-scale adaptation.

Layers:
  repro.core      — the paper (DFG IR, mapper, elastic cycle sim, multi-shot
                    planner, SoC/CPU/power models)
  repro.kernels   — Pallas TPU kernels (fabric_stream, fabric_reduce,
                    stream_matmul, stream_conv2d, flash_attention) + jnp
                    oracles
  repro.models    — the 10 assigned architectures (dense/MoE/SSM/hybrid/
                    VLM/enc-dec), scan-over-layers, bf16
  repro.configs   — exact assigned configs + reduced smoke variants + shapes
  repro.launch    — production meshes, multi-pod dry-run, train/serve drivers
  repro.roofline  — HLO cost parser + 3-term roofline analysis
  repro.{data,optim,checkpoint,runtime} — substrate (pipeline, AdamW+WSD,
                    mesh-agnostic checkpoints, fault tolerance, partitioning,
                    pipeline parallelism, gradient compression)

See DESIGN.md / EXPERIMENTS.md at the repository root.
"""
