"""LM serving launch driver: prefill + greedy decode with KV caches.

Not to be confused with ``repro.serve`` (the always-on CGRA kernel
serving engine) — this module batch-serves *language models* on the jax
substrate. It lived at ``repro.launch.serve`` until ISSUE 8; the old
name forwards here.

  PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen1.5-4b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.data.pipeline import stub_frames
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.api import build_model


def init_decode_state(cfg, api, batch, max_len, prompt_batch):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_caches(cfg, batch, max_len)
    if cfg.family == "ssm":
        return ssm.init_lm_states(cfg, batch)
    if cfg.family == "hybrid":
        return hybrid.init_decode_state(cfg, batch, max_len)
    enc_out = encdec.encode  # audio handled in main
    raise ValueError(cfg.family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen + 1
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    decode = jax.jit(api.decode_step, donate_argnums=(1,))

    t0 = time.time()
    if cfg.family == "audio":
        frames = jnp.asarray(stub_frames(B, cfg.encdec.enc_len, cfg.d_model)
                             ).astype(cfg.jdtype)
        enc_out = encdec.encode(params, cfg, frames)
        state = (enc_out, encdec.init_caches(cfg, B, max_len))
    elif cfg.family in ("dense", "moe", "vlm"):
        state = transformer.init_caches(cfg, B, max_len)
    elif cfg.family == "ssm":
        state = ssm.init_lm_states(cfg, B)
    else:
        state = hybrid.init_decode_state(cfg, B, max_len)

    # prefill via repeated decode over the prompt (cache warmup); production
    # uses api.prefill — this path also exercises long-cache decode_step
    cache_len = jnp.zeros((), jnp.int32)
    logits = None
    for t in range(S):
        logits, state = decode(params, state, tokens[:, t:t + 1], cache_len)
        cache_len = cache_len + 1
    prefill_t = time.time() - t0

    out = []
    t0 = time.time()
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(args.gen):
        out.append(np.asarray(cur)[:, 0])
        logits, state = decode(params, state, cur, cache_len)
        cache_len = cache_len + 1
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen_t = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] arch={cfg.arch_id} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {prefill_t:.2f}s, decode "
          f"{gen_t / args.gen * 1000:.1f} ms/token/batch")
    print(f"[serve] sample generations (token ids): {gen[0][:12].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab), "padded-vocab leak!"


if __name__ == "__main__":
    main()
