"""Deprecated alias for :mod:`repro.launch.serve_lm`.

The LM prefill/decode launch driver moved to ``repro.launch.serve_lm``
so its name stops colliding with :mod:`repro.serve`, the always-on CGRA
kernel serving engine (ISSUE 8). Import from the new location.
"""
from repro.launch.serve_lm import *            # noqa: F401,F403
from repro.launch.serve_lm import init_decode_state, main  # noqa: F401

if __name__ == "__main__":
    main()
