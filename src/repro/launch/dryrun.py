import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function (train_step / prefill /
decode_step) is jitted with production in/out shardings and lowered against
ShapeDtypeStructs — no parameter ever materializes. A successful
``.compile()`` proves the distribution (sharding propagation, collectives,
memory) is coherent on the 16x16 single-pod mesh and the 2x16x16 multi-pod
mesh; ``memory_analysis()`` proves it fits; ``cost_analysis()`` + the
optimized-HLO collective parse feed EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, all_archs, cell_runnable, get_arch
from repro.launch.mesh import make_production_mesh
from repro.models.api import ModelAPI, build_model
from repro.optim.adamw import AdamW, clip_by_global_norm, cosine_schedule
from repro.roofline import analysis as RA
from repro.runtime import partition as PT

STACKED = ("layers", "enc_layers", "dec_layers")


def count_params(sds_tree) -> float:
    return float(sum(x.size for x in jax.tree_util.tree_leaves(sds_tree)))


def count_active_params(cfg: ArchConfig, sds_tree) -> float:
    """Active parameters per token (MoE: routed experts scaled by k/E)."""
    flat = PT.tree_paths(sds_tree)
    total = 0.0
    for path, leaf in flat.items():
        frac = 1.0
        if cfg.moe is not None and "w_experts" in path:
            frac = cfg.moe.top_k / cfg.moe.n_experts
        total += leaf.size * frac
    return total


def make_train_step(api: ModelAPI, opt: AdamW):
    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm, "aux": aux}
    return step


def _shardify(mesh, spec_tree):
    names = tuple(mesh.axis_names)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, PT.filter_spec(s, names)), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             skip_compile: bool = False,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = get_arch(arch_id)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch_id, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, reason = cell_runnable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    api = build_model(cfg)
    params_sds = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
    pspecs = PT.param_specs(params_sds, STACKED)
    pshard = _shardify(mesh, pspecs)
    batch_sds = api.input_specs(shape)
    bshard = _shardify(mesh, PT.batch_specs(batch_sds, shape.global_batch))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = AdamW(lr=cosine_schedule(3e-4, 100, 10000))
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospecs_inner = PT.zero1_specs(params_sds, stacked_prefixes=STACKED)
            ospecs = type(opt_sds)(ospecs_inner, ospecs_inner, P())
            oshard = _shardify(mesh, ospecs)
            fn = jax.jit(make_train_step(api, opt),
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_sds, opt_sds, batch_sds)
            tokens = shape.global_batch * shape.seq_len
            mf = RA.model_flops_train(count_active_params(cfg, params_sds),
                                      tokens)
        elif shape.kind == "prefill":
            fn = jax.jit(api.prefill, in_shardings=(pshard, bshard))
            lowered = fn.lower(params_sds, batch_sds)
            tokens = shape.global_batch * shape.seq_len
            mf = RA.model_flops_decode(count_active_params(cfg, params_sds),
                                       tokens)
        else:  # decode
            state_sds = api.state_specs(shape)
            sspecs = PT.decode_state_specs(cfg, shape, state_sds)
            sshard = _shardify(mesh, sspecs)
            if shape.global_batch == 1 and cfg.family != "ssm":
                # §Perf C2: single-request decode — weights 2-D sharded
                # (model x data) so all 256 chips split every projection
                # instead of 16 data rows replicating them. Confirmed for
                # hybrid (zamba 0.200->0.132 s/token); REFUTED for pure ssm
                # (mamba's weights are too small — the weight all-gathers
                # cost more than the replicated reads), hence the family
                # condition. See EXPERIMENTS.md §Perf C.
                pshard = _shardify(mesh, PT.zero1_specs(
                    params_sds, stacked_prefixes=STACKED))
            tok_spec = P(("pod", "data"), None) if shape.global_batch > 1 \
                else P(None, None)
            tok_spec = PT.filter_spec(tok_spec, tuple(mesh.axis_names))
            fn = jax.jit(api.decode_step,
                         in_shardings=(pshard, sshard,
                                       NamedSharding(mesh, tok_spec), None),
                         out_shardings=(None, sshard),
                         donate_argnums=(1,))
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            len_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(params_sds, state_sds, tok_sds, len_sds)
            mf = RA.model_flops_decode(count_active_params(cfg, params_sds),
                                       shape.global_batch)

        rec["lower_s"] = round(time.time() - t0, 2)
        if skip_compile:
            rec["status"] = "lowered"
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis ----
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes_per_device": int(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)) // max(chips, 1),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)[:200]}

    # ---- cost analysis (HLO-text parser: loop-trip-aware; XLA's own
    # cost_analysis does not scale while bodies on the CPU backend) ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_cost_analysis"] = {"flops": float(ca.get("flops", 0.0)),
                                    "bytes": float(ca.get("bytes accessed",
                                                          0.0))}
    except Exception as e:  # pragma: no cover
        rec["xla_cost_analysis"] = {"error": str(e)[:200]}
    try:
        from repro.roofline.hlo_costs import HLOCosts
        hlo = compiled.as_text()
        hc = HLOCosts(hlo)
        # the optimized module is post-SPMD: every shape is per-chip, so
        # globals are per-chip costs x chips (balanced SPMD assumption)
        flops = hc.flops() * chips
        nbytes = hc.hbm_bytes() * chips
        by_type = {k: v * chips for k, v in hc.collective_bytes().items()}
        coll_bytes = sum(by_type.values())
        rec["collectives"] = {"bytes_by_type": by_type,
                              "total_bytes": coll_bytes}
        rec["hlo_kb"] = len(hlo) // 1024
    except Exception as e:  # pragma: no cover
        flops, nbytes, coll_bytes = 0.0, 0.0, 0.0
        rec["collectives"] = {"error": str(e)[:200]}

    rl = RA.roofline_from_costs(flops, nbytes, coll_bytes, chips, mf)
    rec["roofline"] = {
        "flops": rl.flops, "hbm_bytes": rl.hbm_bytes,
        "collective_bytes": rl.collective_bytes, "chips": chips,
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "collective_s": rl.collective_s, "bottleneck": rl.bottleneck,
        "model_flops": mf,
        "useful_fraction": rl.useful_fraction(),
        "roofline_fraction": rl.roofline_fraction(),
    }
    rec["n_params"] = count_params(params_sds)
    rec["n_params_active"] = count_active_params(cfg, params_sds)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-compile", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (e.g. "
                         "attention_impl=chunked, moe_impl=gspmd) — used to "
                         "reproduce the §Perf iterations")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = int(v) if v.isdigit() else v

    cells = []
    archs = list(all_archs()) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mp,
                                   skip_compile=args.skip_compile,
                                   overrides=overrides or None)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": f"{type(e).__name__}: {str(e)[:400]}"}
                    traceback.print_exc()
                rec["wall_s"] = round(time.time() - t0, 1)
                results.append(rec)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    rl = rec["roofline"]
                    extra = (f" bottleneck={rl['bottleneck']}"
                             f" compute={rl['compute_s']:.4f}s"
                             f" mem={rl['memory_s']:.4f}s"
                             f" coll={rl['collective_s']:.4f}s")
                    mem = rec.get("memory", {})
                    if "peak_bytes_per_device" in mem:
                        extra += (f" mem/dev="
                                  f"{mem['peak_bytes_per_device']/2**30:.2f}GiB")
                print(f"[dryrun] {tag}: {status} ({rec['wall_s']}s){extra}",
                      flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
