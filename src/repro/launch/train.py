"""End-to-end training driver.

Wires together the model zoo, data pipeline, AdamW(+WSD), checkpointing,
fault-tolerance supervision and (optionally) int8 gradient compression.
Runs on whatever devices exist (CPU debug meshes included); the dry-run
proves the same step function scales to the production meshes.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import get_arch
from repro.data.pipeline import DataCfg, TokenPipeline, stub_frames
from repro.launch.mesh import make_local_mesh
from repro.models.api import build_model
from repro.optim import grad_compress
from repro.optim.adamw import AdamW, clip_by_global_norm, cosine_schedule, wsd_schedule
from repro.runtime import partition as PT
from repro.runtime.fault_tolerance import TrainSupervisor

STACKED = ("layers", "enc_layers", "dec_layers")


def make_step(api, opt, use_compression: bool):
    def step(params, opt_state, err_state, batch):
        (loss, aux), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch)
        if use_compression:
            grads, err_state = grad_compress.apply(grads, err_state)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, err_state, {"loss": loss, "gnorm": gnorm}
    return step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=("cosine", "wsd"), default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # minicpm trains with the WSD schedule (arXiv:2404.06395)
    sched_kind = args.schedule or ("wsd" if cfg.arch_id.startswith("minicpm")
                                   else "cosine")
    if sched_kind == "wsd":
        lr = wsd_schedule(args.lr, warmup=max(args.steps // 20, 5),
                          stable=int(args.steps * 0.7),
                          decay=max(int(args.steps * 0.25), 1))
    else:
        lr = cosine_schedule(args.lr, warmup=max(args.steps // 20, 5),
                             total=args.steps)
    api = build_model(cfg)
    opt = AdamW(lr=lr)

    mesh = make_local_mesh(args.model_axis)
    params = api.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    err_state = (grad_compress.init_error(params)
                 if args.grad_compression else None)
    pspecs = PT.param_specs(params, STACKED)
    names = tuple(mesh.axis_names)
    shardify = lambda specs: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, PT.filter_spec(s, names)), specs,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, shardify(pspecs))

    pipe = TokenPipeline(DataCfg(cfg.vocab, args.seq, args.batch,
                                 seed=args.seed))
    step_fn = jax.jit(make_step(api, opt, args.grad_compression),
                      donate_argnums=(0, 1, 2))

    sup = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        sup = TrainSupervisor(ckpt, args.ckpt_dir + "/hb",
                              save_every=args.save_every)
        restored, start_step, extra = sup.resume_or_init(
            {"params": params, "opt": opt_state})
        if restored is not None:
            # mesh-agnostic restore: re-shard onto whatever mesh we have now
            params = jax.device_put(restored["params"], shardify(pspecs))
            ospecs = type(opt_state)(shardify(pspecs), shardify(pspecs),
                                     NamedSharding(mesh, P()))
            opt_state = jax.device_put(restored["opt"], ospecs)
            print(f"[train] resumed from step {start_step}")

    losses = []
    with mesh:
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch_np = pipe.batch(step)
            batch: Dict[str, Any] = {k: jnp.asarray(v)
                                     for k, v in batch_np.items()}
            if cfg.family == "vlm":
                batch["patches"] = jnp.asarray(stub_frames(
                    args.batch, cfg.n_patches, cfg.d_model, step)).astype(
                        cfg.jdtype)
            if cfg.family == "audio":
                batch["frames"] = jnp.asarray(stub_frames(
                    args.batch, cfg.encdec.enc_len, cfg.d_model,
                    step)).astype(cfg.jdtype)
            params, opt_state, err_state, metrics = step_fn(
                params, opt_state, err_state, batch)
            if sup is not None:
                sup.on_step(step, {"params": params, "opt": opt_state})
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"({dt / max(step - start_step + 1, 1):.2f}s/step)",
                      flush=True)
    if sup is not None:
        sup.ckpt.wait()
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
