"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def compat_make_mesh(shape: Sequence[int],
                     axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across JAX versions.

    ``jax.sharding.AxisType`` only exists in some JAX releases (it was added,
    renamed, and moved across 0.4.x/0.5.x); where present we request Auto
    axes explicitly (the pre-AxisType default), otherwise the plain call
    already means the same thing.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod ('data' x 'model'); the multi-pod mesh adds
    a leading 'pod' axis (2 pods = 512 chips). Must be called in a process
    whose jax platform exposes enough devices (see launch/dryrun.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return compat_make_mesh((data, model_axis), ("data", "model"))
