"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod ('data' x 'model'); the multi-pod mesh adds
    a leading 'pod' axis (2 pods = 512 chips). Must be called in a process
    whose jax platform exposes enough devices (see launch/dryrun.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh(
        (data, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
