"""WorkloadClass registry: real model-layer compute as serve request classes.

Each entry bridges one per-layer op of the seed's model zoo onto the
fabric as a first-class config class for ``repro.serve`` / ``repro.fleet``:
a traced fixed-point kernel (``workloads/kernels.py``), a seeded input
generator (ranges chosen so every intermediate stays inside int32 — the
precondition for the oracle equivalence), an independent ``jnp`` oracle
closure, an arrival-mix weight, and the *expected* pallas
``backend_skip_reason`` (None means the class must run there).

The registry is the single source of truth consumed by:

  * ``serve/load.py`` — ``model_recipes()`` / ``model_classes()`` and the
    per-class input generators (``workload_input_gen``);
  * ``fleet`` placement / DSE — model labels resolve through the same
    ``mix_recipes`` the paper classes use, so geometry cost tables and
    routing need no special cases;
  * ``tests/test_workloads.py`` — the differential conformance gate
    (bit-exact vs oracle on every capability-eligible backend, expected
    skip reason on the rest, float-semantics tie with stated tolerance);
  * ``benchmarks/bench_serve.py --mix model`` — soak rows re-verify every
    served response against the oracle and report ``oracle_match``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.workloads import kernels as WK


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One model-layer op registered as a serve/fleet request class."""

    label: str                         # config-class label in the mix
    layer: str                         # transformer | attention | ssm | moe
    description: str
    build: Callable[[], Callable]      # -> python fn for repro.frontend
    compile_kwargs: Mapping[str, object]
    # seeded input generation: {stream name: (lo, hi)} half-open ranges,
    # in traced-argument order (names must match the traced fn's args)
    inputs: Mapping[str, Tuple[int, int]]
    oracle: Callable                   # (**streams) -> tuple of int32 arrays
    weight: float                      # relative arrival-mix weight
    pallas_skip: Optional[str]         # expected backend_skip_reason there
    exactness: str                     # the per-class oracle contract
    float_ref: Optional[Callable]      # (inputs, outputs)->(got, want, atol)

    def gen_inputs(self, length: int,
                   rng: np.random.Generator) -> Dict[str, np.ndarray]:
        """Seeded input streams, consumed from ``rng`` in a fixed order —
        part of the serve/fleet replay contract."""
        return {name: rng.integers(lo, hi, length).astype(np.int32)
                for name, (lo, hi) in self.inputs.items()}


_BIT_EXACT = "bit-exact int32 vs jnp oracle on every eligible backend"

MODEL_CLASSES: Dict[str, WorkloadClass] = {}


def _register(wc: WorkloadClass) -> None:
    if wc.label in MODEL_CLASSES:
        raise ValueError(f"duplicate workload class {wc.label!r}")
    MODEL_CLASSES[wc.label] = wc


_register(WorkloadClass(
    label="ln_affine", layer="transformer",
    description="LayerNorm/RMSNorm scale-shift fused with the residual add",
    build=WK.ln_affine_fn, compile_kwargs={},
    inputs={"x": (-2048, 2048), "r": (-2048, 2048)},
    oracle=WK.ln_affine_oracle, weight=2.0, pallas_skip=None,
    exactness=_BIT_EXACT + "; float affine+residual within atol 0.02",
    float_ref=WK.ln_affine_float))

_register(WorkloadClass(
    label="silu_q", layer="transformer",
    description="MLP activation: hard-SiLU piecewise fixed-point pipeline",
    build=WK.silu_q_fn, compile_kwargs={},
    inputs={"x": (-2048, 2048)},
    oracle=WK.silu_q_oracle, weight=1.5, pallas_skip=None,
    exactness=_BIT_EXACT + "; float h-swish within atol 0.02",
    float_ref=WK.silu_q_float))

_register(WorkloadClass(
    label="swiglu_ms", layer="transformer",
    description="SwiGLU MLP gate under pe_limit -> multi-shot plan",
    build=WK.swiglu_fn, compile_kwargs={"pe_limit": 4},
    inputs={"g": (-2048, 2048), "u": (-2048, 2048)},
    oracle=WK.swiglu_oracle, weight=0.75, pallas_skip=None,
    exactness=_BIT_EXACT + "; float hswish(g)*u within atol 0.2",
    float_ref=WK.swiglu_float))

_register(WorkloadClass(
    label="attn_score", layer="attention",
    description="attention-score row dot tile (flash_attention q.k piece)",
    build=WK.attn_score_fn, compile_kwargs={},
    inputs={"q": (-1024, 1024), "k": (-1024, 1024)},
    oracle=WK.attn_score_oracle, weight=1.5, pallas_skip=None,
    exactness=_BIT_EXACT + "; float dot within atol length/128",
    float_ref=WK.attn_score_float))

_register(WorkloadClass(
    label="softmax_den", layer="attention",
    description="softmax denominator: exp2 exponent/mantissa + accumulator",
    build=WK.softmax_denom_fn, compile_kwargs={},
    inputs={"x": (-2048, 1)},          # max-shifted logits, <= 0
    oracle=WK.softmax_denom_oracle, weight=1.0, pallas_skip=None,
    exactness=_BIT_EXACT + "; float sum(exp2) within rel 0.08",
    float_ref=WK.softmax_denom_float))

_register(WorkloadClass(
    label="ssm_scan", layer="ssm",
    description="selective SSD recurrence h = a_t*h + u_t (lax.scan)",
    build=WK.ssm_scan_fn, compile_kwargs={},
    inputs={"u": (-2048, 2048), "a": (0, WK.SSM_DECAY_MAX + 1)},
    oracle=WK.ssm_scan_oracle, weight=0.75, pallas_skip="loop-state",
    exactness=_BIT_EXACT + " (sim); float recurrence within atol 0.05",
    float_ref=WK.ssm_scan_float))

_register(WorkloadClass(
    label="ssm_relax", layer="ssm",
    description="implicit SSM step by fixed-point iteration "
                "(demand-gated loop, data-dependent trip count)",
    build=WK.ssm_relax_fn, compile_kwargs={},
    inputs={"x": (1, 2048)},
    oracle=WK.ssm_relax_oracle, weight=0.5,
    pallas_skip="loop-state+recirculation",
    exactness=_BIT_EXACT + " (sim); float fixed point within atol 0.04",
    float_ref=WK.ssm_relax_float))

_register(WorkloadClass(
    label="moe_gate", layer="moe",
    description="MoE top-1-of-2 routing as Branch/Merge expert select",
    build=WK.moe_gate_fn, compile_kwargs={},
    inputs={"x": (-2048, 2048), "s": (-256, 256)},
    oracle=WK.moe_gate_oracle, weight=1.0, pallas_skip=None,
    exactness=_BIT_EXACT + "; float routed expert within atol 0.01",
    float_ref=WK.moe_gate_float))


# the served model mix, in a stable order (fleet configs carry tuples)
MODEL_MIX: Tuple[str, ...] = tuple(sorted(MODEL_CLASSES))


def model_recipes(length: int) -> Dict[str, tuple]:
    """The model-layer mix as uncompiled recipes in the serve/fleet recipe
    shape ``{label: (factory, compile_kwargs)}`` — factories return the
    *python function* to trace (``serve/load.py::compile_recipe`` passes
    the stream length), where the paper classes return ready DFGs."""
    return {label: (wc.build, dict(wc.compile_kwargs))
            for label, wc in MODEL_CLASSES.items()}


def model_weights() -> Dict[str, float]:
    """Arrival-mix weights of the model classes (transformer-block-heavy:
    two norms + activations per attention tile, sparse MoE/SSM traffic)."""
    return {label: wc.weight for label, wc in MODEL_CLASSES.items()}


def workload_input_gen(label: str) -> Optional[Callable]:
    """The per-class seeded input generator ``(length, rng) -> streams``,
    or None for labels outside the model registry (paper classes keep the
    generic ``request_inputs`` ranges)."""
    wc = MODEL_CLASSES.get(label)
    return wc.gen_inputs if wc is not None else None
