"""Fixed-point model-layer kernels: transformer / SSM / MoE per-layer ops
decomposed onto the int32 streaming fabric.

The seed's model zoo (``repro.models``) is float; the STRELA datapath is a
32-bit integer ALU (ADD/SUB/MUL/SHL/SHR/AND/OR/XOR + EQZ/GTZ).  The bridge
is the standard quantized-inference decomposition: activations are Q8
fixed point (1.0 == 256), layer constants fold into the PE configuration
at trace time, and every float op is rewritten into the primitive set the
frontend lowers — shifts for requantization, clamp/select for piecewise
nonlinearities, the ALU accumulator for row reductions, Branch/Merge for
routing decisions, and the elastic loop schema for recurrences.

Decomposition rules (DESIGN.md §16):

  * **requantize with shifts** — a Qa x Qb product is brought back to Q8
    with an arithmetic right shift; non-power-of-two divisors become a
    multiply by a Q15/Q16 reciprocal followed by a shift (e.g. ``/6`` is
    ``* 21845 >> 16`` after a ``>> 9``);
  * **piecewise nonlinearities** — GELU/SiLU use the *hard* variants of
    quantized inference (h-swish: ``x * clip(x+3, 0, 6) / 6``), exact in
    int32 and within a stated float tolerance of the real activation;
  * **exp via exponent/mantissa split** — softmax terms ``2^x`` (logits
    pre-scaled by log2 e) split into an integer exponent (variable SHR)
    and a linearly interpolated mantissa;
  * **recurrences ride the elastic loop schema** — an SSD-style gated
    recurrence is a ``lax.scan`` (loop-carried back edge); an *implicit*
    state update solved by fixed-point iteration is a ``lax.while_loop``
    (demand-gated recirculation, data-dependent trip count);
  * **routing is Branch/Merge** — MoE top-1 gating steers each token down
    one expert leg of a ``lax.cond``; only the taken side fires.

Every kernel here comes in two forms that must stay in lockstep:

  * the **traced form** (``*_fn`` factories) — plain Python/JAX over int32
    streams, lowered by ``repro.frontend.trace`` through partition, P&R,
    config emission, and either execution backend;
  * the **jnp oracle** (``*_oracle``) — the same integer arithmetic
    evaluated directly with jax.numpy, *independently of the DFG*.  The
    differential gate in tests/test_workloads.py requires them bit-exact,
    which checks the whole trace→partition→map→execute stack, not the
    kernels.

All intermediates are kept below 2**31 by the input ranges in
``registry.py``, so int32 wraparound never triggers and numpy / jnp /
executor semantics coincide exactly.
"""
from __future__ import annotations

import numpy as np

Q = 8                       # activation fixed point: Q8, 1.0 == 256
ONE = 1 << Q

# layer constants (fold into PE configs at trace time; one config class
# per kernel, so every request of a class batches under one fabric config)
LN_GAIN = 307               # LayerNorm gain  ~1.199 Q8
LN_BIAS = -13               # LayerNorm bias  ~-0.051 Q8
INV6_Q16 = 21845            # 1/3 in Q16 (pairs with a >>9 for /1536)
SCORE_SHIFT = 7             # attention scores requantize per product (Q7)
SSM_DECAY_MAX = 230         # selective-scan decay gate upper bound (~0.9)
REC_A = 128                 # implicit-step recurrence weight 0.5 Q8
REC_B = 192                 # implicit-step input weight    0.75 Q8
REC_TOL = 2                 # fixed-point iteration stop (Q8 units)
MOE_W0 = 282                # expert-0 weight ~1.102 Q8
MOE_W1 = 154                # expert-1 weight ~0.602 Q8


# ---------------------------------------------------------------------------
# transformer: LayerNorm affine + residual, MLP activations
# ---------------------------------------------------------------------------

def ln_affine_fn():
    """LayerNorm/RMSNorm scale-shift fused with the residual add:
    ``out = x*g >> Q + b + r`` over a normalized activation stream ``x``
    and a residual stream ``r`` (models/layers.py's ``g * x_hat + b`` tail
    plus the block's skip connection)."""
    def ln_affine(x, r):
        return ((x * LN_GAIN) >> Q) + LN_BIAS + r
    return ln_affine


def _hswish(x):
    """Hard-SiLU (h-swish), the quantized-inference SiLU:
    ``x * clip(x+3, 0, 6) / 6`` — exact in int32 via ``* 21845 >> 25``."""
    import jax.numpy as jnp
    t = jnp.clip(x + 3 * ONE, 0, 6 * ONE)
    p = (x * t) >> 9
    return (p * INV6_Q16) >> 16


def silu_q_fn():
    """Transformer MLP activation: hard-SiLU elementwise pipeline."""
    def silu_q(x):
        return _hswish(x)
    return silu_q


def swiglu_fn():
    """SwiGLU MLP gate: ``hswish(g) * u >> Q`` over the gate and up
    projections.  Served under ``pe_limit`` so the 8-FU pipeline
    partitions into a multi-shot plan (the preemptible long request)."""
    def swiglu(g, u):
        return (_hswish(g) * u) >> Q
    return swiglu


# ---------------------------------------------------------------------------
# attention: score-row dot tile, softmax denominator
# ---------------------------------------------------------------------------

def attn_score_fn():
    """One attention-score row piece: the q·k dot tile of
    kernels/ref.py's ``flash_attention`` inner loop, requantized per
    product (Q7 operands) and folded by the ALU accumulator."""
    import jax.numpy as jnp

    def attn_score(q, k):
        return jnp.sum((q * k) >> SCORE_SHIFT)
    return attn_score


def softmax_denom_fn():
    """Softmax denominator over max-shifted logits (``x <= 0``, Q8,
    pre-scaled by log2 e): each term ``2^(x/256)`` splits into an integer
    exponent (variable SHR) and a linear mantissa, then folds through the
    ALU accumulator — the online-softmax normalizer of
    models/layers.py's ``_chunked_attention``."""
    import jax.numpy as jnp

    def softmax_denom(x):
        d = -x
        k = d >> Q                        # integer part of the exponent
        f = d & (ONE - 1)                 # fractional part
        mant = ONE - (f >> 1)             # 2^-f linearly interpolated
        return jnp.sum(mant >> k)
    return softmax_denom


# ---------------------------------------------------------------------------
# SSM: selective-scan recurrence (explicit + implicit forms)
# ---------------------------------------------------------------------------

def ssm_scan_fn():
    """Selective SSD recurrence (models/ssm.py): per step
    ``h = a_t*h >> Q + u_t`` with a data-dependent decay gate stream
    ``a`` — a loop-carried back edge (sim-only: loop-state)."""
    from jax import lax

    def ssm_scan(u, a):
        def step(h, ua):
            ui, ai = ua
            h2 = ((ai * h) >> Q) + ui
            return h2, h2
        _, ys = lax.scan(step, 0, (u, a))
        return ys
    return ssm_scan


def ssm_relax_fn():
    """Implicit (trapezoid-style) SSM state update solved by fixed-point
    iteration: per element, relax ``h = A*h >> Q + c`` (``c = B*x >> Q``)
    from 0 until the increment falls to ``REC_TOL`` — a data-dependent
    trip count per element, lowered onto the demand-gated elastic loop
    schema (sim-only: loop-state + recirculation)."""
    from jax import lax

    def ssm_relax(x):
        c = (x * REC_B) >> Q

        def cond(s):
            return s[1] > REC_TOL

        def body(s):
            h, _ = s
            h2 = ((h * REC_A) >> Q) + c
            return h2, h2 - h

        h, _ = lax.while_loop(cond, body, (0, REC_TOL + 1))
        return h
    return ssm_relax


# ---------------------------------------------------------------------------
# MoE: top-1 routing as Branch/Merge
# ---------------------------------------------------------------------------

def moe_gate_fn():
    """MoE top-1-of-2 gate (models/moe.py routing): the router margin
    ``s = logit0 - logit1`` steers each token down one expert leg of a
    ``lax.cond`` (Branch/Merge — only the taken expert fires); also emits
    the chosen expert index."""
    from jax import lax

    def moe_gate(x, s):
        pred = s > 0
        y = lax.cond(pred,
                     lambda v: (v * MOE_W0) >> Q,
                     lambda v: (v * MOE_W1) >> Q, x)
        return y, pred.astype("int32")
    return moe_gate


# ---------------------------------------------------------------------------
# jnp oracles: the same integer arithmetic, evaluated independently
# ---------------------------------------------------------------------------

def _i32(*arrs):
    import jax.numpy as jnp
    return tuple(jnp.asarray(a, dtype=jnp.int32) for a in arrs)


def _np(*arrs):
    return tuple(np.asarray(a, dtype=np.int32) for a in arrs)


def ln_affine_oracle(x, r):
    (x, r) = _i32(x, r)
    return _np(((x * LN_GAIN) >> Q) + LN_BIAS + r)


def silu_q_oracle(x):
    (x,) = _i32(x)
    return _np(_hswish(x))


def swiglu_oracle(g, u):
    (g, u) = _i32(g, u)
    return _np((_hswish(g) * u) >> Q)


def attn_score_oracle(q, k):
    import jax.numpy as jnp
    (q, k) = _i32(q, k)
    return _np(jnp.sum((q * k) >> SCORE_SHIFT))


def softmax_denom_oracle(x):
    import jax.numpy as jnp
    (x,) = _i32(x)
    d = -x
    mant = ONE - ((d & (ONE - 1)) >> 1)
    return _np(jnp.sum(mant >> (d >> Q)))


def ssm_scan_oracle(u, a):
    from jax import lax
    (u, a) = _i32(u, a)

    def step(h, ua):
        ui, ai = ua
        h2 = ((ai * h) >> Q) + ui
        return h2, h2
    _, ys = lax.scan(step, np.int32(0), (u, a))
    return _np(ys)


def ssm_relax_oracle(x):
    """Vectorized masked relaxation: converged lanes freeze, so the joint
    loop is element-wise identical to the fabric's per-element loop."""
    import jax.numpy as jnp
    from jax import lax
    (x,) = _i32(x)
    c = (x * REC_B) >> Q
    h0 = jnp.zeros_like(c)
    d0 = jnp.full_like(c, REC_TOL + 1)

    def cond(s):
        return jnp.any(s[1] > REC_TOL)

    def body(s):
        h, d = s
        live = d > REC_TOL
        h2 = jnp.where(live, ((h * REC_A) >> Q) + c, h)
        d2 = jnp.where(live, h2 - h, d)
        return h2, d2

    h, _ = lax.while_loop(cond, body, (h0, d0))
    return _np(h)


def moe_gate_oracle(x, s):
    import jax.numpy as jnp
    (x, s) = _i32(x, s)
    pred = s > 0
    y = jnp.where(pred, (x * MOE_W0) >> Q, (x * MOE_W1) >> Q)
    return _np(y, pred.astype(jnp.int32))


# ---------------------------------------------------------------------------
# float references: tie each integer kernel to the real layer semantics
# ---------------------------------------------------------------------------
# Each returns (got_float, want_float, atol): the dequantized fabric
# output vs the float layer math, with the stated quantization tolerance
# (derived in DESIGN.md §16 from the shift-truncation error budget).

def _f(a):
    return np.asarray(a, dtype=np.float64) / ONE


def ln_affine_float(inputs, outputs):
    x, r = _f(inputs["x"]), _f(inputs["r"])
    want = x * (LN_GAIN / ONE) + (LN_BIAS / ONE) + r
    return _f(outputs[0]), want, 0.02


def _hswish_f(x):
    return x * np.clip(x + 3.0, 0.0, 6.0) / 6.0


def silu_q_float(inputs, outputs):
    return _f(outputs[0]), _hswish_f(_f(inputs["x"])), 0.02


def swiglu_float(inputs, outputs):
    g, u = _f(inputs["g"]), _f(inputs["u"])
    return _f(outputs[0]), _hswish_f(g) * u, 0.2


def attn_score_float(inputs, outputs):
    q = np.asarray(inputs["q"], dtype=np.float64) / (1 << SCORE_SHIFT)
    k = np.asarray(inputs["k"], dtype=np.float64) / (1 << SCORE_SHIFT)
    got = np.asarray(outputs[0], dtype=np.float64) / (1 << SCORE_SHIFT)
    return got, np.sum(q * k, keepdims=True), len(q) / 128.0


def softmax_denom_float(inputs, outputs):
    x = _f(inputs["x"])
    want = np.sum(np.exp2(x), keepdims=True)
    got = _f(outputs[0])
    # relative tolerance (the mantissa interpolation is ~6% worst case):
    # normalize both to the exact denominator before the atol compare
    return got / want, want / want, 0.08


def ssm_scan_float(inputs, outputs):
    u, a = _f(inputs["u"]), _f(inputs["a"])
    h, ys = 0.0, np.zeros_like(u)
    for i in range(len(u)):
        h = a[i] * h + u[i]
        ys[i] = h
    return _f(outputs[0]), ys, 0.05


def ssm_relax_float(inputs, outputs):
    x = _f(inputs["x"])
    cf = x * (REC_B / ONE)
    want = cf / (1.0 - REC_A / ONE)       # the implicit step's fixed point
    return _f(outputs[0]), want, 0.04


def moe_gate_float(inputs, outputs):
    x = _f(inputs["x"])
    s = np.asarray(inputs["s"])
    want = np.where(s > 0, x * (MOE_W0 / ONE), x * (MOE_W1 / ONE))
    return _f(outputs[0]), want, 0.01
