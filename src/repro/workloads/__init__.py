"""repro.workloads — real model-layer compute as STRELA request classes.

The bridge between the seed's float model zoo (``repro.models``,
``repro.kernels``) and the int32 streaming fabric: per-layer ops are
decomposed into fixed-point streaming kernels (``workloads/kernels.py``),
traced through ``repro.frontend``, and registered as
:class:`~repro.workloads.registry.WorkloadClass` entries
(``workloads/registry.py``) that ``repro.serve`` / ``repro.fleet`` ingest
like any other config class.  See DESIGN.md §16.
"""
from repro.workloads.registry import (MODEL_CLASSES, MODEL_MIX,
                                      WorkloadClass, model_recipes,
                                      model_weights, workload_input_gen)

__all__ = [
    "MODEL_CLASSES",
    "MODEL_MIX",
    "WorkloadClass",
    "model_recipes",
    "model_weights",
    "workload_input_gen",
]
