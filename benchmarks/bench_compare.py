"""Table IV reproduction: state-of-the-art comparison (IPA / UE-CGRA /
RipTide vs STRELA), using our simulated STRELA numbers next to the paper's
published values for every system."""
from __future__ import annotations

from typing import List

from repro.core import paper_data as PD
from benchmarks import bench_multishot, bench_oneshot


def run() -> List[dict]:
    ours_one = {r["kernel"]: r for r in bench_oneshot.run()}
    ours_multi = {r["kernel"]: r for r in bench_multishot.run()}
    rows = []
    for work, metrics in PD.TABLE_IV.items():
        for bench, (perf, power, eff) in metrics.items():
            row = {"work": work, "bench": bench, "perf_mops_paper": perf,
                   "power_mw_paper": power, "eff_paper": eff}
            if work == "STRELA":
                ours = ours_one.get(bench) or ours_multi.get(bench)
                if ours:
                    row.update(perf_mops_ours=ours["perf_mops"],
                               power_mw_ours=ours["cgra_mw"],
                               eff_ours=ours["eff_mops_mw"])
            rows.append(row)
    return rows


def main() -> None:
    rows = run()
    print(f"{'work':10s} {'bench':7s} {'MOPs(paper)':>12s} {'MOPs(ours)':>11s} "
          f"{'mW(p)':>6s} {'mW(o)':>6s} {'eff(p)':>7s} {'eff(o)':>7s}")
    for r in rows:
        ours_p = f"{r.get('perf_mops_ours', float('nan')):11.1f}" \
            if "perf_mops_ours" in r else "          -"
        ours_w = f"{r.get('power_mw_ours', float('nan')):6.2f}" \
            if "power_mw_ours" in r else "     -"
        ours_e = f"{r.get('eff_ours', float('nan')):7.1f}" \
            if "eff_ours" in r else "      -"
        print(f"{r['work']:10s} {r['bench']:7s} {r['perf_mops_paper']:12.1f} "
              f"{ours_p} {r['power_mw_paper']:6.2f} {ours_w} "
              f"{r['eff_paper']:7.1f} {ours_e}")


if __name__ == "__main__":
    main()
