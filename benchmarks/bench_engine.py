"""Execution-engine benchmark: batched vs naive dispatch per kernel.

For each kernel the same request set is dispatched twice through
``repro.engine.Engine`` — once per-request (``run``: cold fabric, full
configuration fetch every time) and once batched (``submit``/``flush``:
requests grouped by config class, consecutive same-class shots pay only the
stream re-arm preamble). The difference in config+re-arm cycles is the
amortization the paper's multi-shot results hinge on (Table II, Sec. IV-B),
applied at the traffic level.

``run()`` returns machine-readable rows; ``write_json()`` dumps them as
``BENCH_engine.json`` (the perf-trajectory artifact consumed by CI and
``benchmarks/run.py``). The CLI supports tiny smoke runs::

    PYTHONPATH=src python -m benchmarks.bench_engine --length 16 --requests 8
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.fabric import Fabric
from repro.engine import ArtifactCache, Engine

# kernel -> (DFG factory, input maker); lengths are decided at run time
_KERNELS: Dict[str, Callable[[int], DFG]] = {
    "relu": lambda n: K.relu(),
    "vadd": lambda n: K.vadd(),
    "axpby": lambda n: K.axpby(3, 5),
    "mac1": lambda n: K.mac1(n),
    "fft": lambda n: K.fft_butterfly(),
    # irregular loops: data-dependent trip counts, verified drain-by-
    # token-exhaustion; their II is data-dependent, so the reported model
    # estimate is a per-iteration lower bound
    "div_loop": lambda n: K.div_loop(7),
    "clip_scan": lambda n: _traced(K.clip_scan_fn(-40, 40), n, "clip_scan"),
    "div_iter": lambda n: _traced(K.loop_div_fn(7), n, "div_iter"),
}


def _traced(fn, length: int, name: str) -> DFG:
    from repro.frontend import trace
    return trace(fn, length, name=name)


def _inputs(g: DFG, length: int, rng) -> Dict[str, np.ndarray]:
    lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def run(length: int = 64, n_requests: int = 16, backend: str = "sim",
        fabric: Fabric = None) -> List[dict]:
    fabric = fabric or Fabric()
    rng = np.random.default_rng(0)
    rows: List[dict] = []
    for kname, factory in _KERNELS.items():
        g = factory(length)
        reqs = [_inputs(g, length, rng) for _ in range(n_requests)]

        naive = Engine(fabric=fabric, backend=backend,
                       cache=ArtifactCache(memory_only=True))
        art = naive.compile(g)
        t0 = time.perf_counter()
        for ins in reqs:
            naive.run(art, dict(ins))
        t_naive = time.perf_counter() - t0
        naive_overhead = naive.tally.config + naive.tally.rearm

        batched = Engine(fabric=fabric, backend=backend,
                         cache=ArtifactCache(memory_only=True))
        art_b = batched.compile(g)
        t0 = time.perf_counter()
        for ins in reqs:
            batched.submit(art_b, dict(ins))
        batched.flush()
        t_batched = time.perf_counter() - t0
        batched_overhead = batched.tally.config + batched.tally.rearm

        rows.append({
            "kernel": kname,
            "backend": backend,
            "geometry": f"{fabric.rows}x{fabric.cols}",
            "n_shots": art_b.n_shots,
            "length": length,
            "requests": n_requests,
            "ii": art_b.estimated_ii(),
            "cycles_naive": naive.tally.total,
            "cycles_batched": batched.tally.total,
            "exec_cycles": batched.tally.exec,
            "config_rearm_naive": naive_overhead,
            "config_rearm_batched": batched_overhead,
            "rearm_cycles_saved": naive_overhead - batched_overhead,
            "wall_us_naive": t_naive * 1e6,
            "wall_us_batched": t_batched * 1e6,
        })
    return rows


def write_json(rows: List[dict], path: str = "BENCH_engine.json") -> str:
    with open(path, "w") as f:
        json.dump({"bench": "engine", "rows": rows}, f, indent=2)
        f.write("\n")
    return path


def main(length: int = 64, n_requests: int = 16, json_path: str = "",
         geometries: Tuple[Tuple[int, int], ...] = ((4, 4),)) -> List[dict]:
    rows: List[dict] = []
    for (r_, c_) in geometries:
        geo_rows = run(length=length, n_requests=n_requests,
                       fabric=Fabric(rows=r_, cols=c_))
        print(f"  {r_}x{c_} fabric")
        print(f"  {'kernel':8s} {'II':>5s} {'total(naive)':>13s} "
              f"{'total(batch)':>13s} {'ovh(naive)':>11s} "
              f"{'ovh(batch)':>11s} {'saved':>7s}")
        for r in geo_rows:
            print(f"  {r['kernel']:8s} {r['ii']:5.2f} "
                  f"{r['cycles_naive']:13d} {r['cycles_batched']:13d} "
                  f"{r['config_rearm_naive']:11d} "
                  f"{r['config_rearm_batched']:11d} "
                  f"{r['rearm_cycles_saved']:7d}")
            # multi-shot plans alternate fabric configs internally, so
            # back-to-back requests legitimately save nothing
            if r["n_shots"] == 1:
                assert r["rearm_cycles_saved"] > 0, (
                    f"{r['kernel']}: batching saved no overhead cycles")
            else:
                assert r["rearm_cycles_saved"] >= 0, r
        rows.extend(geo_rows)
    if json_path:
        print(f"  wrote {write_json(rows, json_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=64,
                    help="stream length per request")
    ap.add_argument("--requests", type=int, default=16,
                    help="requests per kernel (>= 8 exercises the "
                         "acceptance-criterion batch size)")
    ap.add_argument("--geometry", action="append", default=None,
                    metavar="RxC", help="fabric geometry to sweep "
                    "(repeatable; default 4x4)")
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    geos = tuple(tuple(int(v) for v in s.lower().split("x"))
                 for s in (args.geometry or ["4x4"]))
    main(length=args.length, n_requests=args.requests,
         json_path=args.json, geometries=geos)
