"""Execution-engine benchmark: batched vs naive dispatch per kernel.

For each kernel the same request set is dispatched twice through
``repro.engine.Engine`` — once per-request (``run``: cold fabric, full
configuration fetch every time) and once batched (``submit``/``flush``:
requests grouped by config class, consecutive same-class shots pay only the
stream re-arm preamble). The difference in config+re-arm cycles is the
amortization the paper's multi-shot results hinge on (Table II, Sec. IV-B),
applied at the traffic level.

Measurement methodology (ISSUE 4 satellite): **cycles are the primary
metric** — they are exact, machine-independent, and what the paper's
claims are stated in. Wall time is the *best of N amortized timed
samples on a fully-warm engine* per mode (see ``_median_wall``); both
modes are compiled and warmed identically before either timed loop runs,
the warmup dispatches provide the cycle numbers (identical to one-shot
dispatch) and populate the caches whose effectiveness the wall metric is
meant to show — the timing-trace cache makes repeat dispatch of
static-rate kernels O(length) NumPy, and the cold compile path is
reported separately as ``wall_us_*_cold``.

``run()`` returns machine-readable rows; ``write_json()`` dumps them as
``BENCH_engine.json`` (the perf-trajectory artifact consumed by CI and
``benchmarks/run.py``). By default ``main()`` emits rows for **both
backends** (ISSUE 5): pallas rows cover every kernel inside the declared
capability set (engine/capabilities.py) — reductions included — with the
same request streams as the sim rows, so their cycle columns must match
exactly (timing/value decoupling) while values are verified bit-exact
against a sim engine (``values_match_sim``). Pallas rows run in interpret
mode on CPU (``interpret_mode``), where wall time measures the
interpreter, not the substrate — consumers (``perf_smoke``) budget only
the sim rows' wall time and assert value parity on the pallas rows.

The CLI supports tiny smoke runs::

    PYTHONPATH=src python -m benchmarks.bench_engine --length 16 --requests 8
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.fabric import Fabric
from repro.engine import ArtifactCache, Engine

# kernel -> (DFG factory, input maker); lengths are decided at run time
_KERNELS: Dict[str, Callable[[int], DFG]] = {
    "relu": lambda n: K.relu(),
    "vadd": lambda n: K.vadd(),
    "axpby": lambda n: K.axpby(3, 5),
    "mac1": lambda n: K.mac1(n),
    "fft": lambda n: K.fft_butterfly(),
    # irregular loops: data-dependent trip counts, verified drain-by-
    # token-exhaustion; their II is data-dependent, so the reported model
    # estimate is a per-iteration lower bound
    "div_loop": lambda n: K.div_loop(7),
    "clip_scan": lambda n: _traced(K.clip_scan_fn(-40, 40), n, "clip_scan"),
    "div_iter": lambda n: _traced(K.loop_div_fn(7), n, "div_iter"),
}


def _traced(fn, length: int, name: str) -> DFG:
    from repro.frontend import trace
    return trace(fn, length, name=name)


def _inputs(g: DFG, length: int, rng) -> Dict[str, np.ndarray]:
    lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def _sample(dispatch: Callable[[], None], inner: int) -> float:
    """One amortized wall sample: ``inner`` back-to-back dispatches."""
    t0 = time.perf_counter()
    for _ in range(inner):
        dispatch()
    return (time.perf_counter() - t0) / inner


def _median_wall(dispatch: Callable[[], None], repeats: int,
                 inner: int = 1) -> float:
    """Best amortized wall over ``repeats`` isolated samples (see
    ``_paired_walls`` for the two-mode comparison methodology)."""
    return min(_sample(dispatch, inner) for _ in range(repeats))


def _paired_walls(a: Callable[[], None], b: Callable[[], None],
                  repeats: int, inner: int) -> Tuple[float, float]:
    """Per-mode best-of-``repeats`` amortized wall for two dispatch modes,
    sampled in adjacent pairs.

    Three noise defenses, applied identically to both modes: ``inner``
    amortization lifts sub-millisecond kernels off the timer/scheduler
    jitter floor; pairing samples back-to-back means slow host drift
    (frequency scaling, co-tenant load) lands on both modes instead of
    biasing whichever loop ran later; and taking the *minimum* rejects
    one-sided contention spikes (interference only ever inflates a wall
    sample — the min is the measurement). The old layout — a median of
    bare single-dispatch samples, naive timed before the batched engine
    even compiled — is how the phantom warm-path "batching regressions"
    were manufactured."""
    wa, wb = [], []
    for _ in range(repeats):
        wa.append(_sample(a, inner))
        wb.append(_sample(b, inner))
    return min(wa), min(wb)


# one timed sample should span at least this much wall time; the inner
# iteration count per kernel is derived from a warm pre-measurement and
# shared by both modes, so their samples are equally amortized
_MIN_SAMPLE_S = 8e-3


def _inner_count(dispatch: Callable[[], None]) -> int:
    once = _sample(dispatch, 1)
    return max(1, min(64, int(_MIN_SAMPLE_S / max(once, 1e-5))))


def _pallas_capable(g: DFG, length: int) -> bool:
    from repro.engine.capabilities import backend_skip_reason
    return backend_skip_reason(g, length, "pallas") is None


def run(length: int = 64, n_requests: int = 16, backend: str = "sim",
        fabric: Fabric = None, repeats: int = 5,
        kernels=None, mapper: str = None) -> List[dict]:
    """``kernels``: optional kernel-name subset to execute (e.g.
    perf_smoke's judged pair). The request streams still draw from the
    shared rng for every kernel, so a subset run stays stream-identical —
    and therefore cycle-comparable — with a full run.

    ``mapper`` pins the place & route ("greedy" | "anneal"); None follows
    ``STRELA_MAPPER``. Whatever is resolved lands in every row's
    ``mapper`` column so baselines from different mappers never get
    compared as if they were one population."""
    from repro.core.mapper import default_mapper
    fabric = fabric or Fabric()
    mapper = default_mapper() if mapper is None else mapper
    rng = np.random.default_rng(0)
    rows: List[dict] = []
    interpret = False
    if backend == "pallas":
        from repro.kernels.fabric_reduce import default_interpret
        interpret = default_interpret()
    for kname, factory in _KERNELS.items():
        g = factory(length)
        # request streams draw from the shared rng for EVERY kernel, even
        # skipped ones — stream parity across backends/subsets is what
        # makes the cycle columns comparable
        reqs = [_inputs(g, length, rng) for _ in range(n_requests)]
        if kernels is not None and kname not in kernels:
            continue
        if backend == "pallas" and not _pallas_capable(g, length):
            continue            # named skips live in the conformance gate

        # Both modes get their own engine instance, and both are compiled
        # AND warmed before either timed loop starts: the warmup dispatches
        # provide the cycle metrics and identically pre-populate every
        # cache either timed loop can touch (timing traces, shot
        # memoization, process-level allocator/JIT warmth). Interleaving
        # warmup and timing — the old layout — handed the later mode the
        # warmth the earlier one had paid for, which is exactly how the
        # phantom warm-path "batching regressions" were manufactured.
        naive = Engine(fabric=fabric, backend=backend, mapper=mapper,
                       cache=ArtifactCache(memory_only=True))
        batched = Engine(fabric=fabric, backend=backend, mapper=mapper,
                         cache=ArtifactCache(memory_only=True))
        art = naive.compile(g)
        art_b = batched.compile(g)

        def run_naive():
            return [naive.run(art, dict(ins)) for ins in reqs]

        def run_batched():
            handles = [batched.submit(art_b, dict(ins)) for ins in reqs]
            batched.flush()
            return handles

        t0 = time.perf_counter()
        outs_naive = run_naive()                 # warmup + cycle metrics
        t_naive_cold = time.perf_counter() - t0
        cycles_naive = naive.tally.total
        naive_overhead = naive.tally.config + naive.tally.rearm

        t0 = time.perf_counter()
        handles = run_batched()                  # warmup + cycle metrics
        t_batched_cold = time.perf_counter() - t0
        lane_batches_per_flush = batched.stats.lane_batches
        cycles_batched = batched.tally.total
        exec_cycles = batched.tally.exec
        batched_overhead = batched.tally.config + batched.tally.rearm

        # timed loops: isolated engines, fully warm, drift-paired samples
        inner = _inner_count(run_naive)
        t_naive, t_batched = _paired_walls(run_naive, run_batched,
                                           repeats, inner)

        row = {
            "kernel": kname,
            "backend": backend,
            "mapper": mapper,
            "geometry": f"{fabric.rows}x{fabric.cols}",
            "n_shots": art_b.n_shots,
            "length": length,
            "requests": n_requests,
            "repeats": repeats,
            "ii": art_b.estimated_ii(),
            "cycles_naive": cycles_naive,
            "cycles_batched": cycles_batched,
            "exec_cycles": exec_cycles,
            "config_rearm_naive": naive_overhead,
            "config_rearm_batched": batched_overhead,
            "rearm_cycles_saved": naive_overhead - batched_overhead,
            "wall_us_naive": t_naive * 1e6,
            "wall_us_batched": t_batched * 1e6,
            "wall_us_naive_cold": t_naive_cold * 1e6,
            "wall_us_batched_cold": t_batched_cold * 1e6,
            # batching must never cost wall time: the batched dispatch does
            # strictly less work (one flush, fewer config fetches). A True
            # here means scheduler overhead ate the savings — a warning,
            # not a failure (cycles are the contract), surfaced per row and
            # summarized by main(). The 5% margin is the residual noise
            # floor of the paired-min methodology above; flagging inside it
            # would just report timer jitter
            "batching_regressed": bool(t_batched > t_naive * 1.05),
        }
        if backend == "pallas":
            # value parity vs a sim engine over the identical requests —
            # both the per-request dispatches and the lane-batched flush;
            # asserted per (request, output, path) so a divergence names
            # exactly where it happened
            sim_eng = Engine(fabric=fabric, backend="sim", mapper=mapper,
                             cache=ArtifactCache(memory_only=True))
            sim_art = sim_eng.compile(g)
            for i, (ins, outs, h) in enumerate(zip(reqs, outs_naive,
                                                   handles)):
                want = sim_eng.run(sim_art, dict(ins))
                for o in want:
                    for path, got in (("run", outs[o]),
                                      ("flush", h.result()[o])):
                        assert np.array_equal(got, want[o]), (
                            f"{kname}: pallas {path} diverged from sim on "
                            f"request {i} output {o}: {got!r} != "
                            f"{want[o]!r}")
            row["values_match_sim"] = True       # unreachable otherwise
            row["interpret_mode"] = interpret
            # per-flush grid count (the engine stat is cumulative across
            # the warmup + timed repeats)
            row["lane_batches"] = lane_batches_per_flush
        rows.append(row)
    return rows


def write_json(rows: List[dict], path: str = "BENCH_engine.json") -> str:
    with open(path, "w") as f:
        json.dump({"bench": "engine", "rows": rows}, f, indent=2)
        f.write("\n")
    return path


def main(length: int = 64, n_requests: int = 16, json_path: str = "",
         geometries: Tuple[Tuple[int, int], ...] = ((4, 4),),
         repeats: int = 5,
         backends: Tuple[str, ...] = ("sim", "pallas")) -> List[dict]:
    rows: List[dict] = []
    for (r_, c_) in geometries:
        for backend in backends:
            geo_rows = run(length=length, n_requests=n_requests,
                           backend=backend, fabric=Fabric(rows=r_, cols=c_),
                           repeats=repeats)
            note = " [interpret mode: values verified vs sim, wall time " \
                   "measures the interpreter]" if backend == "pallas" else ""
            print(f"  {r_}x{c_} fabric, backend={backend}{note} (cycles are "
                  f"the primary metric; wall = best of {repeats} warm "
                  f"amortized samples)")
            print(f"  {'kernel':10s} {'II':>5s} {'cyc(naive)':>11s} "
                  f"{'cyc(batch)':>11s} {'saved':>7s} {'wall_ms(n)':>10s} "
                  f"{'wall_ms(b)':>10s}")
            for r in geo_rows:
                print(f"  {r['kernel']:10s} {r['ii']:5.2f} "
                      f"{r['cycles_naive']:11d} {r['cycles_batched']:11d} "
                      f"{r['rearm_cycles_saved']:7d} "
                      f"{r['wall_us_naive'] / 1e3:10.2f} "
                      f"{r['wall_us_batched'] / 1e3:10.2f}")
                # multi-shot plans alternate fabric configs internally, so
                # back-to-back requests legitimately save nothing
                if r["n_shots"] == 1:
                    assert r["rearm_cycles_saved"] > 0, (
                        f"{r['kernel']}: batching saved no overhead cycles")
                else:
                    assert r["rearm_cycles_saved"] >= 0, r
            rows.extend(geo_rows)
        # cycle columns are backend-independent (timing/value decoupling):
        # every pallas row must match its sim row exactly
        sim_by_kernel = {r["kernel"]: r for r in rows
                         if r["backend"] == "sim"
                         and r["geometry"] == f"{r_}x{c_}"}
        for r in rows:
            if r["backend"] != "pallas" or r["geometry"] != f"{r_}x{c_}":
                continue
            s = sim_by_kernel.get(r["kernel"])
            if s is None:
                continue
            for field in ("cycles_naive", "cycles_batched", "exec_cycles"):
                assert r[field] == s[field], (
                    f"{r['kernel']}: pallas {field} {r[field]} != sim "
                    f"{s[field]}")
    regressed = [r for r in rows if r["batching_regressed"]]
    if regressed:
        print(f"  WARNING: batched dispatch slower than naive (wall) on "
              f"{len(regressed)}/{len(rows)} rows:")
        for r in regressed:
            print(f"    {r['kernel']:10s} [{r['backend']}/{r['geometry']}] "
                  f"batched {r['wall_us_batched']:.0f} us > naive "
                  f"{r['wall_us_naive']:.0f} us "
                  f"(cycles still saved: {r['rearm_cycles_saved']})")
    if json_path:
        print(f"  wrote {write_json(rows, json_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=64,
                    help="stream length per request")
    ap.add_argument("--requests", type=int, default=16,
                    help="requests per kernel (>= 8 exercises the "
                         "acceptance-criterion batch size)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per mode (best sample reported)")
    ap.add_argument("--geometry", action="append", default=None,
                    metavar="RxC", help="fabric geometry to sweep "
                    "(repeatable; default 4x4)")
    ap.add_argument("--backend", action="append", default=None,
                    choices=("sim", "pallas"),
                    help="execution backend for the dispatch rows "
                         "(repeatable; default: both)")
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    geos = tuple(tuple(int(v) for v in s.lower().split("x"))
                 for s in (args.geometry or ["4x4"]))
    main(length=args.length, n_requests=args.requests,
         json_path=args.json, geometries=geos, repeats=args.repeats,
         backends=tuple(args.backend or ("sim", "pallas")))
