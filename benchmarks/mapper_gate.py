"""Mapper differential gate: anneal vs greedy over the conformance corpus.

Reuses the seeded random-DFG generator of ``tests/test_conformance.py``
(the same 230-case population the 5-way conformance gate pins) and, for a
corpus slice, maps every case twice — greedy and annealed — then asserts
the optimizer's contract *on the case's own reference workload*:

  * annealed outputs bit-exact with the greedy outputs AND with the
    case's independent pure-Python reference values;
  * annealed simulated cycles never worse than greedy;
  * annealed config footprint never worse than greedy.

Cases the greedy mapper cannot place, and cases whose greedy netlist
deadlocks (the 2-slot elastic-buffer liveness limit the conformance suite
documents), are counted as named skips — exactly like the conformance
gate treats them. A corpus case that anneals to different *values* is a
correctness bug in the optimizer and fails the gate immediately.

    PYTHONPATH=src python -m benchmarks.mapper_gate --cases 40
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List

import numpy as np

_TESTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests")


def _corpus():
    """The conformance suite's generator module (tests/ isn't a package)."""
    if _TESTS_DIR not in sys.path:
        sys.path.insert(0, _TESTS_DIR)
    import test_conformance as tc
    return tc


def run(n_cases: int = 40, start: int = 0, seed: int = 1,
        moves: int = 96, verbose: bool = True) -> dict:
    from repro.core.elastic_sim import simulate
    from repro.core.mapper import MappingError, map_dfg
    from repro.core.opt_mapper import anneal_map

    tc = _corpus()
    stats = {"verified": 0, "improved_cycles": 0, "improved_config": 0,
             "skip_unmappable": 0, "skip_deadlock": 0,
             "cycles_saved": 0, "config_cycles_saved": 0}
    t0 = time.perf_counter()
    for i in range(start, start + n_cases):
        length = (8, 16, 24)[i % 3]
        g, inputs, refs = tc._mk_case(i, length)
        try:
            # the conformance suite's exact greedy P&R call
            greedy = map_dfg(g, restarts=60, seed=seed, optimize="greedy")
        except MappingError:
            stats["skip_unmappable"] += 1
            continue
        try:
            gsim = simulate(greedy, dict(inputs))
        except RuntimeError as e:
            if "deadlock" in str(e):
                stats["skip_deadlock"] += 1
                continue
            raise
        annealed = anneal_map(g, seed=seed, baseline=greedy, moves=moves,
                              extra_probes=[dict(inputs)])
        asim = simulate(annealed, dict(inputs))

        assert set(asim.outputs) == set(gsim.outputs), (
            f"case {i} ({g.name}): annealed output set diverged")
        for o, want in gsim.outputs.items():
            got = asim.outputs[o]
            assert np.array_equal(got, want), (
                f"case {i} ({g.name}): annealed values diverged from "
                f"greedy on {o}: {got.tolist()[:8]} != {want.tolist()[:8]}")
            if o in refs:
                assert got.tolist() == refs[o], (
                    f"case {i} ({g.name}): annealed values diverged from "
                    f"the pure-Python reference on {o}")
        assert asim.cycles <= gsim.cycles, (
            f"case {i} ({g.name}): annealed cycles {asim.cycles} worse "
            f"than greedy {gsim.cycles}")
        assert annealed.config_cycles() <= greedy.config_cycles(), (
            f"case {i} ({g.name}): annealed config "
            f"{annealed.config_cycles()} worse than greedy "
            f"{greedy.config_cycles()}")

        stats["verified"] += 1
        if asim.cycles < gsim.cycles:
            stats["improved_cycles"] += 1
            stats["cycles_saved"] += gsim.cycles - asim.cycles
        if annealed.config_cycles() < greedy.config_cycles():
            stats["improved_config"] += 1
            stats["config_cycles_saved"] += \
                greedy.config_cycles() - annealed.config_cycles()
        if verbose:
            mark = ""
            if annealed.config_cycles() < greedy.config_cycles():
                mark = (f"  cfg {greedy.config_cycles()}->"
                        f"{annealed.config_cycles()}")
            if asim.cycles < gsim.cycles:
                mark += f"  cyc {gsim.cycles}->{asim.cycles}"
            print(f"  case {i:3d} {g.name:8s} len={length:2d} ok{mark}")
    stats["wall_s"] = time.perf_counter() - t0
    return stats


def main(argv: List[str] = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cases", type=int, default=40,
                    help="corpus slice size (seeds start..start+cases)")
    ap.add_argument("--start", type=int, default=0)
    ap.add_argument("--seed", type=int, default=1,
                    help="P&R seed (the conformance suite uses 1)")
    ap.add_argument("--moves", type=int, default=96,
                    help="anneal move budget per case (small on purpose: "
                         "the gate checks the contract, not peak gains)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)
    stats = run(n_cases=args.cases, start=args.start, seed=args.seed,
                moves=args.moves, verbose=not args.quiet)
    print(f"  mapper-gate: {stats['verified']} verified "
          f"(values bit-exact vs greedy + reference, cycles/config never "
          f"worse), {stats['improved_config']} config-improved "
          f"(-{stats['config_cycles_saved']} cycles), "
          f"{stats['improved_cycles']} cycle-improved "
          f"(-{stats['cycles_saved']}), "
          f"{stats['skip_unmappable']} unmappable, "
          f"{stats['skip_deadlock']} deadlocked "
          f"[{stats['wall_s']:.1f}s]")
    assert stats["verified"] > 0, "gate verified nothing"
    return stats


if __name__ == "__main__":
    main()
