"""CI perf smoke: fail when sim dispatch wall time regresses > Nx baseline.

Runs ``bench_engine`` and judges a two-kernel subset — one static-rate
kernel (``fft``, exercising the timing-trace replay path) and one
irregular loop (``div_loop``, exercising the element-parallel value path
plus live simulation) — comparing the measured warm-dispatch wall times
against the checked-in ``benchmarks/perf_baseline.json``. The budget is
``baseline * factor`` (default 2x, per ISSUE 4): generous enough for CI
machine variance, tight enough that losing the trace cache or the
vectorized executor (both ~5-10x) fails the build.

Pallas rows (ISSUE 5) are judged differently: in interpret mode wall time
measures the Pallas interpreter, not the substrate, so **no wall-clock
budget applies** — instead every pallas row must assert bit-exact value
parity against the sim backend (``values_match_sim``) and identical cycle
columns (timing/value decoupling).

The serve gate (ISSUE 8) replays a fixed-seed 200-request soak through
``repro.serve`` under the virtual clock: served/rejected/failed counts
are pinned exactly (the run is deterministic) and the p99 latency — in
machine-independent virtual microseconds — must meet the pinned budget.

The model-mix gate (ISSUE 10) replays the same kind of soak over the
transformer/SSM/MoE workload classes of ``repro.workloads``: counts and
the preemption tally are pinned exactly and every served response must
re-verify bit-exactly against its ``jnp`` oracle — so a semantics drift
in any model-layer kernel fails the build even if scheduling is intact.

The fleet gate (ISSUE 9) does the same for the multi-fabric scheduler: a
fixed-seed 3-fabric soak with one fabric scripted to die mid-run pins
served/rejected/failed *and* the fault-drain tally exactly, plus a
virtual-time p99 budget — drift means placement, stealing, or the drain
path changed behavior.

    PYTHONPATH=src python -m benchmarks.perf_smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import bench_engine

BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "perf_baseline.json")
SMOKE_KERNELS = ("fft", "div_loop")
# pallas parity subset: one streaming kernel, one reduction kernel
PALLAS_SMOKE_KERNELS = ("fft", "mac1")


def calibrate() -> float:
    """Wall microseconds of a fixed deterministic workload (one reference
    simulation of relu over 64 elements), used to scale the checked-in
    budgets to the executing machine: a CI runner 3x slower than the
    baseline machine gets a 3x larger budget instead of a red build,
    while a faster runner keeps the baseline budget (never tightened)."""
    import numpy as np
    from repro.core import kernels_lib as K
    from repro.core.elastic_sim_ref import simulate_reference
    from repro.core.mapper import map_dfg

    g = K.relu()
    m = map_dfg(g, restarts=300)
    rng = np.random.default_rng(0)
    ins = {k: rng.integers(-64, 64, 64).astype(np.int32) for k in g.inputs}
    simulate_reference(m, ins)                       # warm
    return bench_engine._median_wall(
        lambda: simulate_reference(m, ins), 5) * 1e6


def main(factor: float = 2.0, baseline_path: str = BASELINE_PATH) -> int:
    from repro import obs

    # the wall budgets below are defined for the obs-disabled default
    # (STRELA_OBS=0): instrumentation must cost nothing when off, so the
    # smoke run both requires obs off up front and asserts afterwards that
    # the benchmark left zero observability residue behind
    if obs.enabled():
        print("  perf smoke requires STRELA_OBS=0 (budgets are defined "
              "for the zero-overhead disabled mode)")
        return 1
    with open(baseline_path) as f:
        baseline = json.load(f)
    scale = 1.0
    if baseline.get("calib_us"):
        scale = max(1.0, calibrate() / baseline["calib_us"])
    # run the full kernel set (the request streams draw from one shared
    # seeded rng, so subsetting would shift the data-dependent cycle
    # counts); wall budgets judge only the two smoke kernels, but all sim
    # rows stay around for the pallas cycle-identity comparison below
    rows_sim = bench_engine.run(length=baseline["length"],
                                n_requests=baseline["requests"])
    rows = [r for r in rows_sim if r["kernel"] in SMOKE_KERNELS]
    assert {r["kernel"] for r in rows} == set(SMOKE_KERNELS), (
        f"perf smoke kernels missing from bench rows: got "
        f"{[r['kernel'] for r in rows]}, want {SMOKE_KERNELS}")
    failures = []
    print(f"  perf smoke (budget = baseline x {factor:g} x machine scale "
          f"{scale:.2f})")
    for r in rows:
        base = baseline["kernels"][r["kernel"]]
        for field in ("wall_us_naive", "wall_us_batched"):
            budget = base[field] * factor * scale
            status = "ok" if r[field] <= budget else "REGRESSED"
            print(f"  {r['kernel']:10s} {field:16s} "
                  f"{r[field] / 1e3:8.2f} ms (budget "
                  f"{budget / 1e3:8.2f} ms) {status}")
            if r[field] > budget:
                failures.append((r["kernel"], field, r[field], budget))
        # cycle metrics are exact: any drift is a correctness failure
        for field in ("cycles_naive", "cycles_batched"):
            if r[field] != base[field]:
                print(f"  {r['kernel']:10s} {field:16s} {r[field]} != "
                      f"baseline {base[field]} CYCLES DRIFTED")
                failures.append((r["kernel"], field, r[field], base[field]))

    # pallas rows: no wall budget in interpret mode; value parity and
    # cycle identity with the sim rows measured in this same process (all
    # three cycle columns) are mandatory. Judged on a two-kernel subset —
    # one streaming (fft), one reduction (mac1) — because interpret-mode
    # dispatch is slow and the full pallas sweep already runs (and
    # asserts parity) in the bench_engine CI step; the subset stays
    # stream-identical to the sim rows via run(kernels=...)
    try:
        rows_p = bench_engine.run(length=baseline["length"],
                                  n_requests=baseline["requests"],
                                  backend="pallas", repeats=1,
                                  kernels=PALLAS_SMOKE_KERNELS)
    except AssertionError as e:       # run() asserts parity per request
        rows_p = []
        print(f"  pallas value parity FAILED: {e}")
        failures.append(("pallas", "values_match_sim", str(e)[:120], True))
    sim_by_kernel = {r["kernel"]: r for r in rows_sim}
    print(f"  pallas rows (interpret mode: value parity + cycle identity "
          f"vs sim judged, wall budgets skipped)")
    for r in rows_p:
        ok = r.get("values_match_sim") is True
        print(f"  {r['kernel']:10s} values_match_sim={ok} "
              f"cycles_naive={r['cycles_naive']}")
        if not ok:
            failures.append((r["kernel"], "values_match_sim", False, True))
        s = sim_by_kernel[r["kernel"]]
        for field in ("cycles_naive", "cycles_batched", "exec_cycles"):
            if r[field] != s[field]:
                print(f"  {r['kernel']:10s} pallas {field} {r[field]} != "
                      f"sim {s[field]} CYCLES DIVERGED")
                failures.append((r["kernel"], f"pallas_{field}",
                                 r[field], s[field]))
    if {r["kernel"] for r in rows_p} != set(PALLAS_SMOKE_KERNELS):
        failures.append(("pallas", "rows",
                         sorted(r["kernel"] for r in rows_p),
                         PALLAS_SMOKE_KERNELS))

    # serve smoke (ISSUE 8): a fixed-seed soak through the serving loop
    # under the virtual clock. Counts are pinned EXACTLY — the virtual
    # clock makes the whole run deterministic, so a changed served/
    # rejected/failed split means the scheduler's behavior drifted. The
    # p99 budget is virtual-time (modeled cycles): machine-independent,
    # hence no factor/scale applied.
    sb = baseline.get("serve")
    if sb is not None:
        from benchmarks.bench_serve import calibrate as serve_calibrate
        from benchmarks.bench_serve import soak
        mean_us, _ = serve_calibrate("sim", baseline["length"], True)
        _, rep = soak(seed=sb["seed"], n_requests=sb["requests"],
                      length=baseline["length"], backend="sim",
                      rate_per_us=sb["offered_load"] / mean_us)
        p99 = rep["latency"]["p99_us"]
        print(f"  serve gate: seed={sb['seed']} requests={sb['requests']} "
              f"load={sb['offered_load']}x -> served={rep['served']} "
              f"rejected={rep['rejected']} failed={rep['failed']} "
              f"p99={p99:.1f} us (budget {sb['p99_budget_us']:.1f} "
              f"virtual us)")
        for field in ("served", "rejected", "failed"):
            if rep[field] != sb[field]:
                print(f"  serve {field} {rep[field]} != pinned "
                      f"{sb[field]} ACCOUNTING DRIFTED")
                failures.append(("serve", field, rep[field], sb[field]))
        total = rep["served"] + rep["rejected"] + rep["failed"]
        if rep["offered"] != sb["requests"] or total != rep["offered"]:
            print(f"  serve accounting leak: offered={rep['offered']} "
                  f"served+rejected+failed={total}")
            failures.append(("serve", "accounting", total, rep["offered"]))
        if p99 > sb["p99_budget_us"]:
            print(f"  serve p99 {p99:.1f} us > budget "
                  f"{sb['p99_budget_us']:.1f} us REGRESSED")
            failures.append(("serve", "p99_us", p99, sb["p99_budget_us"]))

    # model-mix serve smoke (ISSUE 10): the same fixed-seed soak over the
    # transformer/SSM/MoE workload classes. Counts AND the preemption
    # tally are pinned exactly, and every served response must re-verify
    # bit-exactly against its jnp oracle — a drift here means either the
    # scheduler or a model-layer kernel's semantics changed.
    mb = baseline.get("serve_model")
    if mb is not None:
        from benchmarks.bench_serve import soak as model_soak
        _, mrep = model_soak(seed=mb["seed"], n_requests=mb["requests"],
                             length=baseline["length"], backend="sim",
                             rate_per_us=mb["rate_per_us"], mix="model")
        mp99 = mrep["latency"]["p99_us"]
        print(f"  model gate: seed={mb['seed']} requests={mb['requests']} "
              f"rate={mb['rate_per_us']:g}/us -> served={mrep['served']} "
              f"rejected={mrep['rejected']} failed={mrep['failed']} "
              f"preemptions={mrep['preemptions']} "
              f"oracle={mrep['oracle_checked']}/"
              f"{mrep['oracle_mismatches']} p99={mp99:.1f} us "
              f"(budget {mb['p99_budget_us']:.1f} virtual us)")
        for field in ("served", "rejected", "failed", "preemptions"):
            if mrep[field] != mb[field]:
                print(f"  model {field} {mrep[field]} != pinned "
                      f"{mb[field]} ACCOUNTING DRIFTED")
                failures.append(("serve_model", field, mrep[field],
                                 mb[field]))
        if mrep["oracle_mismatches"] != 0 \
                or mrep["oracle_checked"] != mrep["served"]:
            print(f"  model oracle divergence: "
                  f"{mrep['oracle_mismatches']} mismatches over "
                  f"{mrep['oracle_checked']}/{mrep['served']} served")
            failures.append(("serve_model", "oracle",
                             mrep["oracle_mismatches"], 0))
        if mp99 > mb["p99_budget_us"]:
            print(f"  model p99 {mp99:.1f} us > budget "
                  f"{mb['p99_budget_us']:.1f} us REGRESSED")
            failures.append(("serve_model", "p99_us", mp99,
                             mb["p99_budget_us"]))

    # fleet smoke (ISSUE 9): a fixed-seed multi-fabric soak with one
    # fabric scripted to die mid-run. Counts — including how many
    # requests the fault-drain moved — are pinned exactly; the p99
    # budget is virtual-time, so no factor/scale applies. A drift here
    # means placement, stealing, or the drain path changed behavior.
    fb = baseline.get("fleet")
    if fb is not None:
        from repro.engine import ArtifactCache
        from repro.fleet import fleet_soak, homogeneous
        cfg = homogeneous(fb["fabrics"], n_requests=fb["requests"],
                          rate_per_us=fb["rate_per_us"],
                          fail_at=((fb["fail_fabric"], fb["fail_at_us"]),))
        _, frep = fleet_soak(fb["seed"], cfg,
                             cache=ArtifactCache(memory_only=True))
        fp99 = frep["latency"]["p99_us"]
        print(f"  fleet gate: seed={fb['seed']} fabrics={fb['fabrics']} "
              f"requests={fb['requests']} kill {fb['fail_fabric']}@"
              f"{fb['fail_at_us']:g}us -> served={frep['served']} "
              f"rejected={frep['rejected']} failed={frep['failed']} "
              f"drained={frep['drained']} p99={fp99:.1f} us "
              f"(budget {fb['p99_budget_us']:.1f} virtual us)")
        for field in ("served", "rejected", "failed", "drained"):
            if frep[field] != fb[field]:
                print(f"  fleet {field} {frep[field]} != pinned "
                      f"{fb[field]} ACCOUNTING DRIFTED")
                failures.append(("fleet", field, frep[field], fb[field]))
        ftotal = frep["served"] + frep["rejected"] + frep["failed"]
        if frep["offered"] != fb["requests"] or ftotal != frep["offered"]:
            print(f"  fleet accounting leak: offered={frep['offered']} "
                  f"served+rejected+failed={ftotal}")
            failures.append(("fleet", "accounting", ftotal,
                             frep["offered"]))
        if frep["dead"] != [fb["fail_fabric"]]:
            failures.append(("fleet", "dead", frep["dead"],
                             [fb["fail_fabric"]]))
        if fp99 > fb["p99_budget_us"]:
            print(f"  fleet p99 {fp99:.1f} us > budget "
                  f"{fb['p99_budget_us']:.1f} us REGRESSED")
            failures.append(("fleet", "p99_us", fp99, fb["p99_budget_us"]))

    # obs smoke: the entire bench ran through the instrumented pipeline
    # with observability disabled — not one span may have been recorded
    # and no tracer/registry may have materialized (the disabled path is
    # the zero-overhead contract the wall budgets above price in)
    if obs.enabled() or obs.ring_len() != 0 or obs.registry() is not None:
        print(f"  OBS LEAKED: enabled={obs.enabled()} "
              f"ring={obs.ring_len()} registry={obs.registry()!r}")
        failures.append(("obs", "disabled_mode_noop", obs.ring_len(), 0))
    else:
        print("  obs disabled-mode no-op: ok (0 spans, no registry)")

    if failures:
        print(f"  PERF SMOKE FAILED: {failures}")
        return 1
    print("  perf smoke passed")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--factor", type=float, default=2.0,
                    help="allowed slowdown over the checked-in baseline")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args()
    sys.exit(main(factor=args.factor, baseline_path=args.baseline))
