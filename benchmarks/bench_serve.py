"""Serving benchmark: throughput and tail latency vs offered load.

Drives :class:`repro.serve.ServeEngine` under the deterministic virtual
clock with seeded open-loop Poisson traffic at several offered-load
points (fractions of the calibrated service capacity), on both backends.
Each row reports throughput — both the wall figure (served / full run
duration, drain tail included) and the steady-state figure (served /
first-arrival-to-last-completion window), which is the honest sustained
rate — p50/p99 latency, admission outcomes, and the
config-cycle ledger — ``config_cycles_paid`` (what the continuous batcher
actually spent on reconfiguration) vs ``config_cycles_naive`` (what
per-request ``Engine.run`` dispatch would have paid). The acceptance
claim of ISSUE 8 is asserted here: at the highest offered load the
continuous batcher is **strictly cheaper in config cycles than naive**
(that is the paper's reconfiguration-amortization story applied to
traffic, Sec. IV-B).

Everything is a pure function of the seed: the rows embed each run's
``trace_digest`` so two machines producing the same BENCH_serve.json can
be diffed decision-for-decision.

Mixes: ``--mix paper`` drives the paper's kernel classes, ``--mix model``
the transformer/SSM/MoE layer classes of ``repro.workloads`` (realistic
model-serving traffic). Model rows additionally re-verify **every served
response against its class's jnp reference oracle** and report
``oracle_match`` — the bench-level half of the workload differential gate
(tests/test_workloads.py is the other half).

Backends: sim rows serve every class; classes a backend cannot lower are
dropped with named capability reasons by ``serve_classes`` (e.g. the
irregular-loop and SSM-recurrence classes on pallas), and pallas rows use
a smaller request count because interpret mode executes on the CPU
interpreter. Timing columns are virtual-clock microseconds — modeled
fabric cycles, not host wall time — so they are machine-independent on
both backends.

CLI::

    PYTHONPATH=src python -m benchmarks.bench_serve --requests 200
    PYTHONPATH=src python -m benchmarks.bench_serve --mix model
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.engine import ArtifactCache, Engine
from repro.serve import (ServeConfig, ServeEngine, bursty_arrival_times,
                         make_requests, poisson_arrival_times,
                         request_inputs, serve_classes)

# offered load as a fraction of calibrated single-server capacity:
# under-loaded (batching must not hurt latency), saturated, and
# over-driven (admission control + batching must hold the line)
LOAD_POINTS: Tuple[float, ...] = (0.25, 1.0, 3.0)


def _fresh_engine(backend: str) -> Engine:
    return Engine(backend=backend, cache=ArtifactCache(memory_only=True))


def _mix_weights(mix: str) -> Optional[Dict[str, float]]:
    """The class-mix bias: model traffic uses the registry's arrival
    weights (transformer-block-heavy); the paper mix stays uniform."""
    if mix == "model":
        from repro.workloads import model_weights
        return model_weights()
    return None


def calibrate(backend: str, length: int,
              include_loops: Optional[bool] = None,
              mix: str = "paper") -> Tuple[float, Dict[str, object]]:
    """Mean modeled service time (us/request) of the class mix, measured
    by one naive dispatch per class on a throwaway engine."""
    eng = _fresh_engine(backend)
    classes = serve_classes(eng, length, include_loops=include_loops,
                            mix=mix)
    rng = np.random.default_rng(0)
    before = eng.tally.total
    for label, art in classes.items():
        eng.run(art, request_inputs(art, length, rng, label=label))
    cycles = eng.tally.total - before
    cfg = ServeConfig()
    return (cycles / len(classes)) * cfg.us_per_cycle, classes


def verify_model_outputs(serve: ServeEngine,
                         classes: Dict[str, object]) -> Tuple[int, int]:
    """Re-verify every served model-class response against its registered
    jnp oracle; returns ``(checked, mismatches)``. The bench-level
    differential assertion of the workload bridge: what the serving loop
    returned under batching/preemption must be bit-exact with the
    reference closure, per class, per request."""
    from repro.workloads import MODEL_CLASSES
    by_name = {a.name: l for l, a in classes.items()}
    checked = mismatches = 0
    for tk in serve.served:
        wc = MODEL_CLASSES.get(by_name.get(tk.artifact.name, ""))
        if wc is None:
            continue
        checked += 1
        want = wc.oracle(**tk.inputs)
        for i, w in enumerate(want):
            got = np.ravel(np.asarray(tk.outputs[f"out{i}"]))
            if not np.array_equal(got, np.ravel(w)):
                mismatches += 1
                break
    return checked, mismatches


def soak(seed: int, n_requests: int, length: int = 64,
         backend: str = "sim", rate_per_us: Optional[float] = None,
         config: Optional[ServeConfig] = None,
         include_loops: Optional[bool] = None,
         bursty: bool = False, mix: str = "paper"
         ) -> Tuple[ServeEngine, Dict]:
    """One deterministic serve run: seeded workload -> drive -> report.

    The single entry point shared by this benchmark, the perf_smoke serve
    gate, and tests/test_serve.py's cross-process replay check — same
    (seed, args) means bit-identical trace and results everywhere.
    Returns ``(serve_engine, report)``; model-mix reports carry the
    oracle re-verification tally (``oracle_checked`` / ``oracle_
    mismatches``)."""
    engine = _fresh_engine(backend)
    classes = serve_classes(engine, length, include_loops=include_loops,
                            mix=mix)
    cfg = config or ServeConfig()
    rng = np.random.default_rng(seed)
    if rate_per_us is None:
        mean_us, _ = calibrate(backend, length, include_loops, mix=mix)
        rate_per_us = 1.0 / mean_us
    if bursty:
        times = bursty_arrival_times(rng, n_requests, burst_size=16,
                                     gap_us=8.0 / rate_per_us)
    else:
        times = poisson_arrival_times(rng, n_requests, rate_per_us)
    reqs = make_requests(classes, times, length, rng,
                         weights=_mix_weights(mix))
    serve = ServeEngine(engine, cfg)
    report = serve.drive(reqs)
    report["results_digest"] = serve.results_digest()
    if mix != "paper":
        checked, bad = verify_model_outputs(serve, classes)
        report["oracle_checked"] = checked
        report["oracle_mismatches"] = bad
    return serve, report


def run(length: int = 64, n_requests: int = 200, backend: str = "sim",
        seed: int = 0, loads: Tuple[float, ...] = LOAD_POINTS,
        mix: str = "paper") -> List[dict]:
    mean_us, classes = calibrate(backend, length, mix=mix)
    rows: List[dict] = []
    for load in loads:
        rate = load / mean_us
        _, rep = soak(seed, n_requests, length=length, backend=backend,
                      rate_per_us=rate, mix=mix)
        lat = rep["latency"]
        rows.append({
            "backend": backend,
            "mix": mix,
            "length": length,
            "requests": n_requests,
            "seed": seed,
            "classes": len(classes),
            "offered_load": load,
            "offered_rps": rate * 1e6,
            "duration_us": rep["now_us"],
            # wall throughput counts the pre-traffic lead-in and the
            # post-admission drain tail; steady-state throughput divides
            # by the actual service window (first served arrival to last
            # completion) — the honest sustained-rate figure, which under
            # light load the wall figure badly understates
            "throughput_rps": rep["served"] / rep["now_us"] * 1e6,
            "steady_window_us": rep["steady_window_us"],
            "steady_throughput_rps":
                rep["served"] / rep["steady_window_us"] * 1e6
                if rep["steady_window_us"] else None,
            "served": rep["served"],
            "rejected": rep["rejected"],
            "failed": rep["failed"],
            "preemptions": rep["preemptions"],
            "batches": rep["batches"],
            "close_reasons": rep["close_reasons"],
            "p50_us": lat["p50_us"] if lat["count"] else None,
            "p99_us": lat["p99_us"] if lat["count"] else None,
            "config_cycles_paid": rep["config_cycles_paid"],
            "config_cycles_naive": rep["config_cycles_naive"],
            "config_cycles_saved": rep["config_cycles_saved"],
            "trace_digest": rep["trace_digest"],
            "results_digest": rep["results_digest"],
        })
        if mix != "paper":
            # the workload differential gate, bench half: every served
            # model-layer response was re-checked against its jnp oracle
            rows[-1]["oracle_checked"] = rep["oracle_checked"]
            rows[-1]["oracle_match"] = rep["oracle_mismatches"] == 0
            assert rep["oracle_mismatches"] == 0, (
                f"{backend}/{mix}: {rep['oracle_mismatches']} of "
                f"{rep['oracle_checked']} served responses diverged from "
                f"the jnp oracle at load {load}x")
            assert rep["oracle_checked"] == rep["served"], (
                f"{backend}/{mix}: oracle covered "
                f"{rep['oracle_checked']} of {rep['served']} served")
    # the acceptance claim: under the heaviest traffic, continuous
    # batching pays strictly fewer config cycles than per-request dispatch
    top = rows[-1]
    assert top["config_cycles_paid"] < top["config_cycles_naive"], (
        f"{backend}: continuous batching saved nothing at load "
        f"{top['offered_load']}x: paid {top['config_cycles_paid']} vs "
        f"naive {top['config_cycles_naive']}")
    return rows


def write_json(rows: List[dict], path: str = "BENCH_serve.json") -> str:
    with open(path, "w") as f:
        json.dump({"bench": "serve", "rows": rows}, f, indent=2)
        f.write("\n")
    return path


def main(length: int = 64, n_requests: int = 200,
         pallas_requests: int = 48, json_path: str = "BENCH_serve.json",
         seed: int = 0, backends: Tuple[str, ...] = ("sim", "pallas"),
         mixes: Tuple[str, ...] = ("paper",)) -> List[dict]:
    rows: List[dict] = []
    for mix in mixes:
        for backend in backends:
            n = n_requests if backend == "sim" else pallas_requests
            note = " [interpret mode; capability-ineligible classes " \
                   "dropped]" if backend == "pallas" else ""
            print(f"  mix={mix}, backend={backend}, {n} requests{note} "
                  f"(latencies are virtual-clock us — modeled cycles, "
                  f"machine-independent)")
            brows = run(length=length, n_requests=n, backend=backend,
                        seed=seed, mix=mix)
            print(f"  {'load':>5s} {'offer rps':>10s} {'wall rps':>10s} "
                  f"{'steady rps':>10s} {'p50 us':>8s} {'p99 us':>8s} "
                  f"{'srv':>4s} {'rej':>4s} {'pre':>4s} {'cfg paid':>9s} "
                  f"{'cfg naive':>9s} {'oracle':>6s}")
            for r in brows:
                steady = r["steady_throughput_rps"]
                oracle = {True: "ok", False: "FAIL"}.get(
                    r.get("oracle_match"), "-")
                print(f"  {r['offered_load']:5.2f} "
                      f"{r['offered_rps']:10.0f} "
                      f"{r['throughput_rps']:10.0f} "
                      f"{steady if steady is None else round(steady):>10} "
                      f"{r['p50_us']:8.1f} {r['p99_us']:8.1f} "
                      f"{r['served']:4d} {r['rejected']:4d} "
                      f"{r['preemptions']:4d} "
                      f"{r['config_cycles_paid']:9d} "
                      f"{r['config_cycles_naive']:9d} {oracle:>6s}")
            rows.extend(brows)
    if json_path:
        print(f"  wrote {write_json(rows, json_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--requests", type=int, default=200,
                    help="sim requests per load point")
    ap.add_argument("--pallas-requests", type=int, default=48,
                    help="pallas requests per load point (interpret mode "
                         "is CPU-bound)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", action="append", default=None,
                    choices=("sim", "pallas"))
    ap.add_argument("--mix", action="append", default=None,
                    choices=("paper", "model"),
                    help="class mixes to drive (repeatable; default "
                         "paper)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    main(length=args.length, n_requests=args.requests,
         pallas_requests=args.pallas_requests, json_path=args.json,
         seed=args.seed, backends=tuple(args.backend or ("sim", "pallas")),
         mixes=tuple(args.mix or ("paper",)))
