"""Benchmark aggregator: one section per paper table + kernel micros +
calibration reports. Prints ``name,us_per_call,derived`` CSV rows at the
end (harness contract) and a human-readable report above them.
"""
from __future__ import annotations

import os
import sys
import time

# allow `python benchmarks/run.py` (the documented invocation): the
# `benchmarks` package resolves relative to the repo root, not this file
if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from repro.core import paper_data as PD
from repro.core.energy import PowerModel, features_from_sim
from repro.core.soc import cpu_model_report


def main() -> None:
    t_start = time.time()
    from benchmarks import (bench_compare, bench_engine, bench_kernels,
                            bench_multishot, bench_oneshot)

    # ---- calibrate the power model across ALL 12 paper samples ----
    print("=" * 72)
    print("Power-model calibration (fitted on Tables I+II samples)")
    multi_collected = bench_multishot.collect()
    pm = PowerModel()
    from repro.core.elastic_sim import simulate
    from repro.core.paper_mappings import paper_mapping
    samples = []
    rng = np.random.default_rng(0)
    for name, paper_key in bench_oneshot._PAPER_ROW.items():
        if name == "find2min_brmg":
            continue
        m = paper_mapping(name)
        sim = simulate(m, bench_oneshot._inputs_for(name, rng))
        t1 = PD.TABLE_I[paper_key]
        samples.append(features_from_sim(m, sim, 1.0, t1[5], t1[11]))
    samples += [f for _, _, _, f in multi_collected if f is not None]
    pm.fit(samples)
    errs = [abs(r["cgra_rel_err"]) for r in pm.report()]
    print(f"  CGRA power fit: mean |err| = {100*np.mean(errs):.1f}% over "
          f"{len(errs)} samples; coefficients beta={np.round(pm.beta, 3)}")

    print("=" * 72)
    print("CPU cycle model calibration (CV32E40P, fixed architectural "
          "weights)")
    cerrs = []
    for r in cpu_model_report():
        cerrs.append(abs(r["rel_err"]))
        print(f"  {r['kernel']:10s} paper={r['paper_cpu_cycles']:8d} "
              f"model={r['model_cpu_cycles']:8d} "
              f"err={r['rel_err']*100:+6.1f}%")
    print(f"  mean |err| = {100*np.mean(cerrs):.1f}%")

    print("=" * 72)
    print("Table I — one-shot kernels")
    bench_oneshot.main()
    print("=" * 72)
    print("Table II — multi-shot kernels")
    bench_multishot.main()
    print("=" * 72)
    print("Table IV — state-of-the-art comparison")
    bench_compare.main()
    print("=" * 72)
    print("Pallas kernel micro-benchmarks")
    bench_kernels.main()
    print("=" * 72)
    print("Execution engine — batched vs naive dispatch")
    engine_rows = bench_engine.main(json_path="BENCH_engine.json")
    print("=" * 72)
    print("Elastic simulator — reference vs vectorized core (+ lane mode)")
    from benchmarks import bench_sim
    bench_sim.main(json_path="BENCH_sim.json")

    # ---- harness CSV contract ----
    print("=" * 72)
    print("name,us_per_call,derived")
    clock = PD.CLOCK_MHZ
    for r in bench_oneshot.run(pm):
        us = r["exec_cycles"] / clock
        print(f"oneshot_{r['kernel']},{us:.3f},"
              f"perf_mops={r['perf_mops']:.1f};paper_err="
              f"{r['cycles_err']:+.3f}")
    for r in bench_multishot.run(pm):
        us = r["total_cycles"] / clock
        print(f"multishot_{r['kernel']},{us:.3f},"
              f"perf_mops={r['perf_mops']:.1f};paper_err="
              f"{r['cycles_err']:+.3f}")
    for r in bench_kernels.run():
        est = (f"tpu_roofline_us={r['tpu_roofline_us']:.3f}"
               if "tpu_roofline_us" in r
               else f"fabric_sim_us={r['fabric_sim_us']:.3f}")
        print(f"kernel_{r['kernel']},{r['us_xla_cpu']:.3f},{est}")
    for r in engine_rows:
        us = r["cycles_batched"] / clock
        print(f"engine_{r['kernel']},{us:.3f},"
              f"ii={r['ii']:.2f};rearm_saved={r['rearm_cycles_saved']}")
    print(f"# total wall time {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
