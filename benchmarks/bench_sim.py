"""Simulator benchmark: reference vs vectorized core, plus lane mode.

Measures, per kernel, the wall time of one cycle-accurate simulation under

  * the **reference** simulator (``elastic_sim_ref``, the original
    token-by-token implementation kept as the ``STRELA_SIM=reference``
    differential oracle),
  * the **fast** core (``elastic_sim``: integer station ids, precomputed
    fall-through structure, Python-int datapath),
  * the **lane-parallel** mode (``simulate_lanes``: N same-mapping
    requests advancing through one compiled station graph per sweep),

asserting cycle counts and outputs stay bit-identical, and records the
speedups in ``BENCH_sim.json`` — the before/after artifact for ISSUE 4's
"same cycles, less wall time" claim. Where a kernel is static-rate the row
also reports the trace-replay time: the cost of a *repeat* dispatch once
the ``TimingTrace`` is cached (value computation excluded).

    PYTHONPATH=src python -m benchmarks.bench_sim --length 64 --lanes 16
"""
from __future__ import annotations

import argparse
import json
from typing import Callable, Dict, List

import numpy as np

from benchmarks.bench_engine import _median_wall
from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.elastic_sim import TimingTrace, simulate, simulate_lanes
from repro.core.elastic_sim_ref import simulate_reference
from repro.core.executor import execute
from repro.core.mapper import map_dfg

_KERNELS: Dict[str, Callable[[], DFG]] = {
    "relu": K.relu,
    "vadd": K.vadd,
    "fft": K.fft_butterfly,
    "dither": K.dither,
    "div_loop": lambda: K.div_loop(7),
}


def run(length: int = 64, lanes: int = 16, repeats: int = 5) -> List[dict]:
    rng = np.random.default_rng(0)
    rows: List[dict] = []
    for kname, factory in _KERNELS.items():
        g = factory()
        m = map_dfg(g, restarts=300)
        lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
        ins = {name: rng.integers(lo, hi, length).astype(np.int32)
               for name in g.inputs}
        batch = [{name: rng.integers(lo, hi, length).astype(np.int32)
                  for name in g.inputs} for _ in range(lanes)]

        ref = simulate_reference(m, ins)
        fast = simulate(m, ins)
        assert ref.cycles == fast.cycles, (kname, ref.cycles, fast.cycles)
        assert all(ref.outputs[k].tolist() == fast.outputs[k].tolist()
                   for k in ref.outputs), kname

        t_ref = _median_wall(lambda: simulate_reference(m, ins), repeats)
        t_fast = _median_wall(lambda: simulate(m, ins), repeats)
        t_lanes = _median_wall(lambda: simulate_lanes(m, batch), repeats)

        t_replay = None
        if g.is_static_rate():
            trace = TimingTrace.from_sim(fast, length, (), 4)
            outs = execute(g, ins)
            t_replay = _median_wall(lambda: trace.replay(outs), repeats)

        rows.append({
            "kernel": kname,
            "length": length,
            "lanes": lanes,
            "cycles": ref.cycles,
            "cycles_match": ref.cycles == fast.cycles,
            "static_rate": g.is_static_rate(),
            "wall_us_reference": t_ref * 1e6,
            "wall_us_fast": t_fast * 1e6,
            "speedup": t_ref / t_fast,
            "wall_us_lane_batch": t_lanes * 1e6,
            "wall_us_lane_per_req": t_lanes / lanes * 1e6,
            "wall_us_trace_replay": (t_replay * 1e6 if t_replay is not None
                                     else None),
        })
    return rows


def write_json(rows: List[dict], path: str = "BENCH_sim.json") -> str:
    with open(path, "w") as f:
        json.dump({"bench": "sim", "rows": rows}, f, indent=2)
        f.write("\n")
    return path


def main(length: int = 64, lanes: int = 16, repeats: int = 5,
         json_path: str = "BENCH_sim.json") -> List[dict]:
    rows = run(length=length, lanes=lanes, repeats=repeats)
    print(f"  {'kernel':10s} {'cycles':>7s} {'ref_ms':>8s} {'fast_ms':>8s} "
          f"{'speedup':>8s} {'replay_us':>10s}")
    for r in rows:
        rep = f"{r['wall_us_trace_replay']:10.1f}" \
            if r["wall_us_trace_replay"] is not None else "         -"
        print(f"  {r['kernel']:10s} {r['cycles']:7d} "
              f"{r['wall_us_reference'] / 1e3:8.2f} "
              f"{r['wall_us_fast'] / 1e3:8.2f} {r['speedup']:8.1f} {rep}")
        assert r["cycles_match"], r
    if json_path:
        print(f"  wrote {write_json(rows, json_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", default="BENCH_sim.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    main(length=args.length, lanes=args.lanes, repeats=args.repeats,
         json_path=args.json)
