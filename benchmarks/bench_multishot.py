"""Table II reproduction: multi-shot kernels (mm, conv2d, PolyBench SMALL).

Each benchmark runs functionally (validated against NumPy) while the
multi-shot runner accounts config-fetch / re-arm / execution cycles from
cycle-accurate per-shot simulations. Power uses the fitted duty-cycle model
(the fabric is clock-gated while the CPU re-arms — why mm consumes 3.99 mW
vs fft's 16.84 mW in the paper).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import multishot as MS
from repro.core import paper_data as PD
from repro.core.energy import (CPU_MW, SOC_CPU_MW, PowerModel,
                               features_from_sim)
from repro.core.soc import cpu_cycles, profiles


def _mm(n, rng) -> Tuple[MS.Tally, bool, Dict]:
    A = rng.integers(-64, 64, (n, n)).astype(np.int32)
    B = rng.integers(-64, 64, (n, n)).astype(np.int32)
    C = np.zeros((n, n), np.int32)
    r = MS.ShotRunner(True)
    t = MS.run_mm(A, B, C, runner=r)
    ok = np.array_equal(C, (A.astype(np.int64) @ B.astype(np.int64)
                            ).astype(np.int32))
    return t, ok, _agg_features(r)


def _conv2d(rng):
    img = rng.integers(0, 256, (64, 64)).astype(np.int32)
    kern = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.int32)
    out = np.zeros((62, 62), np.int32)
    r = MS.ShotRunner(True)
    t = MS.run_conv2d(img, kern, out, runner=r)
    ref = sum(kern[i, j] * img[i:i + 62, j:j + 62].astype(np.int64)
              for i in range(3) for j in range(3))
    return t, np.array_equal(out, ref.astype(np.int32)), _agg_features(r)


def _gemm(rng):
    NI, NJ, NK = 60, 70, 80
    A = rng.integers(-32, 32, (NI, NK)).astype(np.int32)
    B = rng.integers(-32, 32, (NK, NJ)).astype(np.int32)
    C = rng.integers(-32, 32, (NI, NJ)).astype(np.int32)
    C0 = C.copy()
    r = MS.ShotRunner(True)
    t = MS.run_gemm(3, A, B, 2, C, runner=r)
    ref = (3 * (A.astype(np.int64) @ B.astype(np.int64))
           + 2 * C0.astype(np.int64)).astype(np.int32)
    return t, np.array_equal(C, ref), _agg_features(r)


def _gemver(rng):
    N = 120
    A = rng.integers(-8, 8, (N, N)).astype(np.int32)
    A0 = A.copy()
    u1, v1, u2, v2, y, z = (rng.integers(-4, 4, N).astype(np.int32)
                            for _ in range(6))
    w = np.zeros(N, np.int32)
    x = np.zeros(N, np.int32)
    r = MS.ShotRunner(True)
    t = MS.run_gemver(2, 3, A, u1, v1, u2, v2, w, x, y, z, runner=r)
    Ap = A0.astype(np.int64) + np.outer(u1, v1) + np.outer(u2, v2)
    xr = 3 * (Ap.T @ y.astype(np.int64)) + z
    wr = 2 * (Ap @ xr)
    ok = (np.array_equal(A, Ap.astype(np.int32))
          and np.array_equal(x, xr.astype(np.int32))
          and np.array_equal(w, wr.astype(np.int32)))
    return t, ok, _agg_features(r)


def _gesummv(rng):
    N = 90
    A = rng.integers(-16, 16, (N, N)).astype(np.int32)
    B = rng.integers(-16, 16, (N, N)).astype(np.int32)
    x = rng.integers(-16, 16, N).astype(np.int32)
    y = np.zeros(N, np.int32)
    r = MS.ShotRunner(True)
    t = MS.run_gesummv(3, 2, A, B, x, y, runner=r)
    ref = (3 * (A.astype(np.int64) @ x) + 2 * (B.astype(np.int64) @ x)
           ).astype(np.int32)
    return t, np.array_equal(y, ref), _agg_features(r)


def _2mm(rng):
    NI, NJ, NK, NL = 40, 50, 70, 80
    A = rng.integers(-8, 8, (NI, NK)).astype(np.int32)
    B = rng.integers(-8, 8, (NK, NJ)).astype(np.int32)
    C = rng.integers(-8, 8, (NJ, NL)).astype(np.int32)
    D = rng.integers(-8, 8, (NI, NL)).astype(np.int32)
    D0 = D.copy()
    r = MS.ShotRunner(True)
    t = MS.run_2mm(2, 3, A, B, C, D, runner=r)
    ref = (2 * (A.astype(np.int64) @ B.astype(np.int64) @ C.astype(np.int64))
           + 3 * D0.astype(np.int64)).astype(np.int32)
    return t, np.array_equal(D, ref), _agg_features(r)


def _3mm(rng):
    NI, NJ, NK, NL, NM = 40, 50, 60, 70, 80
    A = rng.integers(-8, 8, (NI, NK)).astype(np.int32)
    B = rng.integers(-8, 8, (NK, NJ)).astype(np.int32)
    C = rng.integers(-8, 8, (NJ, NM)).astype(np.int32)
    D = rng.integers(-8, 8, (NM, NL)).astype(np.int32)
    r = MS.ShotRunner(True)
    t, G = MS.run_3mm(A, B, C, D, runner=r)
    ref = (A.astype(np.int64) @ B.astype(np.int64)
           @ (C.astype(np.int64) @ D.astype(np.int64))).astype(np.int32)
    return t, np.array_equal(G, ref), _agg_features(r)


def _agg_features(runner: MS.ShotRunner):
    """Feature source: the dominant (largest) representative shot sim."""
    sims = runner.rep_sims()
    if not sims:
        return None
    sig, sim = max(sims.items(), key=lambda kv: kv[1].cycles)
    return runner.mappings()[sig[0]], sim


_BENCHES = {
    "mm16": lambda rng: _mm(16, rng),
    "mm64": lambda rng: _mm(64, rng),
    "conv2d": _conv2d,
    "gemm": _gemm,
    "gemver": _gemver,
    "gesummv": _gesummv,
    "2mm": _2mm,
    "3mm": _3mm,
}

_PAPER_OPS = {k: v[1] for k, v in PD.TABLE_II.items()}


def collect(rng=None):
    """Run all benches; return (name, tally, ok, features) tuples."""
    rng = rng or np.random.default_rng(1)
    out = []
    for name, fn in _BENCHES.items():
        tally, ok, ms = fn(rng)
        t2 = PD.TABLE_II[name]
        feats = None
        if ms is not None:
            m, sim = ms
            feats = features_from_sim(m, sim, duty=tally.duty,
                                      cgra_mw_paper=t2[4],
                                      soc_mw_paper=t2[10])
        out.append((name, tally, ok, feats))
    return out


def run(power_model: Optional[PowerModel] = None) -> List[dict]:
    collected = collect()
    if power_model is None:
        power_model = PowerModel()
        power_model.fit([f for _, _, _, f in collected if f is not None])
    rows = []
    for name, tally, ok, feats in collected:
        t2 = PD.TABLE_II[name]
        n_ops = _PAPER_OPS[name]
        perf_mops = n_ops / (tally.total / PD.CLOCK_MHZ)
        if feats is not None:
            cgra_mw = power_model.cgra_mw(feats)
            soc_mw = power_model.soc_mw(feats)
        else:
            cgra_mw, soc_mw = t2[4], t2[10]
        prof = profiles()[name]
        cpu_cyc = cpu_cycles(prof)
        rows.append({
            "kernel": name, "ok": ok,
            "total_cycles": tally.total, "total_cycles_paper": t2[0],
            "cycles_err": (tally.total - t2[0]) / t2[0],
            "config": tally.config, "rearm": tally.rearm,
            "exec": tally.exec, "shots": tally.shots, "duty": tally.duty,
            "n_ops": n_ops, "ops_measured": tally.ops,
            "perf_mops": perf_mops, "perf_mops_paper": t2[3],
            "cgra_mw": cgra_mw, "cgra_mw_paper": t2[4],
            "eff_mops_mw": perf_mops / cgra_mw, "eff_paper": t2[5],
            "cpu_cycles_model": round(cpu_cyc), "cpu_cycles_paper": t2[6],
            "speedup": cpu_cyc / tally.total, "speedup_paper": t2[8],
            "esave_soc": (cpu_cyc * SOC_CPU_MW) / (tally.total * soc_mw),
            "esave_soc_paper": t2[12],
        })
    return rows


def main() -> None:
    rows = run()
    print(f"{'kernel':8s} {'ok':>3s} {'cycles':>8s} {'paper':>8s} {'err%':>6s} "
          f"{'MOPs':>8s} {'pMOPs':>8s} {'speedup':>8s} {'pspd':>6s} {'duty':>5s}")
    for r in rows:
        print(f"{r['kernel']:8s} {str(r['ok']):>3s} {r['total_cycles']:8d} "
              f"{r['total_cycles_paper']:8d} {100*r['cycles_err']:+6.1f} "
              f"{r['perf_mops']:8.1f} {r['perf_mops_paper']:8.1f} "
              f"{r['speedup']:8.2f} {r['speedup_paper']:6.2f} "
              f"{r['duty']:5.2f}")


if __name__ == "__main__":
    main()
