"""Mapper benchmark: greedy vs annealed place & route per paper kernel.

For every paper kernel the same DFG is mapped twice — once by the greedy
first-feasible mapper and once by the simulated-annealing optimizer
(``core/opt_mapper.py``, seeded and deterministic) — and both mappings
execute the identical input stream on the cycle-accurate elastic
simulator. Per (kernel, mapper) row:

  * ``exec_cycles`` / ``steady_ii`` — measured on the bench stream;
  * ``config_cycles`` / ``config_words`` — the reconfiguration footprint
    (Sec. V-B: five 32-bit words per active PE), the cost every
    multi-shot re-arm pays;
  * ``total_cycles`` — config + exec, the objective the annealer
    minimizes;
  * ``pnr_wall_us`` — what the mapping cost to compute.

``main()`` enforces the optimizer's contract on every kernel — annealed
values bit-exact with greedy, annealed ``total_cycles`` never worse — and
requires strict improvement on at least ``--min-improved`` kernels
(CI gates on the default 3). Output: ``BENCH_mapper.json``.

    PYTHONPATH=src python -m benchmarks.bench_mapper --length 64
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.elastic_sim import simulate
from repro.core.fabric import Fabric
from repro.core.isa import config_stream
from repro.core.mapper import Mapping, generate_configs, map_dfg
from repro.core.opt_mapper import anneal_map

_KERNELS: Dict[str, Callable[[int], DFG]] = {
    "fft": lambda n: K.fft_butterfly(),
    "relu": lambda n: K.relu(),
    "dither": lambda n: K.dither(),
    "find2min": lambda n: K.find2min(),
    "find2min_brmg": lambda n: K.find2min_brmg(),
    "mac1": lambda n: K.mac1(n),
    "mac2x": lambda n: K.mac2x(n),
    "vadd": lambda n: K.vadd(),
    "axpby": lambda n: K.axpby(3, 5),
    "conv2d_row": lambda n: K.conv2d_row(1, 2, 1),
    "outer_row2": lambda n: K.outer_row2(1, 2, 3, 4),
    "div_loop": lambda n: K.div_loop(7),
}


def _inputs(g: DFG, length: int, rng) -> Dict[str, np.ndarray]:
    lo, hi = (0, 100) if g.has_recirculation() else (-64, 64)
    return {name: rng.integers(lo, hi, length).astype(np.int32)
            for name in g.inputs}


def _measure(kname: str, m: Mapping, mapper: str, ins, pnr_wall: float,
             length: int) -> dict:
    sim = simulate(m, dict(ins))
    ii = sim.steady_ii()
    cfg = m.config_cycles()
    return {
        "kernel": kname,
        "mapper": mapper,
        "length": length,
        "steady_ii": None if ii == float("inf") else ii,
        "exec_cycles": sim.cycles,
        "config_cycles": cfg,
        "total_cycles": cfg + sim.cycles,
        "active_pes": m.n_active_pes(),
        "config_words": len(config_stream(generate_configs(m))),
        "pnr_wall_us": pnr_wall * 1e6,
        "outputs": {k: np.asarray(v).tolist() for k, v in
                    sim.outputs.items()},
    }


def run(length: int = 64, seed: int = 0, moves: int = None,
        fabric: Fabric = None) -> List[dict]:
    fabric = fabric or Fabric()
    rng = np.random.default_rng(seed)
    rows: List[dict] = []
    for kname, factory in _KERNELS.items():
        g = factory(length)
        ins = _inputs(g, length, rng)

        t0 = time.perf_counter()
        greedy = map_dfg(g, fabric, seed=seed, optimize="greedy")
        wall_greedy = time.perf_counter() - t0

        # the bench stream rides along as a validation probe: the
        # never-worse guarantee then holds on exactly what we measure
        t0 = time.perf_counter()
        annealed = anneal_map(g, fabric, seed=seed, baseline=greedy,
                              moves=moves, extra_probes=[dict(ins)])
        wall_anneal = time.perf_counter() - t0

        rows.append(_measure(kname, greedy, "greedy", ins, wall_greedy,
                             length))
        rows.append(_measure(kname, annealed, "anneal", ins, wall_anneal,
                             length))
    return rows


def check(rows: List[dict], min_improved: int = 3) -> List[str]:
    """Enforce the optimizer contract; returns the improved kernel names."""
    greedy = {r["kernel"]: r for r in rows if r["mapper"] == "greedy"}
    improved: List[str] = []
    for r in rows:
        if r["mapper"] != "anneal":
            continue
        gr = greedy[r["kernel"]]
        assert r["outputs"] == gr["outputs"], (
            f"{r['kernel']}: annealed outputs diverged from greedy")
        assert r["total_cycles"] <= gr["total_cycles"], (
            f"{r['kernel']}: anneal total {r['total_cycles']} worse than "
            f"greedy {gr['total_cycles']}")
        assert r["exec_cycles"] <= gr["exec_cycles"], (
            f"{r['kernel']}: anneal exec {r['exec_cycles']} worse than "
            f"greedy {gr['exec_cycles']}")
        if r["total_cycles"] < gr["total_cycles"]:
            improved.append(r["kernel"])
    assert len(improved) >= min_improved, (
        f"annealer improved only {improved} (need >= {min_improved})")
    return improved


def write_json(rows: List[dict], path: str = "BENCH_mapper.json") -> str:
    slim = [{k: v for k, v in r.items() if k != "outputs"} for r in rows]
    with open(path, "w") as f:
        json.dump({"bench": "mapper", "rows": slim}, f, indent=2)
        f.write("\n")
    return path


def main(length: int = 64, seed: int = 0, moves: int = None,
         json_path: str = "BENCH_mapper.json",
         min_improved: int = 3) -> List[dict]:
    rows = run(length=length, seed=seed, moves=moves)
    greedy = {r["kernel"]: r for r in rows if r["mapper"] == "greedy"}
    print(f"  greedy vs anneal @ length={length} seed={seed} "
          f"(total = config + exec cycles)")
    print(f"  {'kernel':14s} {'total(g)':>9s} {'total(a)':>9s} "
          f"{'cfg(g)':>7s} {'cfg(a)':>7s} {'PEs':>7s} {'pnr_ms(a)':>10s}")
    for r in rows:
        if r["mapper"] != "anneal":
            continue
        gr = greedy[r["kernel"]]
        mark = "  <" if r["total_cycles"] < gr["total_cycles"] else ""
        print(f"  {r['kernel']:14s} {gr['total_cycles']:9d} "
              f"{r['total_cycles']:9d} {gr['config_cycles']:7d} "
              f"{r['config_cycles']:7d} "
              f"{gr['active_pes']:3d}>{r['active_pes']:<3d} "
              f"{r['pnr_wall_us'] / 1e3:10.1f}{mark}")
    improved = check(rows, min_improved=min_improved)
    print(f"  improved: {', '.join(improved)} "
          f"({len(improved)}/{len(greedy)} kernels; values bit-exact, "
          f"never worse)")
    if json_path:
        print(f"  wrote {write_json(rows, json_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--moves", type=int, default=None,
                    help="anneal move budget (default STRELA_ANNEAL_MOVES "
                         "or 240)")
    ap.add_argument("--min-improved", type=int, default=3,
                    help="fail unless >= this many kernels improved")
    ap.add_argument("--json", default="BENCH_mapper.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    main(length=args.length, seed=args.seed, moves=args.moves,
         json_path=args.json, min_improved=args.min_improved)
