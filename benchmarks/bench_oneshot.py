"""Table I reproduction: one-shot kernels (fft, relu, dither, find2min).

For each kernel: map onto the 4x4 fabric (frozen 'manual' mapping), run the
cycle-level elastic simulation on 1024 input elements with the paper's
stream layout, and derive performance/power/energy metrics from the fitted
models. Paper values are printed side-by-side with relative errors.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import paper_data as PD
from repro.core.dfg import DFG
from repro.core.elastic_sim import SimResult, simulate
from repro.core.energy import (CPU_MW, SOC_CPU_MW, PowerModel,
                               features_from_sim)
from repro.core.paper_mappings import paper_mapping
from repro.core.soc import ONESHOT_PREAMBLE, cpu_cycles, profiles

TOTAL_INPUTS = 1024


def _inputs_for(name: str, rng) -> Dict[str, np.ndarray]:
    if name == "fft":
        return {k: rng.integers(-4096, 4096, 256).astype(np.int32)
                for k in ("ar", "ai", "br", "bi")}
    if name == "relu_x3":
        x = rng.integers(-128, 128, 1023).astype(np.int32)
        return {"x@0": x[0::3], "x@1": x[1::3], "x@2": x[2::3]}
    if name == "dither_c2":
        x = rng.integers(0, 256, 1024).astype(np.int32)
        return {"x@0": x[0::2], "x@1": x[1::2]}
    if name in ("find2min", "find2min_brmg"):
        return {"x": rng.integers(0, 100000, 1024).astype(np.int32)}
    raise KeyError(name)


# mapping-name -> paper Table I row.  find2min appears twice: our mux-based
# mapping (II=2) and the paper-faithful Branch/Merge formulation (II=3,
# Fig. 5 'BR/MG'); both beat the paper's 7175 cycles — see EXPERIMENTS.md
# §Paper-validation for the deviation analysis.
_PAPER_ROW = {"fft": "fft", "relu_x3": "relu", "dither_c2": "dither",
              "find2min": "find2min", "find2min_brmg": "find2min"}
# paper op counts per element (Sec. VII-B conventions)
_OPS = {"fft": 2560, "relu_x3": 2048, "dither_c2": 5120, "find2min": 9216,
        "find2min_brmg": 9216}


def run(power_model: PowerModel = None) -> List[dict]:
    rng = np.random.default_rng(0)
    rows = []
    sims: Dict[str, tuple] = {}
    for name, paper_key in _PAPER_ROW.items():
        m = paper_mapping(name)
        sim = simulate(m, _inputs_for(name, rng))
        sims[name] = (m, sim)

    # fit the power model across one-shot + multi-shot samples happens in
    # run.py; here accept a pre-fitted model (or fit on our 4 samples only)
    pm = power_model
    if pm is None:
        pm = PowerModel()
        samples = []
        for name, paper_key in _PAPER_ROW.items():
            m, sim = sims[name]
            t1 = PD.TABLE_I[paper_key]
            samples.append(features_from_sim(m, sim, 1.0, t1[5], t1[11]))
        pm.fit(samples)

    for name, paper_key in _PAPER_ROW.items():
        m, sim = sims[name]
        t1 = PD.TABLE_I[paper_key]
        n_ops = _OPS[name]
        ops_measured = sum(sim.fu_firings.values())
        perf_mops = n_ops / (sim.cycles / PD.CLOCK_MHZ)  # ops per us = MOPs
        feats = features_from_sim(m, sim, 1.0, t1[5], t1[11])
        cgra_mw = pm.cgra_mw(feats)
        soc_mw = pm.soc_mw(feats)
        eff = perf_mops / cgra_mw
        prof = profiles()[paper_key]
        cpu_cyc = cpu_cycles(prof)
        speedup = cpu_cyc / (sim.cycles + m.config_cycles() + ONESHOT_PREAMBLE)
        esave_cpu = (cpu_cyc * CPU_MW) / (sim.cycles * cgra_mw)
        soc_cpu_mw = SOC_CPU_MW
        esave_soc = (cpu_cyc * soc_cpu_mw) / (sim.cycles * soc_mw)
        rows.append({
            "kernel": name, "paper_kernel": paper_key,
            "config_cycles": m.config_cycles(),
            "config_cycles_paper": t1[0],
            "exec_cycles": sim.cycles, "exec_cycles_paper": t1[1],
            "cycles_err": (sim.cycles - t1[1]) / t1[1],
            "n_ops": n_ops, "ops_measured": ops_measured,
            "outputs_per_cycle": sim.outputs_per_cycle(),
            "outputs_per_cycle_paper": t1[3],
            "perf_mops": perf_mops, "perf_mops_paper": t1[4],
            "cgra_mw": cgra_mw, "cgra_mw_paper": t1[5],
            "eff_mops_mw": eff, "eff_paper": t1[6],
            "cpu_cycles_model": round(cpu_cyc),
            "cpu_cycles_paper": t1[7],
            "speedup": speedup, "speedup_paper": t1[9],
            "esave_soc": esave_soc, "esave_soc_paper": t1[13],
            "steady_ii": sim.steady_ii(),
        })
    return rows


def main() -> None:
    rows = run()
    hdr = (f"{'kernel':13s} {'cycles':>7s} {'paper':>7s} {'err%':>6s} "
           f"{'out/cyc':>8s} {'MOPs':>8s} {'pMOPs':>8s} {'mW':>6s} "
           f"{'pmW':>6s} {'speedup':>8s} {'pspd':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r['kernel']:13s} {r['exec_cycles']:7d} "
              f"{r['exec_cycles_paper']:7d} {100*r['cycles_err']:+6.1f} "
              f"{r['outputs_per_cycle']:8.3f} {r['perf_mops']:8.1f} "
              f"{r['perf_mops_paper']:8.1f} {r['cgra_mw']:6.2f} "
              f"{r['cgra_mw_paper']:6.2f} {r['speedup']:8.2f} "
              f"{r['speedup_paper']:6.2f}")


if __name__ == "__main__":
    main()
