"""Pallas kernel micro-benchmarks (TPU adaptation layer).

CPU wall-times of the jitted XLA reference vs the interpret-mode Pallas
kernel are *correctness* artifacts (interpret mode is a Python interpreter,
not a performance path); the TPU-side expectation is the analytic roofline
estimate printed per kernel (bytes-bound streaming for fabric_stream,
MXU-bound for stream_matmul).

``--frontend traced`` swaps the hand-built ``kernels_lib`` DFGs for graphs
traced from plain Python by ``repro.frontend`` — same fabric semantics,
zero hand assembly.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels_lib as K
from repro.kernels import ops, ref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def _fft_dfg(frontend: str, n: int):
    if frontend == "hand":
        return K.fft_butterfly()
    from repro.frontend import trace
    wr, wi = 23170, -23170

    def fft(ar, ai, br, bi):
        tr = br * wr - bi * wi
        ti = br * wi + bi * wr
        return ar + tr, ai + ti, ar - tr, ai - ti

    return trace(fft, n, name="fft")


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def run(frontend: str = "hand") -> List[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # fabric_stream on the fft butterfly (one-shot engine)
    n = 1 << 16
    g = _fft_dfg(frontend, n)
    ins = {k: jnp.asarray(rng.integers(-4096, 4096, n).astype(np.int32))
           for k in g.inputs}
    ref_fn = jax.jit(lambda d: ref.eval_dfg_elementwise(g, d))
    us_ref = _time(ref_fn, ins)
    stream_bytes = 8 * n * 4                       # 4 in + 4 out streams
    rows.append({"kernel": f"fabric_stream(fft/{frontend})", "n": n,
                 "us_xla_cpu": us_ref,
                 "tpu_roofline_us": stream_bytes / HBM_BW * 1e6,
                 "note": "bandwidth-bound streaming; one HBM round-trip"})

    # stream_matmul (multi-shot engine)
    m, k_, n2 = 512, 512, 512
    a = jnp.asarray(rng.standard_normal((m, k_)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k_, n2)), jnp.float32)
    us_ref = _time(jax.jit(ref.matmul), a, b)
    flops = 2 * m * k_ * n2
    rows.append({"kernel": "stream_matmul", "n": m,
                 "us_xla_cpu": us_ref,
                 "tpu_roofline_us": flops / PEAK_FLOPS * 1e6,
                 "note": "MXU-bound (bf16 would halve bytes)"})

    # stream_conv2d
    img = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    kern = jnp.asarray(rng.standard_normal((3, 3)), jnp.float32)
    us_ref = _time(jax.jit(ref.conv2d_3x3), img, kern)
    rows.append({"kernel": "stream_conv2d", "n": 256,
                 "us_xla_cpu": us_ref,
                 "tpu_roofline_us": (2 * 256 * 256 * 4) / HBM_BW * 1e6,
                 "note": "3 taps fused: single image round-trip"})

    # flash attention
    h, s, d = 8, 1024, 64
    q = jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32)
    us_ref = _time(jax.jit(lambda q: ref.flash_attention(q, q, q)), q)
    flops = 4 * h * s * s * d
    rows.append({"kernel": "flash_attention", "n": s,
                 "us_xla_cpu": us_ref,
                 "tpu_roofline_us": flops / PEAK_FLOPS * 1e6,
                 "note": "compute-bound when fused (no SxS HBM traffic)"})
    rows.extend(run_loops())
    return rows


def run_loops(length: int = 96) -> List[dict]:
    """Traced irregular-loop kernels on the cycle-accurate fabric sim:
    data-dependent trip counts (while) and loop-carried recurrences (scan),
    with the XLA reference wall-time alongside for correctness context."""
    from repro.core.elastic_sim import simulate
    from repro.core.mapper import map_dfg
    from repro.frontend import trace

    rng = np.random.default_rng(1)
    rows: List[dict] = []
    for name, (factory, n_in) in K.TRACED_LOOPS.items():
        fn = factory()
        g = trace(fn, length, name=name)
        ins = {k: rng.integers(0, 100, length).astype(np.int32)
               for k in g.inputs}
        us_ref = _time(jax.jit(jax.vmap(fn) if g.has_recirculation() else fn),
                       *[jnp.asarray(v) for v in ins.values()])
        sim = simulate(map_dfg(g, restarts=400), ins)
        rows.append({
            "kernel": f"loop({name})", "n": length,
            "us_xla_cpu": us_ref,
            # measured fabric time, NOT a TPU roofline bound — loops run on
            # the cycle-accurate simulator (cycles @ the paper's 250 MHz)
            "fabric_sim_us": sim.cycles / 250.0,
            "note": f"fabric sim: {sim.cycles} cyc, II={sim.steady_ii():.1f}, "
                    f"{g.n_pes_used()} PEs, "
                    f"{'token-exhaustion drain' if g.has_recirculation() else 'loop-carried scan'}"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--frontend", choices=("hand", "traced"), default="hand",
                    help="DFG source: hand-built kernels_lib or the traced "
                         "compiler frontend")
    args = ap.parse_args()
    for r in run(frontend=args.frontend):
        est = (f"tpu_roofline={r['tpu_roofline_us']:8.2f}us"
               if "tpu_roofline_us" in r
               else f"fabric_sim={r['fabric_sim_us']:8.2f}us")
        print(f"{r['kernel']:28s} n={r['n']:6d} "
              f"xla_cpu={r['us_xla_cpu']:9.1f}us {est}  {r['note']}")


if __name__ == "__main__":
    main()
