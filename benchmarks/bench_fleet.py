"""Fleet benchmark: multi-fabric scale-out throughput, DSE, fault-drain.

Drives :class:`repro.fleet.FleetEngine` — N independent fabric workers
behind the class-affinity router — through four measured sections, all in
deterministic virtual time (modeled fabric cycles, machine-independent):

  * **scaling** — the same over-driven Poisson mix offered to 1, 2 and 4
    homogeneous 4x4 fabrics. The acceptance claim of ISSUE 9 is asserted
    here: at the top offered load the 4-fabric fleet sustains **>= 3x the
    single-fabric steady-state throughput**. Steady-state throughput
    (served / first-arrival-to-last-completion window) is the honest
    figure; the wall figure also counts the drain tail.
  * **oracle** — every request the 4-fabric fleet served is re-executed
    through one plain ``Engine.run`` on a single 4x4 and the output
    digests must match bit-exactly: sharding must never change values.
  * **dse + hetero** — the geometry sweep table, and the pinned
    heterogeneous-vs-homogeneous comparison: a DSE-provisioned fleet
    (3x 2x2 + 1x 4x4 for the short-kernel-heavy mix) must beat 4
    homogeneous 4x4 fabrics on the 6-class mix p99 at the pinned
    operating point. A small seed sweep is reported alongside so the
    margin's seed-sensitivity is visible in the JSON rather than hidden.
  * **fault-drain** — one fabric is killed mid-soak; zero admitted
    requests may be lost, none duplicated, and a second run must replay
    the post-failure schedule bit-identically (trace digests equal).
  * **model mix** (ISSUE 10) — the transformer/SSM/MoE workload classes
    of ``repro.workloads`` served across a 2-fabric fleet: every served
    response is re-verified bit-exactly against its ``jnp`` oracle and a
    cold-cache second run must replay digest-identically — the fleet
    half of the workload conformance gate.

CLI::

    PYTHONPATH=src python -m benchmarks.bench_fleet
"""
from __future__ import annotations

import argparse
import hashlib
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import Fabric
from repro.engine import ArtifactCache, Engine
from repro.fleet import FleetConfig, fleet_soak, fleet_workload, homogeneous
from repro.fleet import dse
from repro.serve.load import serve_classes

# scaling section: top offered load is 8x one fabric's calibrated
# capacity — far past what a single 4x4 can admit, comfortably inside
# what four can, so the speedup measures real parallel service
SCALING_SEED = 3
SCALING_REQUESTS = 600
SCALING_LOAD = 8.0
SCALING_FLEETS: Tuple[int, ...] = (1, 2, 4)

# hetero-vs-homo pinned operating point (see DESIGN.md §15): a
# short-kernel-heavy mix with div_loop present but rare, driven hard
# enough that batches close on size — the p99 becomes service-bound,
# which is exactly where the DSE'd small fabrics' cheaper config path
# shows up. Everything is a pure function of (seed, FleetConfig), so
# the pinned assertion is replay-stable.
HET_WEIGHTS: Tuple[Tuple[str, float], ...] = (
    ("axpby_ms", 1.0), ("div_loop", 0.1), ("fft", 2.0),
    ("mac1", 2.0), ("relu", 4.0), ("vadd", 4.0))
HET_RATE_PER_US = 1.4
HET_MAX_WAIT_US = 50.0
HET_REQUESTS = 400
HET_PINNED_SEED = 5
HET_SWEEP_SEEDS: Tuple[int, ...] = (3, 5, 7, 9, 13)

# fault-drain section: kill f1 mid-soak
DRAIN_SEED = 2
DRAIN_REQUESTS = 300
DRAIN_RATE_PER_US = 0.6
DRAIN_FAIL_AT_US = 200.0


def calibrate(cache: ArtifactCache, length: int = 64) -> float:
    """Mean modeled service time (us/request) of the full class mix on
    one 4x4 — the unit the scaling loads are expressed in."""
    eng = Engine(Fabric(), backend="sim", cache=cache)
    classes = serve_classes(eng, length)
    rng = np.random.default_rng(0)
    before = eng.tally.total
    from repro.serve.load import request_inputs
    for art in classes.values():
        eng.run(art, request_inputs(art, length, rng))
    cfg = FleetConfig(fabrics=homogeneous(1).fabrics)
    return (eng.tally.total - before) / len(classes) * cfg.us_per_cycle


def oracle_results_digest(fleet, seed: int, config: FleetConfig,
                          cache: ArtifactCache) -> str:
    """Re-execute the fleet's served requests through one plain
    ``Engine.run`` on a single 4x4 and fold the outputs exactly the way
    :meth:`FleetEngine.results_digest` does. Bit-exact values => equal
    digests, regardless of which fabric served what."""
    ref = Engine(Fabric(), backend="sim", cache=cache)
    classes = {l: a for l, a in serve_classes(ref, config.length).items()
               if l in config.classes}
    arrivals = fleet_workload(seed, config, cache=cache)
    outs_by_rid = {}
    for rid, (_, label, inputs) in enumerate(arrivals):
        outs_by_rid[rid] = (label, ref.run(classes[label], inputs))
    h = hashlib.sha1()
    for tk in fleet.served_tickets():
        label, outs = outs_by_rid[tk.rid]
        h.update(f"{tk.rid}|{label}".encode())
        for name in sorted(outs):
            h.update(name.encode())
            h.update(np.ascontiguousarray(
                np.asarray(outs[name], dtype=np.int64)).tobytes())
    return h.hexdigest()


def _scaling_row(n: int, rate: float, cache: ArtifactCache) -> Tuple:
    cfg = homogeneous(n, n_requests=SCALING_REQUESTS, rate_per_us=rate)
    fleet, rep = fleet_soak(SCALING_SEED, cfg, cache=cache)
    row = {
        "fabrics": n,
        "seed": SCALING_SEED,
        "requests": SCALING_REQUESTS,
        "offered_rps": rate * 1e6,
        "throughput_rps": rep["throughput_rps"],
        "steady_throughput_rps": rep["steady_throughput_rps"],
        "steady_window_us": rep["steady_window_us"],
        "served": rep["served"],
        "rejected": rep["rejected"],
        "failed": rep["failed"],
        "steals": rep["steals"],
        "p50_us": rep["latency"]["p50_us"],
        "p99_us": rep["latency"]["p99_us"],
        "trace_digest": rep["trace_digest"],
        "results_digest": fleet.results_digest(),
    }
    return fleet, cfg, row


def run_scaling(cache: ArtifactCache, mean_us: float) -> Tuple[List[dict],
                                                              dict]:
    rate = SCALING_LOAD / mean_us
    rows: List[dict] = []
    fleet4 = cfg4 = None
    for n in SCALING_FLEETS:
        fleet, cfg, row = _scaling_row(n, rate, cache)
        rows.append(row)
        if n == max(SCALING_FLEETS):
            fleet4, cfg4 = fleet, cfg
    base = rows[0]["steady_throughput_rps"]
    top = rows[-1]["steady_throughput_rps"]
    speedup = top / base
    assert speedup >= 3.0, (
        f"fleet scaling regressed: {max(SCALING_FLEETS)} fabrics sustain "
        f"{top:.0f} rps vs single-fabric {base:.0f} rps — only "
        f"{speedup:.2f}x (need >= 3x)")
    # the oracle: values must not depend on sharding
    assert rows[-1]["rejected"] + rows[-1]["served"] + rows[-1]["failed"] \
        == SCALING_REQUESTS
    oracle = oracle_results_digest(fleet4, SCALING_SEED, cfg4, cache)
    assert oracle == rows[-1]["results_digest"], (
        f"fleet served values diverged from the single-engine oracle: "
        f"{rows[-1]['results_digest']} != {oracle}")
    return rows, {"speedup_at_top_load": speedup,
                  "oracle_digest": oracle,
                  "oracle_match": True}


def run_hetero(cache: ArtifactCache,
               ranked: Dict[str, List]) -> dict:
    weights = dict(HET_WEIGHTS)
    kw = dict(n_requests=HET_REQUESTS, max_wait_us=HET_MAX_WAIT_US,
              rate_per_us=HET_RATE_PER_US)
    het_cfg = dse.provision(ranked, 4, weights=weights, **kw)
    homo_cfg = homogeneous(4, weights=HET_WEIGHTS, **kw)
    rows = []
    pinned = None
    for seed in HET_SWEEP_SEEDS:
        _, rh = fleet_soak(seed, homo_cfg, cache=cache)
        _, re_ = fleet_soak(seed, het_cfg, cache=cache)
        row = {
            "seed": seed,
            "pinned": seed == HET_PINNED_SEED,
            "homo_p99_us": rh["latency"]["p99_us"],
            "het_p99_us": re_["latency"]["p99_us"],
            "homo_rejected": rh["rejected"],
            "het_rejected": re_["rejected"],
            "winner": "het" if re_["latency"]["p99_us"]
            < rh["latency"]["p99_us"] else "homo",
        }
        rows.append(row)
        if seed == HET_PINNED_SEED:
            pinned = row
    assert pinned is not None
    # the acceptance claim: at the pinned deterministic operating point
    # the DSE-provisioned heterogeneous fleet beats N homogeneous 4x4s
    # on the 6-class mix p99 — without buying the win with rejections
    assert pinned["het_p99_us"] < pinned["homo_p99_us"], (
        f"heterogeneous fleet lost the pinned p99 point: het "
        f"{pinned['het_p99_us']:.1f} us vs homo "
        f"{pinned['homo_p99_us']:.1f} us (seed {HET_PINNED_SEED})")
    assert pinned["het_rejected"] <= pinned["homo_rejected"], (
        "heterogeneous fleet shed load to win the p99 point")
    return {
        "weights": dict(HET_WEIGHTS),
        "rate_per_us": HET_RATE_PER_US,
        "max_wait_us": HET_MAX_WAIT_US,
        "requests": HET_REQUESTS,
        "pinned_seed": HET_PINNED_SEED,
        "het_geometries": [list(s.geometry) for s in het_cfg.fabrics],
        "pinned_margin_pct": round(
            (1 - pinned["het_p99_us"] / pinned["homo_p99_us"]) * 100, 2),
        "rows": rows,
        "het_wins": sum(r["winner"] == "het" for r in rows),
        "seeds": len(rows),
    }


def run_fault_drain(cache: ArtifactCache) -> dict:
    cfg = homogeneous(4, n_requests=DRAIN_REQUESTS,
                      rate_per_us=DRAIN_RATE_PER_US,
                      fail_at=(("f1", DRAIN_FAIL_AT_US),))
    fleet, rep = fleet_soak(DRAIN_SEED, cfg, cache=cache)
    # no loss: every offered request is accounted for exactly once
    total = rep["served"] + rep["rejected"] + rep["failed"]
    assert rep["offered"] == DRAIN_REQUESTS and total == rep["offered"], (
        f"fault-drain lost requests: offered={rep['offered']} "
        f"served+rejected+failed={total}")
    # no duplicates: served rids are unique
    rids = [tk.rid for tk in fleet.served_tickets()]
    assert len(rids) == len(set(rids)), "fault-drain duplicated requests"
    assert rep["dead"] == ["f1"] and rep["drained"] > 0
    assert not rep["per_fabric"]["f1"]["alive"]
    # deterministic replay of the post-failure schedule
    fleet2, rep2 = fleet_soak(DRAIN_SEED, cfg,
                              cache=ArtifactCache(memory_only=True))
    assert rep2["trace_digest"] == rep["trace_digest"], (
        "fault-drain replay diverged")
    assert fleet2.results_digest() == fleet.results_digest()
    return {
        "seed": DRAIN_SEED,
        "requests": DRAIN_REQUESTS,
        "rate_per_us": DRAIN_RATE_PER_US,
        "fail_at_us": DRAIN_FAIL_AT_US,
        "failed_fabric": "f1",
        "served": rep["served"],
        "rejected": rep["rejected"],
        "failed": rep["failed"],
        "drained": rep["drained"],
        "steals": rep["steals"],
        "p99_us": rep["latency"]["p99_us"],
        "trace_digest": rep["trace_digest"],
        "replay_match": True,
    }


# model-mix section (ISSUE 10): the pinned 2-fabric operating point of
# tests/test_workloads.py's fleet soak, promoted to a benchmark row set
MODEL_SEED = 11
MODEL_REQUESTS = 80
MODEL_RATE_PER_US = 0.25


def run_model_fleet(cache: ArtifactCache) -> dict:
    """The transformer/SSM/MoE workload mix (``repro.workloads``) across
    a 2-fabric fleet — the fleet half of the ISSUE 10 differential gate:
    every served response re-verified bit-exactly against its ``jnp``
    oracle, plus a cold-cache digest-identical replay."""
    from repro.serve.load import model_classes
    from repro.workloads import MODEL_CLASSES, MODEL_MIX, model_weights

    cfg = homogeneous(2, n_requests=MODEL_REQUESTS,
                      rate_per_us=MODEL_RATE_PER_US,
                      classes=MODEL_MIX,
                      weights=tuple(sorted(model_weights().items())))
    fleet, rep = fleet_soak(MODEL_SEED, cfg, cache=cache)
    total = rep["served"] + rep["rejected"] + rep["failed"]
    assert rep["offered"] == MODEL_REQUESTS and total == rep["offered"], (
        f"model fleet lost requests: offered={rep['offered']} "
        f"served+rejected+failed={total}")
    names = {a.name: l
             for l, a in model_classes(Engine(cache=cache),
                                       cfg.length).items()}
    checked = mismatches = 0
    for w in fleet.workers:
        for tk in w.serve.served:
            wc = MODEL_CLASSES[names[tk.artifact.name]]
            want = wc.oracle(**tk.inputs)
            ok = all(np.array_equal(
                np.ravel(np.asarray(tk.outputs[f"out{i}"])),
                np.ravel(np.asarray(wv))) for i, wv in enumerate(want))
            checked += 1
            mismatches += 0 if ok else 1
    assert mismatches == 0 and checked == rep["served"], (
        f"model fleet oracle divergence: {mismatches} mismatches over "
        f"{checked}/{rep['served']} served")
    fleet2, rep2 = fleet_soak(MODEL_SEED, cfg,
                              cache=ArtifactCache(memory_only=True))
    assert rep2["trace_digest"] == rep["trace_digest"], (
        "model fleet replay diverged")
    assert fleet2.results_digest() == fleet.results_digest()
    return {
        "seed": MODEL_SEED,
        "requests": MODEL_REQUESTS,
        "rate_per_us": MODEL_RATE_PER_US,
        "fabrics": 2,
        "classes": sorted(MODEL_MIX),
        "served": rep["served"],
        "rejected": rep["rejected"],
        "failed": rep["failed"],
        "steals": rep["steals"],
        "p99_us": rep["latency"]["p99_us"],
        "oracle_checked": checked,
        "oracle_mismatches": mismatches,
        "placements": rep["placements"],
        "trace_digest": rep["trace_digest"],
        "replay_match": True,
    }


def main(json_path: str = "BENCH_fleet.json") -> dict:
    cache = ArtifactCache(memory_only=True)
    mean_us = calibrate(cache)
    print(f"  calibrated mean 4x4 service: {mean_us:.2f} us/request "
          f"(latencies/throughput below are virtual-clock figures — "
          f"modeled cycles, machine-independent)")

    print(f"  scaling: seed={SCALING_SEED}, {SCALING_REQUESTS} requests "
          f"at {SCALING_LOAD:g}x single-fabric capacity")
    scaling_rows, scaling_meta = run_scaling(cache, mean_us)
    print(f"  {'fabrics':>7s} {'offer rps':>10s} {'steady rps':>11s} "
          f"{'srv':>4s} {'rej':>4s} {'steal':>5s} {'p99 us':>8s}")
    for r in scaling_rows:
        print(f"  {r['fabrics']:7d} {r['offered_rps']:10.0f} "
              f"{r['steady_throughput_rps']:11.0f} {r['served']:4d} "
              f"{r['rejected']:4d} {r['steals']:5d} {r['p99_us']:8.1f}")
    print(f"  speedup at top load: "
          f"{scaling_meta['speedup_at_top_load']:.2f}x (>= 3x required); "
          f"single-engine oracle digest match: ok")

    ranked = dse.sweep(cache=cache)
    dse_rows = dse.table(ranked)
    best = {l: next(c.geometry for c in ranked[l] if c.feasible)
            for l in sorted(ranked)}
    print(f"  dse sweep: {len(dse_rows)} (class, geometry) points; "
          f"best geometry per class: "
          f"{ {l: 'x'.join(map(str, g[:2])) for l, g in best.items()} }")

    het = run_hetero(cache, ranked)
    print(f"  hetero vs homo p99 (rate {het['rate_per_us']:g}/us, "
          f"max_wait {het['max_wait_us']:g} us, het fleet "
          f"{[ 'x'.join(map(str, g[:2])) for g in het['het_geometries']]}):")
    for r in het["rows"]:
        mark = " <- pinned" if r["pinned"] else ""
        print(f"    seed {r['seed']:2d}: homo {r['homo_p99_us']:6.1f} us | "
              f"het {r['het_p99_us']:6.1f} us -> {r['winner']}{mark}")
    print(f"  pinned point: het beats homo by "
          f"{het['pinned_margin_pct']:.1f}% "
          f"(wins {het['het_wins']}/{het['seeds']} sweep seeds)")

    drain = run_fault_drain(cache)
    print(f"  fault-drain: killed f1 at t={drain['fail_at_us']:g} us — "
          f"served={drain['served']} rejected={drain['rejected']} "
          f"failed={drain['failed']} drained={drain['drained']}, "
          f"zero loss, zero duplicates, replay digest match: ok")

    model = run_model_fleet(cache)
    print(f"  model mix: {len(model['classes'])} transformer/SSM/MoE "
          f"classes over {model['fabrics']} fabrics — "
          f"served={model['served']} rejected={model['rejected']} "
          f"steals={model['steals']} p99={model['p99_us']:.1f} us, "
          f"oracle {model['oracle_checked']}/{model['oracle_mismatches']} "
          f"(checked/mismatched), replay digest match: ok")

    out = {
        "bench": "fleet",
        "calibration": {"mean_service_us_4x4": mean_us},
        "scaling": scaling_rows,
        "scaling_meta": scaling_meta,
        "dse": dse_rows,
        "hetero": het,
        "fault_drain": drain,
        "model": model,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default="BENCH_fleet.json",
                    help="output path ('' disables)")
    args = ap.parse_args()
    main(json_path=args.json)
