"""Multi-shot offload demo: run a small linear-algebra app on the CGRA.

Computes w = alpha*(A @ B) @ x + beta*y entirely through multi-shot fabric
plans (mm shots + matvec shots + epilogues), reporting the offload cost
breakdown (config / re-arm / execution cycles), duty cycle, and the
fitted power/energy estimate vs the modeled CPU baseline.

The second half repeats the epilogue through the *traced* compiler
frontend: a plain Python function is traced with ``@offload``, lowered to
the same DFG IR, auto-mapped, and simulated — no hand-built kernel.

Run:  PYTHONPATH=src python examples/strela_offload.py
"""
import numpy as np

from repro.core import multishot as MS
from repro.core.energy import CPU_MW, PowerModel, features_from_sim
from repro.core.paper_data import CLOCK_MHZ
from repro.core.soc import CPU_WEIGHTS, KernelProfile, cpu_cycles

rng = np.random.default_rng(7)
N = 48
A = rng.integers(-8, 8, (N, N)).astype(np.int32)
B = rng.integers(-8, 8, (N, N)).astype(np.int32)
x = rng.integers(-8, 8, N).astype(np.int32)
y = rng.integers(-8, 8, N).astype(np.int32)
alpha, beta = 3, 2

runner = MS.ShotRunner(with_timing=True)

# phase 1: C = A @ B (mac3 shots, Fig. 7c)
C = np.zeros((N, N), np.int32)
MS.run_mm(A, B, C, runner=runner)

# phase 2: d = C @ x (mac3 shots sharing the x stream)
d = MS._matvec_mac3(runner, C, x, col_layout=False)

# phase 3: w = alpha*d + beta*y (one-shot axpby epilogue)
w = np.zeros(N, np.int32)
MS.run_axpby(alpha, d, beta, y, w, runner)

ref = (alpha * (A.astype(np.int64) @ B.astype(np.int64) @ x) +
       beta * y.astype(np.int64)).astype(np.int32)
assert np.array_equal(w, ref), "offloaded result mismatch!"

t = runner.tally
us = t.total / CLOCK_MHZ
print(f"[offload] w = a*(A@B)@x + b*y  (N={N})  -> exact match")
print(f"[offload] shots={t.shots}  cycles={t.total} ({us:.1f} us @250MHz)")
print(f"[offload]   config={t.config}  rearm={t.rearm}  exec={t.exec} "
      f"(duty {t.duty:.2f})")

# energy estimate vs modeled CPU baseline
sims = runner.rep_sims()
sig, sim = max(sims.items(), key=lambda kv: kv[1].cycles)
feats = features_from_sim(runner.mappings()[sig[0]], sim, duty=t.duty,
                          cgra_mw_paper=8.0, soc_mw_paper=30.0)
pm = PowerModel()
pm.fit([feats])                     # single-point anchor; see benchmarks
cgra_mw = pm.cgra_mw(feats)
cpu_cyc = cpu_cycles(KernelProfile(N * N * N + N * N + N, 2, 0.05, 2, 1, 1))
print(f"[offload] est. CGRA power {cgra_mw:.1f} mW; CPU baseline "
      f"{cpu_cyc:.0f} cycles -> speed-up {cpu_cyc / t.total:.1f}x, "
      f"energy ratio {(cpu_cyc * CPU_MW) / (t.total * cgra_mw):.1f}x")

# ---------------------------------------------------------------------------
# traced-frontend variant: the same epilogue written as plain Python/JAX
# ---------------------------------------------------------------------------
import jax.numpy as jnp

from repro.frontend import offload


@offload(debug=True)
def epilogue(d, y):
    """w = alpha*d + beta*y, then ReLU — traced, not hand-built."""
    return jnp.maximum(alpha * d + beta * y, 0)


w_traced = epilogue(d, y)
assert np.array_equal(np.asarray(w_traced), np.maximum(ref, 0)), \
    "traced-frontend result mismatch!"
info = epilogue.last
print(f"[frontend] traced epilogue: {info.n_shots} shot(s), backend "
      f"{info.backend}, II={info.ii:.2f}, {info.cycles} cycles "
      f"(cache {epilogue.cache_info()})")

# ---------------------------------------------------------------------------
# irregular loops: a data-dependent trip count per element (lax.while_loop
# lowered onto gated Branch/Merge recirculation, drained by token exhaustion)
# ---------------------------------------------------------------------------
from jax import lax


@offload(debug=True)
def normalize(v):
    """Shift each |w| value right until it fits in 6 bits — the trip count
    depends on the data, the paper's 'irregular loop' scenario."""
    def cond(c):
        shifts, x = c
        return x > 63

    def body(c):
        shifts, x = c
        return shifts + 1, x >> 1

    return lax.while_loop(cond, body, (0, jnp.where(v > 0, v, -v)))


shifts, mag = normalize(w_traced)
ref_mag = np.abs(np.asarray(w_traced))
ref_shifts = np.zeros_like(ref_mag)
while (ref_mag > 63).any():
    ref_shifts[ref_mag > 63] += 1
    ref_mag[ref_mag > 63] >>= 1
assert np.array_equal(np.asarray(mag), ref_mag)
assert np.array_equal(np.asarray(shifts), ref_shifts)
li = normalize.last
ii = f"II={li.ii:.1f} (data-dependent)" if li.n_shots == 1 \
    else f"{li.n_shots} shots (loop body kept atomic)"
print(f"[loops] traced while_loop kernel: {ii}, {li.cycles} cycles for "
      f"{len(ref_mag)} elements, max trip {int(ref_shifts.max())}")
print("strela_offload OK")
