"""Serve mixed kernel traffic through the always-on serving engine.

The ``repro.serve`` client walkthrough (DESIGN.md §14), in three acts:

  1. **Deterministic soak** — a seeded open-loop Poisson request stream
     (five config classes: short streaming kernels, a reduction, a
     multi-shot plan, an irregular loop) driven through
     :class:`~repro.serve.ServeEngine` under the virtual clock.
     Continuous config-class batching, shot-boundary preemption of the
     long multi-shot plan, and the SLO report (p50/p99, throughput,
     config-cycle savings vs naive per-request dispatch) all fall out of
     one ``drive()`` call — and the run is replayable: same seed, same
     trace digest, same results, on any machine.
  2. **Overload** — the same mix offered 3x faster than the fabric can
     serve: the bounded queue pushes back with named ``AdmissionError``
     rejections instead of letting latency grow without bound.
  3. **Always-on** — the threaded :class:`~repro.serve.Server` front
     end under a wall clock: clients ``submit()`` from anywhere, block
     on ``Ticket.result()``, and the context manager drains cleanly.

Run: PYTHONPATH=src python examples/engine_serve.py
"""
import numpy as np

from repro.engine import ArtifactCache, Engine
from repro.serve import (AdmissionError, ServeConfig, Server, ServeEngine,
                         make_requests, poisson_arrival_times,
                         request_inputs, serve_classes)

LENGTH = 64
N_REQUESTS = 120
SEED = 42


def fresh_engine():
    return Engine(cache=ArtifactCache(memory_only=True))


def soak(rate_per_us, cfg):
    engine = fresh_engine()
    classes = serve_classes(engine, LENGTH)
    rng = np.random.default_rng(SEED)
    times = poisson_arrival_times(rng, N_REQUESTS, rate_per_us)
    reqs = make_requests(classes, times, LENGTH, rng)
    serve = ServeEngine(engine, cfg)
    return serve, serve.drive(reqs)


def report_lines(label, rep):
    lat = rep["latency"]
    print(f"{label}: served {rep['served']}/{rep['offered']} "
          f"(rejected {rep['rejected']}) in {rep['now_us']:.0f} virtual us"
          f" -> {rep['served'] / rep['now_us'] * 1e6:.0f} req/s")
    print(f"  latency p50={lat['p50_us']:7.1f} us  "
          f"p99={lat['p99_us']:7.1f} us   preemptions={rep['preemptions']}"
          f"  batches={rep['batches']} {rep['close_reasons']}")
    print(f"  config cycles: paid {rep['config_cycles_paid']} vs naive "
          f"{rep['config_cycles_naive']} "
          f"(saved {rep['config_cycles_saved']})")


def main():
    cfg = ServeConfig(max_batch=8, max_wait_us=300.0, queue_capacity=48,
                      preempt_wait_us=100.0)

    # --- 1. nominal load: continuous batching + preemption, replayable
    serve, rep = soak(rate_per_us=0.12, cfg=cfg)
    print(f"traffic: {N_REQUESTS} seeded Poisson arrivals over 5 config "
          f"classes (incl. one multi-shot plan, one irregular loop)")
    report_lines("nominal ", rep)
    print(f"  replay contract: trace {rep['trace_digest'][:16]}… / "
          f"results {serve.results_digest()[:16]}… (seed {SEED})")
    assert rep["config_cycles_paid"] < rep["config_cycles_naive"]

    # --- 2. overload: admission control takes the hit, not the tail
    _, hot = soak(rate_per_us=0.6, cfg=cfg)
    print()
    report_lines("overload", hot)
    assert hot["rejected"] > 0, "expected backpressure at 5x the load"

    # --- 3. always-on threaded front end (wall clock)
    engine = fresh_engine()
    classes = serve_classes(engine, LENGTH)
    rng = np.random.default_rng(SEED)
    with Server(engine, cfg) as srv:
        tickets = [srv.submit(art, request_inputs(art, LENGTH, rng))
                   for art in classes.values() for _ in range(4)]
        outs = [tk.result(timeout=60) for tk in tickets]
    relu = classes["relu"]
    tk = next(t for t in tickets if t.artifact is relu)
    assert (tk.outputs["out"] == np.maximum(tk.inputs["x"], 0)).all()
    print(f"\nthreaded: {len(outs)} requests served via Server.submit(), "
          f"results exact, drained clean on exit")
    print(f"rejections raise {AdmissionError.__name__} — named, never "
          f"silent")


if __name__ == "__main__":
    main()
