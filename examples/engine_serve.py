"""Serve mixed kernel traffic through the execution engine.

Demonstrates the full unified pipeline (DESIGN.md §8) on a request mix an
embedded deployment would actually see: three kernels, interleaved arrival
order, dispatched twice —

  1. naive:    every request configures the fabric from scratch;
  2. batched:  requests are queued and flushed grouped by config class, so
               same-kernel runs pay only the stream re-arm preamble.

Prints per-strategy Tally breakdowns, the configuration cycles the
batcher saved, and — via the ``repro.obs`` metrics registry — per-request
latency percentiles (p50/p90/p99) and throughput for each strategy. Also
shows a non-4x4 geometry handling the same artifact pipeline.

Run: PYTHONPATH=src python examples/engine_serve.py
"""
import time

import numpy as np

from repro import obs
from repro.core import kernels_lib as K
from repro.core.fabric import Fabric
from repro.engine import ArtifactCache, Engine

LENGTH = 64
PER_KERNEL = 8


def make_traffic(rng):
    """Interleaved request mix: (kernel name, DFG factory, inputs)."""
    kernels = {
        "relu": K.relu(),
        "axpby": K.axpby(3, 5),
        "mac1": K.mac1(LENGTH),
    }
    traffic = []
    for i in range(PER_KERNEL):
        for name, g in kernels.items():
            ins = {k: rng.integers(-64, 64, LENGTH).astype(np.int32)
                   for k in g.inputs}
            traffic.append((name, g, ins))
    return kernels, traffic


def _latency_line(label: str, wall_s: float, n_requests: int) -> None:
    """p50/p90/p99 + throughput from the obs metrics registry: the engine
    itself recorded every request's latency into the
    ``engine.request_latency_us`` histogram while dispatching."""
    hist = obs.registry().histogram("engine.request_latency_us")
    p = hist.percentiles((50, 90, 99))
    print(f"{label}: latency p50={p[50]:7.1f} us  p90={p[90]:7.1f} us  "
          f"p99={p[99]:7.1f} us  throughput={n_requests / wall_s:8.0f} req/s"
          f"  ({hist.count} samples)")


def main():
    rng = np.random.default_rng(42)
    kernels, traffic = make_traffic(rng)

    print(f"traffic: {len(traffic)} requests, {len(kernels)} config classes,"
          f" arrival order interleaved (worst case for a naive dispatcher)")

    obs.enable(fresh=True)             # per-request latency metrics on
    naive = Engine(cache=ArtifactCache(memory_only=True))
    arts = {name: naive.compile(g) for name, g in kernels.items()}
    t0 = time.perf_counter()
    for name, _, ins in traffic:
        naive.run(arts[name], ins)
    wall_naive = time.perf_counter() - t0
    t = naive.tally
    print(f"\nnaive   : config={t.config:6d} rearm={t.rearm:6d} "
          f"exec={t.exec:6d} total={t.total:6d} (duty {t.duty:.2f})")
    _latency_line("naive   ", wall_naive, len(traffic))

    obs.enable(fresh=True)             # fresh registry: batched phase only
    batched = Engine(cache=ArtifactCache(memory_only=True))
    arts = {name: batched.compile(g) for name, g in kernels.items()}
    t0 = time.perf_counter()
    handles = [(name, batched.submit(arts[name], ins))
               for name, _, ins in traffic]
    batched.flush()
    wall_batched = time.perf_counter() - t0
    t = batched.tally
    print(f"\nbatched : config={t.config:6d} rearm={t.rearm:6d} "
          f"exec={t.exec:6d} total={t.total:6d} (duty {t.duty:.2f})")
    _latency_line("batched ", wall_batched, len(traffic))
    print(f"batching saved {batched.stats.config_cycles_saved} configuration"
          f" cycles ({batched.stats.requests} requests,"
          f" {batched.stats.flushes} flush)")
    obs.disable()

    # results stay exact — spot-check one relu request
    name, h = next((n, h) for n, h in handles if n == "relu")
    x = h.inputs["x"]
    assert (h.result()["out"] == np.maximum(x, 0)).all()

    # same pipeline, different geometry
    eng64 = Engine(fabric=Fabric(rows=6, cols=4))
    art = eng64.compile(K.mac1(LENGTH))
    ins = {"a": np.arange(LENGTH, dtype=np.int32),
           "b0": np.ones(LENGTH, dtype=np.int32)}
    out = eng64.run(art, ins)
    print(f"\n6x4 fabric: mac1 -> {int(out['out0'][0])} "
          f"(= {LENGTH*(LENGTH-1)//2}), {eng64.tally.total} cycles")


if __name__ == "__main__":
    main()
