"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the real production stack — model zoo (internlm2 family scaled to
~100M), deterministic data pipeline, AdamW + cosine, checkpointing +
auto-resume, straggler detection — on the local device mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults are sized so a CPU run finishes in tens of minutes; pass
--steps 20 for a smoke run)
"""
import argparse
import dataclasses
import sys

from repro.configs.base import ArchConfig, register
from repro.launch import train as T


def make_100m() -> ArchConfig:
    # ~109M params: 12L, d=768, 12H, ff=3072, 32k vocab (gpt2-small scale)
    return register(ArchConfig(
        arch_id="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32000, head_dim=64,
        activation="swiglu", remat=False, source="examples/train_lm.py"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/strela_demo_ckpt")
    args = ap.parse_args()

    make_100m()
    sys.argv = ["train", "--arch", "demo-100m", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", args.ckpt_dir, "--save-every", "100",
                "--lr", "6e-4"]
    T.main()


if __name__ == "__main__":
    main()
