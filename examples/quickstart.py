"""Quickstart: offload a kernel onto the STRELA fabric, three ways.

1. functional executor   — what the kernel computes (oracle)
2. elastic cycle sim     — what the 4x4 fabric does, cycle by cycle
3. Pallas fabric_stream  — the TPU adaptation (fused streaming kernel)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import kernels_lib as K
from repro.core.elastic_sim import simulate
from repro.core.executor import execute
from repro.core.mapper import generate_configs
from repro.core.paper_mappings import paper_mapping
from repro.kernels.fabric_stream import fabric_stream

rng = np.random.default_rng(0)

# ---- 1. build the ReLU dataflow graph (Fig. 5 right) --------------------
g = K.relu()
x = rng.integers(-1000, 1000, 4096).astype(np.int32)
ref = execute(g, {"x": x})["out"]
print(f"[exec] relu over {x.size} elements -> {ref[:6]}...")

# ---- 2. map onto the 4x4 fabric and simulate it cycle-accurately --------
m = paper_mapping("relu")
cfgs = generate_configs(m)
sim = simulate(m, {"x": x})
assert np.array_equal(sim.outputs["out"], ref)
print(f"[sim ] mapped to {m.n_active_pes()} PEs "
      f"({len(cfgs)} config words x 158b), {sim.cycles} cycles, "
      f"{sim.outputs_per_cycle():.2f} outputs/cycle, II={sim.steady_ii():.0f}")

# ---- 3. the same DFG as a fused Pallas streaming kernel -----------------
out = fabric_stream(g, {"x": jnp.asarray(x)})["out"]
assert np.array_equal(np.asarray(out), ref)
print(f"[tpu ] fabric_stream matches on {x.size} elements "
      f"(one fused HBM round-trip)")

# ---- bonus: the fft butterfly uses the full fabric ----------------------
gf = K.fft_butterfly()
ins = {k: rng.integers(-4096, 4096, 256).astype(np.int32)
       for k in ("ar", "ai", "br", "bi")}
mf = paper_mapping("fft")
simf = simulate(mf, ins)
reff = execute(gf, ins)
assert all(np.array_equal(simf.outputs[k], reff[k]) for k in reff)
print(f"[fft ] full-fabric butterfly: {simf.cycles} cycles "
      f"(paper: 523), {simf.outputs_per_cycle():.2f} outputs/cycle "
      f"(paper: 1.95)")
print("quickstart OK")
