"""Serving demo: batched prefill + greedy decode with KV caches on the
reduced qwen config (QKV-bias family), plus a mamba2 state-space decode to
show O(1)-state long-context serving.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve_lm as S


def main() -> None:
    for arch in ("qwen1.5-4b", "mamba2-1.3b"):
        print(f"=== serving {arch} (reduced config) ===")
        sys.argv = ["serve", "--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "16", "--gen", "12"]
        S.main()


if __name__ == "__main__":
    main()
