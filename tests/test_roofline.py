"""Roofline machinery tests: the HLO cost parser is validated against
programs with analytically known FLOP counts (including scan trip-count
scaling, the thing XLA's own cost_analysis gets wrong on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import roofline_from_costs
from repro.roofline.hlo_costs import HLOCosts


def _costs_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return HLOCosts(compiled.as_text())


def test_parser_counts_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    hc = _costs_of(lambda a, b: a @ b, a, b)
    want = 2 * 128 * 256 * 64
    assert hc.flops() == pytest.approx(want, rel=0.01)


def test_parser_scales_scan_bodies():
    """A matmul inside an 8-step lax.scan must count 8x — XLA's CPU
    cost_analysis reports it once."""
    w = jnp.zeros((8, 64, 64), jnp.float32)
    x = jnp.zeros((4, 64), jnp.float32)

    def fn(w, x):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    hc = _costs_of(fn, w, x)
    want = 8 * 2 * 4 * 64 * 64
    assert hc.flops() == pytest.approx(want, rel=0.05)


def test_parser_nested_scan_multiplies():
    w = jnp.zeros((3, 5, 32, 32), jnp.float32)
    x = jnp.zeros((2, 32), jnp.float32)

    def fn(w, x):
        def outer(c, ws):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    hc = _costs_of(fn, w, x)
    want = 15 * 2 * 2 * 32 * 32
    assert hc.flops() == pytest.approx(want, rel=0.05)


def test_parser_bytes_nonzero_and_plausible():
    a = jnp.zeros((1024, 1024), jnp.float32)
    hc = _costs_of(lambda a: (a * 2 + 1).sum(), a)
    nbytes = hc.hbm_bytes()
    assert nbytes >= a.size * 4            # at least one read of the input
    assert nbytes < a.size * 4 * 20        # and not wildly overcounted


def test_roofline_terms_and_bottleneck():
    rl = roofline_from_costs(flops=197e12, hbm_bytes=819e9,
                             collective_bytes=0, chips=1)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.bottleneck in ("compute", "memory")
    rl2 = roofline_from_costs(1e12, 1e9, 1e12, chips=256)
    assert rl2.bottleneck == "collective"


def test_collective_parse_on_psum():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # single-device psum lowers away; just verify the parser returns the
    # dict shape and zero totals without error
    hc = _costs_of(lambda x: x * 2, jnp.ones(8))
    coll = hc.collective_bytes()
    assert set(coll) >= {"all-gather", "all-reduce"}
    assert all(v >= 0 for v in coll.values())
