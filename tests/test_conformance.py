"""Cross-backend differential conformance suite (ISSUE 3 satellite,
extended to the 5-way gate of ISSUE 5).

A seeded random-DFG generator composes gadgets from the fabric's full
vocabulary — elementwise ALU/CMP/MUX chains, Branch/Merge conditionals,
loop-carried state cells (dither-style back edges), last-value
accumulators, and gated while-loops with data-dependent trip counts —
under the 4x4 fabric's budgets (<= 4 IMN / <= 4 OMN / bounded PE count).

Every generated graph carries its own *independent* reference semantics: a
pure-Python evaluator built gadget-by-gadget during generation (python
ints, explicit 32-bit wrapping) — deliberately sharing no code with
``core.executor``. Each case then asserts bit-exact agreement between

  1. the pure-Python reference,
  2. the functional executor (vectorized / loop / token paths),
  3. the *vectorized* elastic simulator on the placed-and-routed netlist,
  4. the *reference* simulator (``elastic_sim_ref``, the original
     token-by-token implementation) — which must agree with the
     vectorized core not just on outputs but on cycle counts, arrival
     schedules, FU firing counts, and bank beats (ISSUE 4),
  5. the **pallas backend** (``kernels/fabric_reduce.run_dfg``, interpret
     mode on CPU) for every DFG the declared capability set admits
     (ISSUE 5). Cases outside the set record a *named skip reason* (the
     missing capability features), and the skip tally is pinned: the
     corpus is deterministic, so any capability regression — a DFG class
     silently dropping off the fast substrate — moves the pinned counts
     and fails the gate.

The deterministic corpus below runs everywhere (>= 200 sim-verified cases,
the ISSUE acceptance bar); the hypothesis properties widen the sweep when
hypothesis is installed (CI runs them under the fixed ``ci`` profile).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("ci", deadline=None, derandomize=True,
                              max_examples=60)
    settings.register_profile("dev", deadline=None, max_examples=25)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core import dfg as D
from repro.core.elastic_sim import simulate
from repro.core.elastic_sim_ref import simulate_reference
from repro.core.executor import execute
from repro.core.isa import AluOp, CmpOp
from repro.core.mapper import MappingError, map_dfg

# corpus sizing: the ISSUE acceptance requires >= 200 sim-verified cases
N_CASES = 230
MIN_SIM_VERIFIED = 200
MAX_FUNC_NODES = 10          # leaves route-through headroom on 16 PEs

# 5-way gate pins (the corpus is deterministic, so these are EXACT —
# asserted with equality): 76 cases fall inside the pallas capability set
# and must verify bit-exact; the other 154 carry loop state /
# recirculation and record named skips. Any capability change — narrowing
# *or* widening — moves these and must re-pin them consciously.
PALLAS_VERIFIED = 76
PALLAS_SKIPPED = 154


def _wrap(v: int) -> int:
    """Two's-complement 32-bit wrap on python ints (independent of numpy)."""
    return ((int(v) & 0xFFFFFFFF) ^ 0x80000000) - 0x80000000


def _alu_ref(op: AluOp, a: int, b: int) -> int:
    if op == AluOp.ADD:
        return _wrap(a + b)
    if op == AluOp.SUB:
        return _wrap(a - b)
    if op == AluOp.MUL:
        return _wrap(a * b)
    if op == AluOp.AND:
        return _wrap(a & b)
    if op == AluOp.OR:
        return _wrap(a | b)
    if op == AluOp.XOR:
        return _wrap(a ^ b)
    if op == AluOp.SHL:
        return _wrap(a << (b & 31))
    if op == AluOp.SHR:
        return _wrap(a >> (b & 31))
    raise ValueError(op)


_EW_OPS = (AluOp.ADD, AluOp.SUB, AluOp.MUL, AluOp.AND, AluOp.OR, AluOp.XOR,
           AluOp.SHL, AluOp.SHR)
_ACC_OPS = ((AluOp.ADD, 0), (AluOp.XOR, 0), (AluOp.OR, 0))


class _Gen:
    """One random conformance case: a DFG plus per-wire reference values.
    Wires are ``(node, port)`` tuples; ``self.vals`` maps each full-rate
    wire to its pure-Python per-element reference values.

    The generator is congestion- and skew-aware, like a real kernel author:
    a wire feeds at most two consumers (Fork-Sender pressure), and joined
    operands must sit within one pipeline stage of each other — 2-slot
    elastic buffers deadlock when reconvergent-path skew exceeds their
    slack, a liveness property of the microarchitecture itself."""

    def __init__(self, seed: int, length: int):
        self.rng = np.random.default_rng(seed)
        self.length = length
        self.b = D.DFG.build(f"conf{seed}")
        self.vals = {}               # (node, port) -> [python int] * length
        self.depth = {}              # (node, port) -> pipeline depth
        self.uses = {}               # (node, port) -> consumer count
        self.sensitive = set()       # cond-merge outputs: arrival-ordered
        self.while_exits = []
        self.n_func = 0
        self.k = 0

    def name(self, stem: str) -> str:
        self.k += 1
        return f"{stem}{self.k}"

    def const(self) -> int:
        return int(self.rng.integers(-9, 10))

    def reg(self, wire, vals, depth: int) -> None:
        self.vals[wire] = vals
        self.depth[wire] = depth
        self.uses.setdefault(wire, 0)

    def pick_wire(self, near=None, tol: int = 1, ordered: bool = False):
        """A lightly-used wire, optionally within ``tol`` pipeline stages of
        depth ``near`` (None candidates fall back progressively).

        ``ordered=True`` excludes cond-merge outputs: an any-valid MERGE
        commits its legs in *arrival* order, which backpressure from a
        sub-rate consumer (a loop or state cell) can permute — the paper's
        kernels only ever feed merges into full-rate consumers, and the
        generator mirrors that contract."""
        pool = [w for w in sorted(self.vals)
                if not (ordered and w in self.sensitive)]
        for maxuse, t in ((2, tol), (2, 99), (99, 99)):
            cand = [w for w in pool
                    if self.uses[w] < maxuse
                    and (near is None or abs(self.depth[w] - near) <= t)]
            if cand:
                w = cand[int(self.rng.integers(0, len(cand)))]
                self.uses[w] += 1
                return w
        raise AssertionError("no wires")

    # -- gadgets (each records exact reference semantics) -------------------
    def g_alu(self) -> None:
        a = self.pick_wire()
        op = _EW_OPS[int(self.rng.integers(0, len(_EW_OPS)))]
        n = self.name("alu")
        da = self.depth[a]
        if self.rng.random() < 0.5:
            c = abs(self.const()) if op in (AluOp.SHL, AluOp.SHR) \
                else self.const()
            self.b.alu(n, op, a[0], const_b=c, a_port=a[1])
            self.reg((n, "out"), [_alu_ref(op, v, c) for v in self.vals[a]],
                     da + 1)
        else:
            b2 = self.pick_wire(near=da)
            self.b.alu(n, op, a[0], b2[0], a_port=a[1], b_port=b2[1])
            self.reg((n, "out"),
                     [_alu_ref(op, v, w) for v, w in
                      zip(self.vals[a], self.vals[b2])],
                     max(da, self.depth[b2]) + 1)
        self.n_func += 1

    def _cmp(self, a):
        op = CmpOp.GTZ if self.rng.random() < 0.8 else CmpOp.EQZ
        c = self.const()
        n = self.name("cmp")
        self.b.cmp(n, op, a[0], const_b=c, a_port=a[1])
        diff = [_wrap(v - c) for v in self.vals[a]]
        self.reg((n, "out"), [int(d > 0) if op == CmpOp.GTZ else int(d == 0)
                              for d in diff], self.depth[a] + 1)
        self.n_func += 1
        return (n, "out")

    def g_mux(self) -> None:
        base = self.pick_wire()
        ctrl = self._cmp(base)
        dc = self.depth[ctrl]
        a, b2 = self.pick_wire(near=dc), self.pick_wire(near=dc)
        n = self.name("mux")
        self.b.mux(n, a[0], b2[0], ctrl[0], a_port=a[1], b_port=b2[1],
                   ctrl_port=ctrl[1])
        self.reg((n, "out"),
                 [va if c else vb for va, vb, c in
                  zip(self.vals[a], self.vals[b2], self.vals[ctrl])],
                 max(self.depth[a], self.depth[b2], dc) + 1)
        self.n_func += 1

    def g_branch_merge(self) -> None:
        """cond gadget: BRANCH steers a value onto complementary legs, each
        leg applies a different constant op, a MERGE rejoins them."""
        base = self.pick_wire()
        ctrl = self._cmp(base)
        a = self.pick_wire(near=self.depth[ctrl])
        br = self.name("br")
        self.b.branch(br, a[0], ctrl[0], a_port=a[1], ctrl_port=ctrl[1])
        opt, ct = _EW_OPS[int(self.rng.integers(0, 6))], self.const()
        opf, cf = _EW_OPS[int(self.rng.integers(0, 6))], self.const()
        tn, fn = self.name("lt"), self.name("lf")
        self.b.alu(tn, opt, br, const_b=ct, a_port="t")
        self.b.alu(fn, opf, br, const_b=cf, a_port="f")
        mg = self.name("mg")
        self.b.merge(mg, tn, fn)
        self.reg((mg, "out"),
                 [_alu_ref(opt, v, ct) if c else _alu_ref(opf, v, cf)
                  for v, c in zip(self.vals[a], self.vals[ctrl])],
                 max(self.depth[a], self.depth[ctrl]) + 3)
        self.sensitive.add((mg, "out"))
        self.n_func += 4

    def g_state(self) -> None:
        """dither-style loop-carried cell: s1 = op(x, s2_prev); s2 =
        op2(s1, const); the s2 -> s1 edge is a back edge with an init."""
        x = self.pick_wire(ordered=True)     # sub-rate consumer (II=2 loop)
        op = (AluOp.ADD, AluOp.SUB, AluOp.XOR)[int(self.rng.integers(0, 3))]
        op2, c2 = (AluOp.AND, AluOp.SHR)[int(self.rng.integers(0, 2))], \
            abs(self.const()) % 6 + 1
        init = self.const()
        s1, s2 = self.name("st"), self.name("st")
        self.b.alu(s1, op, x[0], None, a_port=x[1])
        self.b.alu(s2, op2, s1, const_b=c2)
        self.b.back_edge(s2, s1, "b", init=init)
        carry, v1s, v2s = init, [], []
        for v in self.vals[x]:
            v1 = _alu_ref(op, v, carry)
            carry = _alu_ref(op2, v1, c2)
            v1s.append(v1)
            v2s.append(carry)
        self.reg((s1, "out"), v1s, self.depth[x] + 1)
        self.reg((s2, "out"), v2s, self.depth[x] + 2)
        self.n_func += 2

    def g_while(self) -> None:
        """gated data-dependent loop: (q, r) = divmod(x & 31, d) on the
        recirculating Branch/Merge schema (cf. kernels_lib.div_loop)."""
        x = self.pick_wire(ordered=True)     # sub-rate consumer (gated loop)
        d = int(self.rng.integers(3, 10))
        msk = self.name("msk")
        self.b.alu(msk, AluOp.AND, x[0], const_b=31, a_port=x[1])
        gate = self.name("lg")
        self.b.alu(gate, AluOp.ADD, msk, None)
        q0 = self.name("lq0")
        self.b.alu(q0, AluOp.MUL, gate, const_b=0)
        mr, mq = self.name("lmr"), self.name("lmq")
        self.b.merge(mr, None, gate)
        self.b.merge(mq, None, q0)
        c = self.name("lc")
        self.b.cmp(c, CmpOp.GTZ, mr, const_b=d - 1)
        brr, brq = self.name("lbr"), self.name("lbr")
        self.b.branch(brr, mr, c)
        self.b.branch(brq, mq, c)
        rn, qn = self.name("lrn"), self.name("lqn")
        self.b.alu(rn, AluOp.SUB, brr, const_b=d, a_port="t")
        self.b.alu(qn, AluOp.ADD, brq, const_b=1, a_port="t")
        self.b.back_edge(rn, mr, "a", init=None)
        self.b.back_edge(qn, mq, "a", init=None)
        dem = self.name("ldem")
        self.b.alu(dem, AluOp.MUL, brq, const_b=0, a_port="f")
        self.b.back_edge(dem, gate, "b", init=0)
        self.n_func += 10
        # exit legs are full-rate wires usable downstream
        dx = self.depth[x]
        self.reg((brq, "f"), [(v & 31) // d for v in self.vals[x]], dx + 4)
        self.reg((brr, "f"), [(v & 31) % d for v in self.vals[x]], dx + 4)
        self.while_exits += [(brq, "f"), (brr, "f")]

    def build(self):
        rng = self.rng
        n_in = int(rng.integers(1, 4))
        big_range = rng.random() < 0.25            # stress 32-bit wrapping
        lo, hi = ((-2**31, 2**31) if big_range else (-100, 100))
        inputs = {}
        for i in range(n_in):
            nm = f"in{i}"
            self.b.inp(nm)
            arr = rng.integers(lo, hi, self.length, dtype=np.int64)
            inputs[nm] = arr.astype(np.int32)
            self.reg((nm, "out"), [int(v) for v in inputs[nm]], 0)

        gadgets = [self.g_alu, self.g_alu, self.g_mux, self.g_branch_merge,
                   self.g_state]
        want_while = rng.random() < 0.35
        if want_while:
            self.g_while()
        while self.n_func < MAX_FUNC_NODES - 1:
            gadget = gadgets[int(rng.integers(0, len(gadgets)))]
            cost = {self.g_alu: 1, self.g_mux: 2, self.g_branch_merge: 5,
                    self.g_state: 2}[gadget]
            if self.n_func + cost > MAX_FUNC_NODES:
                break
            gadget()

        # a last-value accumulator on some wire (feeds only its OUTPUT)
        acc_out = None
        if rng.random() < 0.4:
            src = self.pick_wire()
            op, init = _ACC_OPS[int(rng.integers(0, len(_ACC_OPS)))]
            an = self.name("acc")
            self.b.alu(an, op, src, acc_init=init, emit_every=0)
            ref = init
            for v in self.vals[src]:
                ref = _alu_ref(op, ref, v)
            acc_out = (an, [ref])

        # outputs: while exits first (guarantees recirculation coverage),
        # then the most recently created full-rate wires, capped at 4 OMNs
        ref_outputs = {}
        chosen = list(self.while_exits)
        chosen += [w for w in sorted(self.vals)
                   if w not in self.while_exits
                   and self.b.nodes[w[0]].kind != D.INPUT][-3:]
        for w in chosen[:4 - bool(acc_out)]:
            o = f"out{len(ref_outputs)}"
            self.b.out(o, w[0], src_port=w[1])
            ref_outputs[o] = self.vals[w]
        if acc_out is not None:
            o = f"out{len(ref_outputs)}"
            self.b.out(o, acc_out[0])
            ref_outputs[o] = acc_out[1]

        # every IMN stream must reach an output: mop up unused inputs
        g = None
        try:
            g = self.b.done()
        except ValueError:
            return None, None, None
        live = _live_inputs(g)
        if set(inputs) - live:
            return None, None, None
        return g, inputs, ref_outputs


def _live_inputs(g: D.DFG) -> set:
    rev = {}
    for e in g.edges:
        rev.setdefault(e.dst, []).append(e.src)
    seen, stack = set(g.outputs), list(g.outputs)
    while stack:
        for p in rev.get(stack.pop(), ()):
            if p not in seen:
                seen.add(p)
                stack.append(p)
    return {n for n in g.inputs if n in seen}


def _mk_case(seed: int, length: int):
    """Generate one case; retries nearby seeds when a draw wires an input
    to nothing (the generator is total apart from that)."""
    for s in range(seed, seed + 50):
        gen = _Gen(s * 7919 + 13, length)
        g, inputs, refs = gen.build()
        if g is not None:
            return g, inputs, refs
    raise AssertionError(f"no viable case near seed {seed}")


def _pallas_skip_reason(g, length: int):
    """Named skip reason when a case falls outside the pallas capability
    set (None = must run and verify bit-exact). Delegates to the single
    source of truth the real dispatcher uses."""
    from repro.engine.capabilities import backend_skip_reason
    return backend_skip_reason(g, length, "pallas")


def _assert_case(seed: int, length: int, with_sim: bool,
                 with_pallas: bool = False, case=None) -> bool:
    """Run one case across the backends; returns True if sim-verified.
    ``case``: a prebuilt ``_mk_case`` result (the corpus loop reuses its
    graph so the capability-analysis memos hit instead of re-walking a
    fresh instance)."""
    g, inputs, refs = case if case is not None else _mk_case(seed, length)
    outs = execute(g, inputs)
    for o, ref in refs.items():
        got = outs[o].tolist()
        assert got == ref, (
            f"seed {seed}: executor vs reference mismatch on {o}: "
            f"{got[:8]} != {ref[:8]} (graph {g.name})")
    if with_pallas and _pallas_skip_reason(g, length) is None:
        from repro.kernels.fabric_reduce import run_dfg
        pouts = run_dfg(g, inputs)
        for o, ref in refs.items():
            got = pouts[o].tolist()
            assert got == ref, (
                f"seed {seed}: pallas vs reference mismatch on {o}: "
                f"{got[:8]} != {ref[:8]} (graph {g.name})")
    if not with_sim:
        return False
    try:
        m = map_dfg(g, restarts=60, seed=1)
    except MappingError:
        return False
    try:
        sim = simulate(m, inputs)
    except RuntimeError as e:
        # 2-slot elastic buffers genuinely deadlock on reconvergent paths
        # whose latency skew exceeds the buffering slack (a liveness limit
        # of the microarchitecture, not a semantics bug) — count these like
        # routing failures, never as conformance passes. The reference
        # simulator must agree that the netlist deadlocks.
        if "deadlock" in str(e):
            with pytest.raises(RuntimeError, match="deadlock"):
                simulate_reference(m, inputs)
            return False
        raise
    for o, ref in refs.items():
        got = sim.outputs[o].tolist()
        assert got == ref, (
            f"seed {seed}: elastic sim vs reference mismatch on {o}: "
            f"{got[:8]} != {ref[:8]} (graph {g.name})")
    # differential oracle: the vectorized core must reproduce the original
    # simulator's full timing surface, not just the values
    ref_sim = simulate_reference(m, inputs)
    assert sim.cycles == ref_sim.cycles, (
        f"seed {seed}: cycle count diverged: fast {sim.cycles} != "
        f"reference {ref_sim.cycles} (graph {g.name})")
    assert sim.arrival_cycles == ref_sim.arrival_cycles, (
        f"seed {seed}: arrival schedule diverged (graph {g.name})")
    assert sim.fu_firings == ref_sim.fu_firings, (
        f"seed {seed}: FU firing counts diverged (graph {g.name})")
    assert sim.bank_beats == ref_sim.bank_beats, (
        f"seed {seed}: bank beats diverged (graph {g.name})")
    for o in refs:
        assert sim.outputs[o].tolist() == ref_sim.outputs[o].tolist(), (
            f"seed {seed}: fast vs reference sim outputs differ on {o}")
    return True


# ---------------------------------------------------------------------------
# deterministic corpus (always runs; the ISSUE acceptance bar)
# ---------------------------------------------------------------------------

def test_conformance_corpus():
    sim_verified = 0
    recirc_cases = 0
    pallas_verified = 0
    pallas_skips = {}              # seed -> named skip reason
    for seed in range(N_CASES):
        length = (8, 16, 24)[seed % 3]
        case = _mk_case(seed, length)
        g = case[0]
        if g.has_recirculation():
            recirc_cases += 1
        reason = _pallas_skip_reason(g, length)
        if reason is None:
            pallas_verified += 1
        else:
            pallas_skips[seed] = reason
        if _assert_case(seed, length, with_sim=True, with_pallas=True,
                        case=case):
            sim_verified += 1
    assert sim_verified >= MIN_SIM_VERIFIED, (
        f"only {sim_verified}/{N_CASES} cases were sim-verified "
        f"(need >= {MIN_SIM_VERIFIED}; rest failed to place-and-route)")
    assert recirc_cases >= 30, "corpus lost its data-dependent-loop coverage"
    # 5-way gate: every admitted case verified above; the tallies are
    # pinned EXACTLY so capability regressions and silent widenings are
    # equally loud (the corpus is deterministic, so equality is stable)
    by_reason = {r: sum(1 for v in pallas_skips.values() if v == r)
                 for r in set(pallas_skips.values())}
    assert pallas_verified == PALLAS_VERIFIED, (
        f"{pallas_verified} cases ran on the pallas backend (pinned "
        f"{PALLAS_VERIFIED}) — the capability set moved; skips by "
        f"reason: {by_reason}")
    assert len(pallas_skips) == PALLAS_SKIPPED, (
        f"{len(pallas_skips)} pallas skips != pinned {PALLAS_SKIPPED}: "
        f"{by_reason}")
    for seed, reason in pallas_skips.items():
        assert reason, f"seed {seed}: skip without a named reason"


def test_conformance_case_is_deterministic():
    a = _mk_case(3, 16)[0].canonical_signature()
    b = _mk_case(3, 16)[0].canonical_signature()
    assert a == b


# ---------------------------------------------------------------------------
# hypothesis properties (skip cleanly without hypothesis; CI profile fixed)
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=N_CASES, max_value=10**6))
@settings(deadline=None)
def test_property_executor_matches_reference(seed):
    """Any generated graph: functional executor == pure-Python reference."""
    _assert_case(seed, 12, with_sim=False)


@given(seed=st.integers(min_value=N_CASES, max_value=10**5),
       length=st.sampled_from([4, 8, 20]))
@settings(deadline=None, max_examples=20)
def test_property_five_way_agreement(seed, length):
    """Both simulators, the executor, the pure-Python reference — and the
    pallas backend where the capability set admits the graph — agree for
    every routable graph and stream length."""
    _assert_case(seed, length, with_sim=True, with_pallas=True)
