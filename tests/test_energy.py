"""Power/energy model tests (ISSUE 5 satellite): ``core/energy.py`` was
the last untested core module. Covers the NNLS fit / report round-trip on
the paper's published Table I samples, feature extraction from real
mapped-and-simulated paper kernels, the energy arithmetic, and — as a
property — that the fitted CGRA power predictor is physical: non-negative
everywhere and monotone in the active-PE count (hierarchical clock gating
means more enabled PEs can never cost *less* power)."""
import dataclasses
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("ci", deadline=None, derandomize=True,
                              max_examples=60)
    settings.register_profile("dev", deadline=None, max_examples=25)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core import kernels_lib as K
from repro.core import paper_data as PD
from repro.core.elastic_sim import simulate
from repro.core.energy import (PowerModel, PowerFeatures, energy_uj,
                               features_from_profile, features_from_sim)
from repro.core.mapper import map_dfg

rng = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# fixtures: mapped + simulated paper kernels with their published powers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_samples():
    """(kernel name, mapping, sim, PowerFeatures) for each Table I kernel,
    simulated at a reduced stream length (features are rates, so the
    length only needs to reach steady state)."""
    out = []
    for name, maker in K.ONE_SHOT.items():
        g = maker()
        m = map_dfg(g, restarts=300, seed=3)
        lo, hi = (0, 255) if name == "dither" else (-100, 100)
        ins = {k: rng.integers(lo, hi, 128).astype(np.int32)
               for k in g.inputs}
        sim = simulate(m, ins)
        t1 = PD.TABLE_I[name]
        out.append((name, m, sim,
                    features_from_sim(m, sim, 1.0, t1[5], t1[11])))
    return out


@pytest.fixture(scope="module")
def fitted(paper_samples):
    pm = PowerModel()
    pm.fit([f for _, _, _, f in paper_samples])
    return pm


# ---------------------------------------------------------------------------
# features_from_sim on the paper kernels
# ---------------------------------------------------------------------------

def test_features_from_sim_are_physical(paper_samples):
    for name, m, sim, f in paper_samples:
        assert 0.0 <= f.duty <= 1.0, name
        assert f.arith_act >= 0 and f.ctrl_act >= 0, name
        assert f.route_pes >= 0, name
        assert f.mem_rate > 0, name          # every kernel streams I/O
        # activity is firings per cycle: bounded by the enabled FU count
        assert f.arith_act + f.ctrl_act <= len(m.dfg.nodes), name
    by_name = {name: f for name, _, _, f in paper_samples}
    # fft is the arithmetic-heavy kernel of Table I (10 muls/adds per 4
    # inputs); its arithmetic activity must dominate relu's single mux path
    assert by_name["fft"].arith_act > by_name["relu"].arith_act
    # control kernels actually enable control FUs
    assert by_name["find2min"].ctrl_act > 0


def test_features_row_matches_model_structure():
    f = PowerFeatures(duty=0.5, arith_act=2.0, ctrl_act=1.0, route_pes=4.0,
                      mem_rate=0.25)
    row = f.row()
    assert row == [0.5, 2.0, 1.0, 4.0 * 0.5, 0.25, 1.0]
    # route-PE leakage is gated with the matrix: duty scales that column
    assert dataclasses.replace(f, duty=0.0).row()[3] == 0.0


def test_features_from_sim_delegates_through_profiler(paper_samples):
    """``features_from_sim`` is re-based on the fabric profiler (ISSUE 6):
    the profiler's firing attribution must reproduce the original direct
    formula exactly — same activity rates, same route-PE count, same
    memory rate — so every previously fitted model (and Table I's esave
    reproduction) is numerically unchanged."""
    from repro.core import dfg as D
    from repro.obs.profiler import profile_sim

    for name, m, sim, f in paper_samples:
        g = m.dfg
        cycles = max(sim.cycles, 1)
        arith = sum(cnt for n, cnt in sim.fu_firings.items()
                    if g.nodes[n].kind == D.ALU) / cycles
        ctrl = sum(cnt for n, cnt in sim.fu_firings.items()
                   if g.nodes[n].kind != D.ALU) / cycles
        assert f.arith_act == arith, name
        assert f.ctrl_act == ctrl, name
        assert f.route_pes == m.n_active_pes() - len(m.place), name
        assert f.mem_rate == sim.bank_beats / cycles, name
        # the profiler's per-PE rows account for every firing the
        # simulator recorded — nothing double-counted, nothing dropped
        p = profile_sim(m, sim)
        assert p.pe_firings == sum(sim.fu_firings.values()), name
        assert features_from_profile(p, 1.0, f.cgra_mw_paper,
                                     f.soc_mw_paper) == f, name


# ---------------------------------------------------------------------------
# fit / report round-trip
# ---------------------------------------------------------------------------

def test_fit_report_round_trip(fitted, paper_samples):
    rows = fitted.report()
    assert len(rows) == len(paper_samples)
    for row in rows:
        for key in ("cgra_mw_model", "cgra_mw_paper", "cgra_rel_err",
                    "soc_mw_model", "soc_mw_paper", "soc_rel_err"):
            assert np.isfinite(row[key]), key
        # the 6-parameter model over 4 published samples must actually
        # calibrate — generous bound, catches sign/col-order regressions
        assert abs(row["cgra_rel_err"]) < 0.75, row
        assert abs(row["soc_rel_err"]) < 0.75, row
        assert row["cgra_mw_model"] > 0
        assert row["soc_mw_model"] > row["cgra_mw_model"] * fitted.gamma[1] \
            - 1e-9                     # SoC adds uncore power on top


def test_fit_coefficients_nonnegative(fitted):
    assert fitted.beta is not None and fitted.gamma is not None
    assert np.all(fitted.beta >= 0)
    assert np.all(fitted.gamma >= 0)


def test_predict_requires_fit():
    pm = PowerModel()
    with pytest.raises(AssertionError):
        pm.cgra_mw(PowerFeatures(1, 1, 1, 0, 0.1))


# ---------------------------------------------------------------------------
# energy arithmetic
# ---------------------------------------------------------------------------

def test_energy_uj_arithmetic():
    # 10 mW for 250e6 cycles at 250 MHz = 10 mW x 1 s = 10 mJ = 1e4 uJ
    assert energy_uj(10.0, 250_000_000, clock_mhz=250.0) == \
        pytest.approx(1e4)
    # linear in both power and cycles; zero cycles cost nothing
    assert energy_uj(5.0, 1000) == pytest.approx(energy_uj(10.0, 500))
    assert energy_uj(123.0, 0) == 0.0
    # doubling the clock halves the energy of a fixed cycle count
    assert energy_uj(8.0, 4096, clock_mhz=500.0) == \
        pytest.approx(energy_uj(8.0, 4096, clock_mhz=250.0) / 2)


def test_cpu_energy_comparison_reproduces_table_i_esave():
    """``energy_uj`` over the published powers and cycle counts must
    reproduce Table I's energy-saving column: direction exactly (fft/relu
    save energy, find2min does *not* — esave 0.70), magnitude within the
    paper's own rounding (the table reports derived columns to 2 digits)."""
    for name, t1 in PD.TABLE_I.items():
        cgra = energy_uj(t1[5], t1[0] + t1[1])       # cgra_mw x cycles
        cpu = energy_uj(t1[8], t1[7])                # cpu_mw x cpu cycles
        esave = cpu / cgra
        assert (esave > 1) == (t1[10] > 1), name
        assert esave == pytest.approx(t1[10], rel=0.3), name


# ---------------------------------------------------------------------------
# property: fitted power is non-negative and monotone in active-PE count
# ---------------------------------------------------------------------------

@given(duty=st.floats(0.0, 1.0), arith=st.floats(0.0, 16.0),
       ctrl=st.floats(0.0, 16.0), route=st.integers(0, 12),
       extra=st.integers(1, 8), mem=st.floats(0.0, 4.0))
@settings(deadline=None)
def test_property_power_nonnegative_and_monotone_in_pes(
        duty, arith, ctrl, route, extra, mem):
    pm = _FITTED_FOR_PROPERTY()
    f = PowerFeatures(duty=duty, arith_act=arith, ctrl_act=ctrl,
                      route_pes=float(route), mem_rate=mem)
    p = pm.cgra_mw(f)
    assert p >= 0.0
    assert pm.soc_mw(f) >= 0.0
    # activating more PEs (route-throughs here, the pure PE-count knob)
    # can only hold or raise power under hierarchical clock gating
    more = dataclasses.replace(f, route_pes=float(route + extra))
    assert pm.cgra_mw(more) >= p - 1e-12


_PM_CACHE = []


def _FITTED_FOR_PROPERTY():
    """Module-lazy fitted model (hypothesis calls the property many times;
    fixtures aren't available inside @given)."""
    if not _PM_CACHE:
        samples = []
        for name, maker in K.ONE_SHOT.items():
            g = maker()
            m = map_dfg(g, restarts=300, seed=3)
            ins = {k: rng.integers(0, 100, 64).astype(np.int32)
                   for k in g.inputs}
            t1 = PD.TABLE_I[name]
            samples.append(features_from_sim(m, simulate(m, ins), 1.0,
                                             t1[5], t1[11]))
        pm = PowerModel()
        pm.fit(samples)
        _PM_CACHE.append(pm)
    return _PM_CACHE[0]
