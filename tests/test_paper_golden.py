"""Golden paper-regression pins (ISSUE 3 satellite).

Table I figures that the repo already models are pinned here as *exact*
asserts on the simulator's measured numbers, next to the paper constant
they reproduce — so a sim/mapper refactor cannot silently drift away from
the paper without failing a test that names the figure it broke.

The streams are fixed (seeded) because the pins are exact; the paper
tolerance check alongside each pin documents how close the model is to the
published number (Sec. VII-B measurement conditions: 1024 total input
elements per kernel).
"""
import numpy as np
import pytest

from repro.core import paper_data as PD
from repro.core.elastic_sim import simulate
from repro.core.isa import config_cycles
from repro.core.paper_mappings import paper_mapping


def _sim(name, inputs):
    return simulate(paper_mapping(name), inputs)


@pytest.fixture(scope="module")
def fft_sim():
    rng = np.random.default_rng(0)
    m = paper_mapping("fft")
    ins = {k: rng.integers(-4096, 4096, 256).astype(np.int32)
           for k in m.dfg.inputs}          # 4 streams x 256 = 1024 elements
    return simulate(m, ins)


def test_fft_outputs_per_cycle_pin(fft_sim):
    """Paper Table I: fft streams 1.95 outputs/cycle; our mapped-netlist
    model measures exactly 2.0 (the 8-streams-on-4-banks bound)."""
    paper = PD.TABLE_I["fft"][3]                       # 1.95
    assert fft_sim.outputs_per_cycle() == 2.0
    assert abs(fft_sim.outputs_per_cycle() - paper) / paper < 0.03


def test_fft_exec_cycles_pin(fft_sim):
    """Paper Table I: 523 execution cycles for 1024 elements; model: 512."""
    paper = PD.TABLE_I["fft"][1]                       # 523
    assert fft_sim.cycles == 512
    assert abs(fft_sim.cycles - paper) / paper < 0.03


def test_fft_config_cycles_pin():
    """Paper Table I: 84 configuration cycles (16 PEs x 5 words + launch)."""
    m = paper_mapping("fft")
    assert m.config_cycles() == PD.TABLE_I["fft"][0] == 84
    assert config_cycles(16) == 84 and config_cycles(14) == 74


def test_dither_ii_pin():
    """Paper Sec. VII-B: dither's 4-FU feedback loop gives exactly II=4."""
    rng = np.random.default_rng(0)
    s = _sim("dither", {"x": rng.integers(0, 256, 1024).astype(np.int32)})
    assert s.steady_ii() == 4.0
    assert s.cycles == 4097                       # 1024 elements x II=4 + fill


def test_dither_c2_cycles_pin():
    """Paper Table I: 4617 cycles for the x2-unrolled dither; model: 4097
    (the II=4 recurrence bound with ideal memory, within 12%)."""
    rng = np.random.default_rng(0)
    m = paper_mapping("dither_c2")
    ins = {k: rng.integers(0, 256, 512).astype(np.int32) for k in m.dfg.inputs}
    s = simulate(m, ins)
    paper = PD.TABLE_I["dither"][1]                    # 4617
    assert s.cycles == 4097
    assert abs(s.cycles - paper) / paper < 0.15


def test_find2min_ii_pin():
    """find2min (irregular loop): the mux-form mapping sustains II=2 and
    ~5.6e-4 outputs/cycle (4 scalars per 1024-element stream, Table I)."""
    rng = np.random.default_rng(0)
    s = _sim("find2min", {"x": rng.integers(0, 10**6, 1024).astype(np.int32)})
    assert s.steady_ii() == 2.0
    assert s.cycles == 2052
    paper_opc = PD.TABLE_I["find2min"][3]              # 5.57e-4
    assert s.outputs_per_cycle() == pytest.approx(4 / 2052)
    assert abs(s.outputs_per_cycle() - paper_opc) / paper_opc < 3.6


def test_find2min_brmg_ii_pin():
    """The paper-faithful Branch/Merge recirculation form of find2min runs
    its 3-FU steering loop at II=3."""
    rng = np.random.default_rng(0)
    s = _sim("find2min_brmg",
             {"x": rng.integers(0, 10**6, 1024).astype(np.int32)})
    assert s.steady_ii() == 3.0
    assert s.cycles == 3077
