"""ISA/configuration-word unit tests (paper Sec. III-C / V-B / V-C)."""
import random

from repro.core import isa


def test_bit_budget_matches_paper():
    # 146 functional + 6 id = the paper's 152-bit word (Sec. V-B), + 6
    # clock-gating bits = 158 (Sec. V-C), streamed as five 32-bit words
    assert isa.FUNC_BITS == 146
    assert isa.ID_BITS == 6
    assert isa.GATE_BITS == 6
    assert isa.TOTAL_BITS == 158
    assert isa.WORDS_PER_PE == 5
    assert isa.WORDS_PER_PE * 32 >= isa.TOTAL_BITS


def test_config_roundtrip_defaults():
    cfg = isa.PEConfig(pe_id=13, gate_mask=0b101010)
    words = cfg.to_words()
    back = isa.PEConfig.from_words(words)
    assert back == cfg


def test_config_roundtrip_random():
    rng = random.Random(0)
    for _ in range(50):
        cfg = isa.PEConfig(
            alu_op=isa.AluOp(rng.randrange(len(isa.AluOp))),
            alu_fb_imm=rng.randrange(2),
            cmp_op=isa.CmpOp(rng.randrange(len(isa.CmpOp))),
            jm_mode=isa.JoinMergeMode(rng.randrange(3)),
            out_mux=isa.OutMux(rng.randrange(3)),
            data_reg_init=rng.randrange(1 << 32),
            valid_reg_init=rng.randrange(8),
            fu_fork_mask=rng.randrange(64),
            valid_delay=rng.randrange(64),
            in_a_sel=isa.OperandSel(rng.randrange(6)),
            in_b_sel=isa.OperandSel(rng.randrange(6)),
            ctrl_sel=isa.CtrlSel(rng.randrange(4)),
            const_val=rng.randrange(1 << 32),
            in_fork_mask_n=rng.randrange(64),
            out_sel_s=isa.OutSel(rng.randrange(7)),
            branch_swap=rng.randrange(2),
            pe_id=rng.randrange(64),
            gate_mask=rng.randrange(64),
        )
        assert isa.PEConfig.from_words(cfg.to_words()) == cfg


def test_config_cycles_match_table_i():
    # Table I: fft/find2min use 16 PEs -> 84 cycles; relu/dither 14 -> 74
    assert isa.config_cycles(16) == 84
    assert isa.config_cycles(14) == 74


def test_config_stream_word_count():
    cfgs = [isa.PEConfig(pe_id=i) for i in range(7)]
    stream = isa.config_stream(cfgs)
    assert len(stream) == 7 * isa.WORDS_PER_PE
    assert all(0 <= w < (1 << 32) for w in stream)
