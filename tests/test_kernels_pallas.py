"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
with hypothesis shape/dtype sweeps (per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core import kernels_lib as K
from repro.kernels import ref
from repro.kernels.fabric_stream import fabric_stream
from repro.kernels.flash_attention import flash_attention
from repro.kernels.stream_conv2d import stream_conv2d
from repro.kernels.stream_matmul import stream_matmul

rng = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# fabric_stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker,names", [
    (K.relu, ("x",)),
    (K.fft_butterfly, ("ar", "ai", "br", "bi")),
    (lambda: K.axpby(3, 5), ("x", "y")),
    (lambda: K.scale_add(7), ("x", "y")),
    (K.vadd, ("x", "y")),
])
def test_fabric_stream_matches_oracle(maker, names):
    g = maker()
    for n in (1, 127, 1024, 3000):
        ins = {k: jnp.asarray(rng.integers(-10000, 10000, n), jnp.int32)
               for k in names}
        got = fabric_stream(g, ins)
        want = ref.eval_dfg_elementwise(g, ins)
        for k in want:
            assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), block_rows=st.sampled_from([1, 2, 8]))
def test_property_fabric_stream_relu(n, block_rows):
    g = K.relu()
    x = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n, dtype=np.int64)
                    .astype(np.int32))
    got = fabric_stream(g, {"x": x}, block_rows=block_rows)["out"]
    assert np.array_equal(np.asarray(got), np.maximum(np.asarray(x), 0))


# ---------------------------------------------------------------------------
# stream_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_property_stream_matmul(m, k, n, dtype):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype=dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype=dtype)
    got = stream_matmul(a, b, bm=128, bn=128, bk=128)
    want = ref.matmul(a, b)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_stream_matmul_small_blocks():
    a = jnp.asarray(rng.standard_normal((70, 90)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((90, 50)), jnp.float32)
    got = stream_matmul(a, b, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# stream_conv2d
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(h=st.integers(3, 64), w=st.integers(3, 200),
       block_rows=st.sampled_from([1, 4, 8]))
def test_property_stream_conv2d(h, w, block_rows):
    img = jnp.asarray(rng.standard_normal((h, w)), jnp.float32)
    kern = jnp.asarray(rng.standard_normal((3, 3)), jnp.float32)
    got = stream_conv2d(img, kern, block_rows=block_rows)
    want = ref.conv2d_3x3(img, kern)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(h=st.integers(1, 4), sq=st.integers(1, 200), sk=st.integers(1, 200),
       d=st.sampled_from([16, 64, 80]), causal=st.booleans())
def test_property_flash_attention(h, sq, sk, d, causal):
    if causal and sq > sk:
        sq = sk          # causal with more queries than keys is undefined here
    q = jnp.asarray(rng.standard_normal((h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((h, sk, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_attention_long_kv_blocks():
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 1000, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 1000, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=128, bk=256)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
