"""Pipeline parallelism: staged shard_map execution must equal the serial
layer scan, forward and backward (autodiff through ppermute)."""
import os

import pytest

# this test needs >= 4 local devices; when the suite runs under the normal
# 1-device CPU env we spawn a subprocess with host_platform_device_count=4
_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from repro.launch.mesh import compat_make_mesh
from repro.runtime.pipeline import pipeline_forward

mesh = compat_make_mesh((4,), ("pod",))
rngk = jax.random.PRNGKey(0)
L, D, B = 8, 16, 12
params = {"w": jax.random.normal(rngk, (L, D, D)) * 0.3,
          "b": jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(2), (B, D))

def layer_fn(lp, h):
    return jnp.tanh(h @ lp["w"] + lp["b"])

def serial(params, x):
    def body(h, lp):
        return layer_fn(lp, h), None
    out, _ = lax.scan(body, x, params)
    return out

with mesh:
    piped = jax.jit(lambda p, x: pipeline_forward(layer_fn, p, x,
                                                  n_microbatches=6))(params, x)
ref = serial(params, x)
err = float(jnp.abs(piped - ref).max())
assert err < 1e-5, f"forward mismatch {err}"

# backward: grads through the pipeline must match serial grads
def loss_p(p, x):
    with mesh:
        return (pipeline_forward(layer_fn, p, x, 6) ** 2).mean()
def loss_s(p, x):
    return (serial(p, x) ** 2).mean()
with mesh:
    gp = jax.jit(jax.grad(loss_p))(params, x)
gs = jax.grad(loss_s)(params, x)
gerr = max(float(jnp.abs(gp[k] - gs[k]).max()) for k in gp)
assert gerr < 1e-5, f"grad mismatch {gerr}"
print("PIPELINE_OK", err, gerr)
"""


def test_pipeline_matches_serial(tmp_path):
    import subprocess
    import sys
    script = tmp_path / "pipe_check.py"
    script.write_text(_SRC)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout
