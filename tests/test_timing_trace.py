"""Timing/value decoupling tests (ISSUE 4).

Covers the three contracts the timing-trace cache rests on:

  1. **Replay equivalence** — for static-rate DFGs from the seeded
     conformance corpus, a ``TimingTrace`` recorded on one input set and
     replayed with a *different* input set's executor values must be
     bit-identical (cycles, steady II, arrivals, outputs) to a fresh
     ``STRELA_SIM=reference`` simulation of those inputs.
  2. **Recirculation bypass** — data-dependent loops have value-dependent
     timing; they must never record or consume traces.
  3. **Lane-parallel exactness** — ``simulate_lanes`` must equal N
     independent reference simulations, per lane.

Plus the ``SimResult.steady_ii`` guard for concatenated arrival streams
and the engine-level persist/replay round trip.
"""
import numpy as np
import pytest

from repro.core import kernels_lib as K
from repro.core.dfg import DFG
from repro.core.elastic_sim import SimResult, TimingTrace, simulate, \
    simulate_lanes
from repro.core.elastic_sim_ref import simulate_reference
from repro.core.executor import execute
from repro.core.fabric import Fabric
from repro.core.mapper import MappingError, map_dfg
from repro.core.multishot import ShotRunner
from repro.engine import ArtifactCache, Engine

from test_conformance import _mk_case


def _cmp(a, b, tag=""):
    assert a.cycles == b.cycles, (tag, a.cycles, b.cycles)
    assert a.steady_ii() == b.steady_ii(), tag
    assert a.arrival_cycles == b.arrival_cycles, tag
    assert a.fu_firings == b.fu_firings, tag
    assert a.bank_beats == b.bank_beats, tag
    assert set(a.outputs) == set(b.outputs), tag
    for k in a.outputs:
        assert a.outputs[k].tolist() == b.outputs[k].tolist(), (tag, k)


# ---------------------------------------------------------------------------
# 1. trace replay == fresh reference run, across the seeded corpus
# ---------------------------------------------------------------------------

def test_trace_replay_matches_reference_across_corpus():
    """Static-rate corpus graphs: record a trace on inputs A, replay it
    with executor values for inputs B, and demand bit-identity with a
    fresh STRELA_SIM=reference run on B."""
    checked = 0
    seed = 0
    while checked < 20 and seed < 230:
        length = (8, 16, 24)[seed % 3]
        g, inputs_a, _ = _mk_case(seed, length)
        seed += 1
        if not g.is_static_rate():
            continue
        try:
            m = map_dfg(g, restarts=60, seed=1)
        except MappingError:
            continue
        rng = np.random.default_rng(seed * 31 + 7)
        inputs_b = {k: rng.integers(-90, 90, length).astype(np.int32)
                    for k in inputs_a}
        try:
            sim_a = simulate(m, inputs_a)
        except RuntimeError:
            continue
        trace = TimingTrace.from_sim(sim_a, length, (), 4)
        replayed = trace.replay(execute(g, inputs_b))
        fresh = simulate_reference(m, inputs_b)
        _cmp(replayed, fresh, f"seed {seed - 1} ({g.name})")
        assert replayed.replayed and not fresh.replayed
        checked += 1
    assert checked >= 10, f"only {checked} static-rate corpus cases checked"


def test_trace_replay_matches_reference_on_paper_kernels():
    rng = np.random.default_rng(3)
    for g in (K.relu(), K.vadd(), K.fft_butterfly(), K.dither(),
              K.mac1(64)):
        m = map_dfg(g, restarts=300)
        a = {k: rng.integers(-64, 64, 64).astype(np.int32)
             for k in g.inputs}
        b = {k: rng.integers(-64, 64, 64).astype(np.int32)
             for k in g.inputs}
        assert g.is_static_rate()
        trace = TimingTrace.from_sim(simulate(m, a), 64, (), 4)
        _cmp(trace.replay(execute(g, b)), simulate_reference(m, b), g.name)


# ---------------------------------------------------------------------------
# 2. recirculation bypasses the trace cache
# ---------------------------------------------------------------------------

def test_recirculation_is_not_static_rate():
    assert not K.div_loop(7).is_static_rate()
    assert K.dither().is_static_rate()          # loop-carried but static
    assert K.fft_butterfly().is_static_rate()
    assert K.find2min().is_static_rate()        # mux form: static schedule
    assert not K.find2min_brmg().is_static_rate()   # Branch/Merge steering


def test_recirculation_bypasses_trace_cache():
    g = K.div_loop(7)
    rng = np.random.default_rng(0)
    runner = ShotRunner(fabric=Fabric())
    ins = {k: rng.integers(0, 100, 32).astype(np.int32) for k in g.inputs}
    # even a maliciously seeded trace must be ignored for recirc graphs
    m = map_dfg(g, restarts=300)
    bogus = TimingTrace(32, (), 4, cycles=1,
                        arrival_cycles={o: [] for o in g.outputs},
                        fu_firings={}, bank_beats=0)
    runner.seed_trace("div7", 32, (), bogus)
    runner.seed_mapping("div7", m)
    runner.run_shot("div7", g, ins, streams_changed=3)
    (sim,) = runner.rep_sims().values()
    assert not sim.replayed, "recirculation shot replayed a timing trace"
    assert sim.cycles > 1
    assert not runner.fresh_traces(), "recirc shot must not record traces"


def test_engine_does_not_persist_traces_for_recirc():
    eng = Engine(fabric=Fabric(), backend="sim",
                 cache=ArtifactCache(memory_only=True))
    g = K.div_loop(7)
    art = eng.compile(g)
    rng = np.random.default_rng(0)
    ins = {k: rng.integers(0, 100, 32).astype(np.int32) for k in g.inputs}
    eng.run(art, ins)
    assert art.timing_traces == {}


# ---------------------------------------------------------------------------
# engine round trip: record once, replay from the persistent cache
# ---------------------------------------------------------------------------

def test_engine_trace_persist_and_replay(tmp_path, monkeypatch):
    root = str(tmp_path / "arts")
    g = K.fft_butterfly()
    rng = np.random.default_rng(1)
    ins = {k: rng.integers(-64, 64, 48).astype(np.int32) for k in g.inputs}

    e1 = Engine(fabric=Fabric(), backend="sim",
                cache=ArtifactCache(root=root))
    a1 = e1.compile(g)
    r1 = e1.run(a1, dict(ins))
    assert a1.timing_traces, "static-rate run must record a trace"

    # a new engine + cache instance (same disk root) must replay: the
    # cycle simulator is forbidden via monkeypatch
    import repro.core.multishot as MS

    def boom(*a, **k):
        raise AssertionError("simulate() called despite cached trace")

    monkeypatch.setattr(MS, "simulate", boom)
    e2 = Engine(fabric=Fabric(), backend="sim",
                cache=ArtifactCache(root=root))
    a2 = e2.compile(g)
    assert a2.timing_traces.keys() == a1.timing_traces.keys()
    rng2 = np.random.default_rng(2)
    ins2 = {k: rng2.integers(-64, 64, 48).astype(np.int32)
            for k in g.inputs}
    r2 = e2.run(a2, dict(ins2))
    assert e2.tally.exec == e1.tally.exec       # identical cycle accounting
    assert set(r2) == set(r1)
    # values must come from the functional executor, not the trace
    expect = execute(g, ins2)
    for k in r2:
        assert r2[k].tolist() == expect[k].tolist()


def test_trace_key_includes_length(tmp_path):
    """A trace recorded at one length must not serve another."""
    root = str(tmp_path / "arts")
    g = K.vadd()
    eng = Engine(fabric=Fabric(), backend="sim",
                 cache=ArtifactCache(root=root))
    art = eng.compile(g)
    rng = np.random.default_rng(0)
    for length in (16, 32):
        ins = {k: rng.integers(-64, 64, length).astype(np.int32)
               for k in g.inputs}
        eng.run(art, ins)
    lengths = {key[1] for key in art.timing_traces}
    assert lengths == {16, 32}


# ---------------------------------------------------------------------------
# 3. lane-parallel mode is bit-exact per lane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("factory,lo,hi", [
    (lambda: K.fft_butterfly(), -64, 64),
    (lambda: K.div_loop(5), 0, 100),
    (lambda: K.dither(), 0, 256),
])
def test_lane_parallel_bit_exact(factory, lo, hi):
    g = factory()
    m = map_dfg(g, restarts=300)
    rng = np.random.default_rng(9)
    batch = [{k: rng.integers(lo, hi, 24).astype(np.int32)
              for k in g.inputs} for _ in range(4)]
    lanes = simulate_lanes(m, batch)
    singles = [simulate_reference(m, ins) for ins in batch]
    for i, (lane, single) in enumerate(zip(lanes, singles)):
        _cmp(lane, single, f"{g.name} lane {i}")


# ---------------------------------------------------------------------------
# steady_ii guard for concatenated arrival streams
# ---------------------------------------------------------------------------

def test_steady_ii_ignores_cross_request_boundaries():
    # two concatenated requests: the cycle counter resets at the boundary
    res = SimResult(cycles=20,
                    outputs={"o": np.zeros(6, dtype=np.int32)},
                    arrival_cycles={"o": [10, 12, 14, 3, 5, 7]},
                    fu_firings={}, bank_beats=0)
    assert res.steady_ii() == 2.0
    # degenerate concat of single-arrival requests: no real gaps at all
    res1 = SimResult(cycles=20,
                     outputs={"o": np.zeros(3, dtype=np.int32)},
                     arrival_cycles={"o": [5, 5, 5]},
                     fu_firings={}, bank_beats=0)
    assert res1.steady_ii() == float("inf")
    # strictly decreasing (pure boundary): previously returned a negative II
    res2 = SimResult(cycles=20,
                     outputs={"o": np.zeros(2, dtype=np.int32)},
                     arrival_cycles={"o": [5, 3]},
                     fu_firings={}, bank_beats=0)
    assert res2.steady_ii() == float("inf")
    # monotone arrivals unchanged
    res3 = SimResult(cycles=20,
                     outputs={"o": np.zeros(4, dtype=np.int32)},
                     arrival_cycles={"o": [2, 4, 6, 8]},
                     fu_firings={}, bank_beats=0)
    assert res3.steady_ii() == 2.0


# ---------------------------------------------------------------------------
# STRELA_SIM switch
# ---------------------------------------------------------------------------

def test_strela_sim_env_selects_reference(monkeypatch):
    g = K.relu()
    m = map_dfg(g, restarts=300)
    rng = np.random.default_rng(4)
    ins = {k: rng.integers(-64, 64, 16).astype(np.int32) for k in g.inputs}
    fast = simulate(m, ins)
    monkeypatch.setenv("STRELA_SIM", "reference")
    ref = simulate(m, ins)
    monkeypatch.delenv("STRELA_SIM")
    _cmp(fast, ref, "env switch")
