"""Optimizing place & route (core/opt_mapper.py): contract tests.

The annealer's contract is *strict refinement* of the greedy mapper —
value-bit-exact, never cycle-worse, measurably cheaper where it adopts a
candidate — plus full determinism under a pinned seed. The 40-case corpus
slice lives in ``benchmarks/mapper_gate.py`` (CI); these tests pin the
contract on the paper kernels and on the stack integration points
(``map_dfg(optimize=)``, ``STRELA_MAPPER``, ``partition.plan``,
``Engine``).
"""
import numpy as np
import pytest

from repro.core import kernels_lib as K
from repro.core.elastic_sim import simulate
from repro.core.fabric import Fabric
from repro.core.mapper import default_mapper, map_dfg
from repro.core.opt_mapper import anneal_map, probe_inputs
from repro.core.paper_mappings import paper_mapping

# the bench-pinned deterministic improvement case: at seed 0 the annealer
# compacts conv2d_row from 12 to 9 active PEs (config 64 -> 49)
_IMPROVE_MOVES = 480


def _sims(m, probes):
    return [simulate(m, dict(p)) for p in probes]


def test_anneal_improves_conv2d_row_config_footprint():
    g = K.conv2d_row(1, 2, 1)
    greedy = map_dfg(g, seed=0, optimize="greedy")
    ann = anneal_map(g, seed=0, baseline=greedy, moves=_IMPROVE_MOVES)
    assert ann.config_cycles() < greedy.config_cycles()
    assert ann.n_active_pes() < greedy.n_active_pes()
    probes = probe_inputs(g, 0)
    for gs, as_ in zip(_sims(greedy, probes), _sims(ann, probes)):
        assert as_.cycles <= gs.cycles
        for o in g.outputs:
            assert np.array_equal(as_.outputs[o], gs.outputs[o])


@pytest.mark.parametrize("factory,moves", [
    (lambda: K.mac2x(24), 64),
    (lambda: K.axpby(3, 5), 64),
    (lambda: K.dither(), 64),          # loop-carried state: II must hold
])
def test_anneal_never_worse_and_value_exact(factory, moves):
    """The contract holds at ANY move budget — tiny searches included:
    an inadmissible candidate must fall back to the greedy baseline."""
    g = factory()
    greedy = map_dfg(g, seed=0, optimize="greedy")
    ann = anneal_map(g, seed=0, baseline=greedy, moves=moves)
    assert ann.config_cycles() <= greedy.config_cycles()
    probes = probe_inputs(g, 0)
    for gs, as_ in zip(_sims(greedy, probes), _sims(ann, probes)):
        assert as_.cycles <= gs.cycles
        for o in g.outputs:
            assert np.array_equal(as_.outputs[o], gs.outputs[o])


def test_anneal_deterministic_per_seed():
    g = K.conv2d_row(1, 2, 1)
    a = anneal_map(g, seed=3, moves=128)
    b = anneal_map(g, seed=3, moves=128)
    assert a.digest() == b.digest()


def test_extra_probes_participate_in_validation():
    """A caller-supplied workload must ride along as a validation probe:
    the annealed mapping reproduces greedy's outputs on it bit-exact."""
    g = K.conv2d_row(1, 2, 1)
    rng = np.random.default_rng(42)
    work = {n: rng.integers(-64, 64, 96).astype(np.int32)
            for n in g.inputs}
    greedy = map_dfg(g, seed=0, optimize="greedy")
    ann = anneal_map(g, seed=0, baseline=greedy, moves=_IMPROVE_MOVES,
                     extra_probes=[dict(work)])
    gs, as_ = simulate(greedy, dict(work)), simulate(ann, dict(work))
    assert as_.cycles <= gs.cycles
    for o in g.outputs:
        assert np.array_equal(as_.outputs[o], gs.outputs[o])


# ---------------------------------------------------------------------------
# stack integration: env selection, hints, partition, engine
# ---------------------------------------------------------------------------

def test_strela_mapper_env_selects_anneal(monkeypatch):
    monkeypatch.setenv("STRELA_MAPPER", "anneal")
    assert default_mapper() == "anneal"
    m = map_dfg(K.axpby(3, 5), seed=0)          # resolves from the env
    greedy = map_dfg(K.axpby(3, 5), seed=0, optimize="greedy")
    assert m.config_cycles() <= greedy.config_cycles()


def test_strela_mapper_env_rejects_unknown(monkeypatch):
    monkeypatch.setenv("STRELA_MAPPER", "quantum")
    with pytest.raises(ValueError, match="quantum"):
        default_mapper()
    with pytest.raises(ValueError, match="quantum"):
        map_dfg(K.vadd())


def test_hinted_paper_mappings_never_annealed(monkeypatch):
    """Placement-hinted mappings (the pinned paper figures) bypass the
    optimizer: their golden config/cycle pins must survive any env."""
    monkeypatch.setenv("STRELA_MAPPER", "anneal")
    m = paper_mapping("fft")
    assert m.n_active_pes() == 16 and m.config_cycles() == 84


def test_partition_plan_anneals_final_mappings():
    from repro.frontend import partition
    g = K.conv2d_row(1, 2, 1)
    pg = partition.plan(g, mapper="greedy", seed=0)
    pa = partition.plan(g, mapper="anneal", seed=0)
    assert pg.n_shots == pa.n_shots == 1
    assert pa.shots[0].mapping.config_cycles() <= \
        pg.shots[0].mapping.config_cycles()


def test_engine_mapper_threads_to_artifact():
    from repro.engine import ArtifactCache, Engine
    g = K.axpby(3, 5)
    cache = ArtifactCache(memory_only=True)
    ga = Engine(cache=cache, mapper="greedy").compile(g)
    aa = Engine(cache=cache, mapper="anneal").compile(g)
    assert ga.mapper == "greedy" and aa.mapper == "anneal"
    # one shared cache, two mapper identities: the keys must not alias
    assert ga.key != aa.key
    rng = np.random.default_rng(5)
    ins = {n: rng.integers(-64, 64, 32).astype(np.int32) for n in g.inputs}
    eng = Engine(cache=cache)
    want = eng.run(ga, dict(ins))
    got = eng.run(aa, dict(ins))
    for o in want:
        assert np.array_equal(got[o], want[o])


def test_anneal_on_bigger_fabric_geometry():
    """The optimizer is geometry-generic (the ISSUE's 4x4-8x8 envelope)."""
    fab = Fabric(rows=6, cols=6, n_imns=6, n_omns=6)
    g = K.conv2d_row(1, 2, 1)
    greedy = map_dfg(g, fab, seed=0, optimize="greedy")
    ann = anneal_map(g, fab, seed=0, baseline=greedy, moves=96)
    assert ann.config_cycles() <= greedy.config_cycles()
    probes = probe_inputs(g, 0)
    for gs, as_ in zip(_sims(greedy, probes), _sims(ann, probes)):
        assert as_.cycles <= gs.cycles
        for o in g.outputs:
            assert np.array_equal(as_.outputs[o], gs.outputs[o])
