"""DFG IR + functional-executor tests, including hypothesis properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core import dfg as D
from repro.core import kernels_lib as K
from repro.core.executor import execute, wrap32
from repro.core.isa import AluOp, CmpOp

rng = np.random.default_rng(0)


def test_validation_catches_missing_operands():
    b = D.DFG.build("bad")
    x = b.inp("x")
    b._add(D.Node("m", D.MUX))          # no operands at all
    b.out("out", "m")
    with pytest.raises(ValueError):
        b.done()


def test_validation_catches_cycles():
    b = D.DFG.build("cyc")
    x = b.inp("x")
    a = b.alu("a", AluOp.ADD, x, None)
    c = b.alu("c", AluOp.ADD, a)
    b.edge(c, a, "b")                   # forward cycle (not a back edge)
    b.out("out", c)
    with pytest.raises(ValueError):
        b.done()


def test_relu_semantics():
    g = K.relu()
    x = rng.integers(-(1 << 20), 1 << 20, 500).astype(np.int32)
    out = execute(g, {"x": x})["out"]
    assert np.array_equal(out, np.maximum(x, 0))


def test_fft_butterfly_semantics():
    wr, wi = 23170, -23170
    g = K.fft_butterfly(wr, wi)
    ins = {k: rng.integers(-(1 << 12), 1 << 12, 128).astype(np.int32)
           for k in ("ar", "ai", "br", "bi")}
    out = execute(g, ins)
    ar, ai = ins["ar"].astype(np.int64), ins["ai"].astype(np.int64)
    br, bi = ins["br"].astype(np.int64), ins["bi"].astype(np.int64)
    tr = br * wr - bi * wi
    ti = br * wi + bi * wr
    assert np.array_equal(out["out_or0"], wrap32(ar + tr))
    assert np.array_equal(out["out_oi1"], wrap32(ai - ti))


def test_dither_error_diffusion():
    g = K.dither()
    x = rng.integers(0, 256, 300).astype(np.int32)
    out = execute(g, {"x": x})["out"]
    # reference Floyd-Steinberg-style 1-D diffusion
    err, exp = 0, []
    for px in x:
        v = int(px) + err
        o = 255 if v > 127 else 0
        exp.append(o)
        err = v - o
    assert np.array_equal(out, np.array(exp, np.int32))


def test_find2min_variants_agree():
    x = rng.integers(0, 1 << 16, 777).astype(np.int32)
    o1 = execute(K.find2min(), {"x": x})
    o2 = execute(K.find2min_brmg(), {"x": x})
    srt = np.sort(x)
    assert o1["out_m1"][0] == srt[0] and o1["out_m2"][0] == srt[1]
    assert o2["out_m1"][0] == srt[0] and o2["out_m2"][0] == srt[1]
    # indices from the mux variant
    assert x[o1["out_i1"][0]] == srt[0]


def test_mac3_segmented_reduction():
    g = K.mac3(8)
    a = rng.integers(-100, 100, 32).astype(np.int32)
    bs = {f"b{k}": rng.integers(-100, 100, 32).astype(np.int32)
          for k in range(3)}
    out = execute(g, {"a": a, **bs})
    for k in range(3):
        seg = (a.astype(np.int64) * bs[f"b{k}"].astype(np.int64)
               ).reshape(4, 8).sum(1)
        assert np.array_equal(out[f"out{k}"], wrap32(seg))


def test_unroll_independent_lanes():
    g = D.unroll(K.relu(), 3)
    assert len(g.inputs) == 3 and len(g.outputs) == 3
    x = rng.integers(-50, 50, 30).astype(np.int32)
    out = execute(g, {"x@0": x[0::3], "x@1": x[1::3], "x@2": x[2::3]})
    merged = np.empty(30, np.int32)
    for k in range(3):
        merged[k::3] = out[f"out@{k}"]
    assert np.array_equal(merged, np.maximum(x, 0))


def test_unroll_chained_matches_serial():
    g2 = D.unroll_chained(K.dither(), 2)
    x = rng.integers(0, 256, 400).astype(np.int32)
    out = execute(g2, {"x@0": x[0::2], "x@1": x[1::2]})
    ref = execute(K.dither(), {"x": x})["out"]
    merged = np.empty(400, np.int32)
    merged[0::2] = out["out@0"]
    merged[1::2] = out["out@1"]
    assert np.array_equal(merged, ref)


def test_int32_wraparound():
    b = D.DFG.build("wrap")
    x = b.inp("x")
    m = b.alu("m", AluOp.MUL, x, x)
    b.out("out", m)
    g = b.done()
    x = np.array([1 << 20, -(1 << 20)], np.int32)
    out = execute(g, {"x": x})["out"]
    assert np.array_equal(out, wrap32(x.astype(np.int64) ** 2))


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

_ALU_OPS = [AluOp.ADD, AluOp.SUB, AluOp.MUL, AluOp.AND, AluOp.OR, AluOp.XOR]


def _random_elementwise_dfg(draw):
    n_in = draw(st.integers(1, 3))
    n_ops = draw(st.integers(1, 6))
    b = D.DFG.build("rand")
    avail = [b.inp(f"x{i}") for i in range(n_in)]
    for i in range(n_ops):
        op = draw(st.sampled_from(_ALU_OPS))
        a = draw(st.sampled_from(avail))
        use_const = draw(st.booleans())
        if use_const:
            node = b.alu(f"n{i}", op, a,
                         const_b=draw(st.integers(-1000, 1000)))
        else:
            node = b.alu(f"n{i}", op, a, draw(st.sampled_from(avail)))
        avail.append(node)
    b.out("out", avail[-1])
    return b.done()


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_property_vectorized_equals_loop(data):
    """The vectorized executor path must equal the token-by-token path."""
    from repro.core import executor as E
    g = _random_elementwise_dfg(data.draw)
    n = data.draw(st.integers(1, 40))
    ins = {name: np.array(data.draw(
        st.lists(st.integers(-2**31, 2**31 - 1), min_size=n, max_size=n)),
        dtype=np.int64).astype(np.int32) for name in g.inputs}
    vec = E._execute_vectorized(g, {k: v.astype(np.int32) for k, v in ins.items()}, n)
    loop = E._execute_loop(g, {k: np.asarray(v, np.int64) for k, v in ins.items()}, n)
    for k in g.outputs:
        assert np.array_equal(vec[k], loop[k]), k


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_property_wrap32_matches_c_semantics(a, b):
    ai, bi = a - 2**31, b - 2**31
    got = int(wrap32(np.int64(ai) + np.int64(bi)))
    exp = ((ai + bi + 2**31) % 2**32) - 2**31   # two's-complement wrap
    assert got == exp
