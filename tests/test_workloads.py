"""repro.workloads tests (ISSUE 10): the model-layer workload bridge.

  * **registry contract** — >= 6 model-layer classes spanning the
    transformer / attention / SSM / MoE layers, at least one demand-gated
    loop (data-dependent trip count via recirculation) and one multi-shot
    plan; every registered input-stream name matches the traced DFG;
  * **differential gate** — every WorkloadClass is bit-exact against its
    independent ``jnp`` oracle across seeded inputs on every
    capability-eligible backend (sim always; pallas unless the class's
    registered ``pallas_skip`` names why not), plus a hypothesis property
    over (class, length, seed);
  * **capability coverage** — each class's expected pallas
    ``backend_skip_reason`` is asserted by *name* (a known capability
    feature, never a crash), at both recipe (pre-compile) and artifact
    (post-compile plan) level — satellite 4;
  * **one source of truth** — ``serve_classes``/``model_classes`` drop
    backend-ineligible classes with those same named reasons, so backends
    can never silently disagree about a mix — satellite 3 lock;
  * **float semantics** — the fixed-point kernels stay within each
    class's stated tolerance of the float layer op they quantize;
  * **soak** — the model mix served end-to-end through ServeEngine and a
    2-fabric FleetEngine under the virtual clock: accounting holds,
    preemption hits a multi-shot model class, every served response
    re-verifies against its oracle, and digests replay bit-identically
    in-process and across OS processes — satellite 2.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.engine import ArtifactCache, Engine
from repro.engine.capabilities import FEATURE_DESC
from repro.serve import (artifact_skip_reason, compile_recipe,
                         model_classes, recipe_skip_reason, serve_classes)
from repro.workloads import (MODEL_CLASSES, MODEL_MIX, model_recipes,
                             model_weights, workload_input_gen)

LENGTH = 32
SEEDS = (0, 1, 2)

# One shared in-memory artifact cache for the whole module: place & route
# runs once per (class, geometry, backend) no matter how many tests touch
# the class. Replay tests that must prove cold-start determinism build
# their own engines/caches explicitly.
_CACHE = ArtifactCache(memory_only=True)
_ARTS = {}


def _engine(backend="sim"):
    return Engine(backend=backend, cache=_CACHE)


def _artifact(label, backend="sim", length=LENGTH):
    key = (label, backend, length)
    if key not in _ARTS:
        _ARTS[key] = compile_recipe(_engine(backend), label, length,
                                    model_recipes(length))
    return _ARTS[key]


def _assert_oracle_exact(label, backend, seed, length=LENGTH):
    wc = MODEL_CLASSES[label]
    eng = _engine(backend)
    art = _artifact(label, backend, length)
    rng = np.random.default_rng(seed)
    ins = wc.gen_inputs(length, rng)
    out = eng.run(art, ins)
    want = wc.oracle(**ins)
    assert len(out) == len(want), (label, sorted(out), len(want))
    for i, w in enumerate(want):
        got = np.ravel(np.asarray(out[f"out{i}"]))
        np.testing.assert_array_equal(
            got, np.ravel(np.asarray(w)),
            err_msg=f"{label}/{backend} seed={seed} out{i} diverged "
                    f"from jnp oracle")


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_registry_covers_model_layers():
    assert len(MODEL_CLASSES) >= 6
    layers = {wc.layer for wc in MODEL_CLASSES.values()}
    assert {"transformer", "attention", "ssm", "moe"} <= layers
    assert MODEL_MIX == tuple(sorted(MODEL_CLASSES))
    for label, wc in MODEL_CLASSES.items():
        assert wc.label == label
        assert wc.weight > 0
        assert wc.description and wc.exactness
    assert set(model_weights()) == set(MODEL_CLASSES)


def test_mix_has_demand_gated_loop_and_multishot():
    """The realism floor: at least one data-dependent-trip-count loop and
    one multi-shot (preemptible) plan in the served mix."""
    arts = {l: _artifact(l) for l in MODEL_CLASSES}
    assert any(a.dfg.has_recirculation() for a in arts.values())
    assert any(a.n_shots > 1 for a in arts.values())
    assert arts["ssm_relax"].dfg.has_recirculation()
    assert arts["swiglu_ms"].n_shots > 1


@pytest.mark.parametrize("label", sorted(MODEL_CLASSES))
def test_traced_inputs_match_registered_generator(label):
    """The registry's input ranges feed the exact stream names the traced
    DFG consumes, in the same declaration order (rng-replay contract)."""
    wc = MODEL_CLASSES[label]
    art = _artifact(label)
    assert list(art.dfg.inputs) == list(wc.inputs)
    gen = workload_input_gen(label)
    assert gen is not None
    a = gen(LENGTH, np.random.default_rng(3))
    b = wc.gen_inputs(LENGTH, np.random.default_rng(3))
    for name, (lo, hi) in wc.inputs.items():
        np.testing.assert_array_equal(a[name], b[name])
        assert a[name].dtype == np.int32
        assert a[name].min() >= lo and a[name].max() < hi
    assert workload_input_gen("relu") is None


# ---------------------------------------------------------------------------
# differential conformance gate (satellite 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label", sorted(MODEL_CLASSES))
def test_oracle_exact_on_sim(label):
    for seed in SEEDS:
        _assert_oracle_exact(label, "sim", seed)


@pytest.mark.parametrize("label", sorted(MODEL_CLASSES))
def test_oracle_exact_on_pallas(label):
    wc = MODEL_CLASSES[label]
    if wc.pallas_skip is not None:
        pytest.skip(f"pallas cannot lower {label}: {wc.pallas_skip}")
    for seed in SEEDS[:2]:
        _assert_oracle_exact(label, "pallas", seed)


@settings(deadline=None, max_examples=15)
@given(st.sampled_from(sorted(MODEL_CLASSES)),
       st.sampled_from([16, 32, 64]),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_property_oracle_equivalence(label, length, seed):
    """Property sweep: oracle equivalence is not an artifact of one
    length or a lucky seed."""
    _assert_oracle_exact(label, "sim", seed, length=length)


@pytest.mark.parametrize("label", sorted(MODEL_CLASSES))
def test_float_semantics_within_stated_tolerance(label):
    """Each fixed-point kernel tracks the float layer op it quantizes
    within the tolerance its ``exactness`` string states."""
    wc = MODEL_CLASSES[label]
    assert wc.float_ref is not None
    eng = _engine()
    art = _artifact(label)
    for seed in SEEDS:
        ins = wc.gen_inputs(LENGTH, np.random.default_rng(seed))
        out = eng.run(art, ins)
        # float_ref takes outputs by position (the oracle-tuple order)
        outs = [np.ravel(np.asarray(out[f"out{i}"]))
                for i in range(len(out))]
        got, want, atol = wc.float_ref(ins, outs)
        err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
        assert err <= atol, (f"{label} seed={seed}: float deviation "
                             f"{err:.4f} > stated atol {atol}")


# ---------------------------------------------------------------------------
# capability coverage (satellite 4) + one source of truth (satellite 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label", sorted(MODEL_CLASSES))
def test_expected_pallas_capability(label):
    """Every class declares its pallas fate up front: either it runs
    there (skip is None — enforced by the differential gate above) or
    the skip reason is a '+'-join of *named* capability features, agreed
    on by the recipe-level probe and the compiled artifact."""
    wc = MODEL_CLASSES[label]
    recipes = model_recipes(LENGTH)
    reason = recipe_skip_reason(label, LENGTH, "pallas", recipes)
    assert reason == wc.pallas_skip
    assert artifact_skip_reason(_artifact(label), LENGTH,
                                "pallas") == wc.pallas_skip
    assert recipe_skip_reason(label, LENGTH, "sim", recipes) is None
    if reason is not None:
        for feature in reason.split("+"):
            assert feature in FEATURE_DESC, (
                f"{label}: skip reason component {feature!r} is not a "
                f"named capability feature")


def test_serve_classes_single_source_of_truth():
    """Satellite 3: backend eligibility is derived from capabilities in
    one place — ``serve_classes`` drops what a backend can't lower with
    the registered named reason, identically for paper and model mixes
    (no hand-maintained per-backend class lists anywhere)."""
    expect_skip = {l: wc.pallas_skip for l, wc in MODEL_CLASSES.items()
                   if wc.pallas_skip is not None}
    skipped = {}
    served = model_classes(_engine("pallas"), LENGTH, skipped=skipped)
    assert skipped == expect_skip
    assert set(served) == set(MODEL_CLASSES) - set(expect_skip)

    assert set(model_classes(_engine(), LENGTH)) == set(MODEL_CLASSES)

    skipped = {}
    paper = serve_classes(_engine("pallas"), LENGTH, skipped=skipped)
    assert "div_loop" in skipped and "div_loop" not in paper
    for feature in skipped["div_loop"].split("+"):
        assert feature in FEATURE_DESC


# ---------------------------------------------------------------------------
# serve / fleet soak over the model mix (satellite 2)
# ---------------------------------------------------------------------------

def _model_soak(seed=0, n=150):
    from benchmarks.bench_serve import soak
    return soak(seed=seed, n_requests=n, length=LENGTH, backend="sim",
                rate_per_us=0.4, mix="model")


def test_model_mix_serve_soak():
    sv, rep = _model_soak()
    assert rep["offered"] == 150
    assert rep["offered"] == (rep["served"] + rep["rejected"] +
                              rep["failed"])
    assert rep["failed"] == 0
    # every model class reached the fabric
    assert len({tk.cls for tk in sv.served}) == len(MODEL_CLASSES)
    # preemption was exercised by a multi-shot model class
    assert rep["preemptions"] >= 1
    assert any(tk.artifact.n_shots > 1 for tk in sv.served)
    # every served response re-verified against its jnp oracle
    assert rep["oracle_mismatches"] == 0
    assert rep["oracle_checked"] == rep["served"]
    # the fixed seed replays bit-identically in-process
    sv2, rep2 = _model_soak()
    assert rep["trace_digest"] == rep2["trace_digest"]
    assert rep["results_digest"] == rep2["results_digest"]


def _model_fleet(seed=11, n=60):
    from repro.fleet import fleet_soak, homogeneous
    cfg = homogeneous(2, n_requests=n, rate_per_us=0.3, length=LENGTH,
                      classes=MODEL_MIX,
                      weights=tuple(sorted(model_weights().items())))
    return fleet_soak(seed, cfg, cache=ArtifactCache(memory_only=True))


def test_model_mix_fleet_soak_two_fabrics():
    fleet, rep = _model_fleet()
    assert rep["offered"] == 60
    assert rep["offered"] == (rep["served"] + rep["rejected"] +
                              rep["failed"] + len(fleet.unroutable))
    assert rep["failed"] == 0 and rep["unroutable"] == 0
    # both fabrics took pins (class-affinity spread the model mix)
    assert set(rep["placements"]) == set(MODEL_MIX)
    assert len(set(rep["placements"].values())) == 2
    # fleet-wide differential verification: every served response on
    # every fabric matches its class's jnp oracle bit-exactly
    names = {a.name: l
             for l, a in model_classes(_engine(), LENGTH).items()}
    checked = 0
    for w in fleet.workers:
        for tk in w.serve.served:
            wc = MODEL_CLASSES[names[tk.artifact.name]]
            want = wc.oracle(**tk.inputs)
            for i, wv in enumerate(want):
                np.testing.assert_array_equal(
                    np.ravel(np.asarray(tk.outputs[f"out{i}"])),
                    np.ravel(np.asarray(wv)),
                    err_msg=f"fleet {w.name}/{tk.cls} rid={tk.rid}")
            checked += 1
    assert checked == rep["served"]
    # bit-identical replay from a cold cache
    fleet2, rep2 = _model_fleet()
    assert rep["trace_digest"] == rep2["trace_digest"]
    assert fleet.results_digest() == fleet2.results_digest()


def test_model_soak_replays_across_processes():
    """Same seed -> same serve and fleet digests in a separate OS
    process: the model-layer classes keep the PR 8/9 replay contract."""
    prog = (
        "from benchmarks.bench_serve import soak; "
        "from repro.engine import ArtifactCache; "
        "from repro.fleet import fleet_soak, homogeneous; "
        "from repro.workloads import MODEL_MIX, model_weights; "
        "sv, rep = soak(seed=9, n_requests=40, length=32, backend='sim', "
        "rate_per_us=0.4, mix='model'); "
        "cfg = homogeneous(2, n_requests=30, rate_per_us=0.3, length=32, "
        "classes=MODEL_MIX, "
        "weights=tuple(sorted(model_weights().items()))); "
        "fl, frep = fleet_soak(9, cfg, "
        "cache=ArtifactCache(memory_only=True)); "
        "assert rep['oracle_mismatches'] == 0, rep; "
        "print(rep['trace_digest'], rep['results_digest'], "
        "frep['trace_digest'], fl.results_digest())")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(root, "src"), root]),
               STRELA_CACHE="0")
    digests = set()
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", prog], cwd=root,
                             env=env, capture_output=True, text=True,
                             check=True)
        digests.add(out.stdout.strip())
    assert len(digests) == 1, f"cross-process replay diverged: {digests}"
