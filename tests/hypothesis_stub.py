"""Fallback shims so property-based tests degrade to skips when
``hypothesis`` is not installed (it is an optional dev dependency — see
requirements.txt). Import sites do::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import given, settings, st

keeping every non-property test in the module collectable and runnable.
"""
import pytest


class _StubStrategies:
    """Stands in for ``hypothesis.strategies``: any strategy constructor
    (``st.integers(...)``, ``st.composite``, ...) returns an inert callable,
    which is enough for module-level decorator evaluation; the decorated
    tests themselves are skipped by the ``given`` stub below."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: (lambda *a, **k: None)


st = _StubStrategies()


def given(*args, **kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*args, **kwargs):
    return lambda fn: fn
