"""Pallas backend parity (ISSUE 5): reductions, multi-shot plans, and
lane-batched dispatch run on the fused Pallas substrate (interpret mode)
bit-exact against the sim backend — and every kernel outside the declared
capability set is rejected with a diagnostic *naming* the offending
feature, mirroring the frontend's named-equation errors."""
import numpy as np
import pytest

from repro.core import dfg as D
from repro.core import kernels_lib as K
from repro.core.executor import execute
from repro.core.isa import AluOp, CmpOp
from repro.engine import ArtifactCache, CapabilityError, Engine, dfg_features

rng = np.random.default_rng(7)


def _mem_engine(backend):
    return Engine(backend=backend, cache=ArtifactCache(memory_only=True))


def _inputs(g, length):
    return {name: rng.integers(-60, 60, length).astype(np.int32)
            for name in g.inputs}


# ---------------------------------------------------------------------------
# acceptance: every pallas-capable kernels_lib kernel, bit-exact vs sim
# ---------------------------------------------------------------------------

# every single-shot kernel in kernels_lib inside the pallas capability set:
# the elementwise/conditional one-shots plus every reduction kernel
PALLAS_KERNELS = {
    "fft": lambda n: K.fft_butterfly(),
    "relu": lambda n: K.relu(),
    "mac1": K.mac1,
    "mac3": K.mac3,
    "mac2x": K.mac2x,
    "axpby": lambda n: K.axpby(3, 5),
    "scale": lambda n: K.scale(7),
    "scale_add": lambda n: K.scale_add(4),
    "vadd": lambda n: K.vadd(),
    "conv2d_row3": lambda n: K.conv2d_row3(1, -2, 3),
    "conv2d_row": lambda n: K.conv2d_row(1, -2, 3),
    "outer_row": lambda n: K.outer_row(2, -3),
    "outer_row2": lambda n: K.outer_row2(2, -3, 5, 1),
}


@pytest.mark.parametrize("name", sorted(PALLAS_KERNELS))
def test_kernels_lib_pallas_matches_sim(name):
    length = 16
    g = PALLAS_KERNELS[name](length)
    ins = _inputs(g, length)
    ep, es = _mem_engine("pallas"), _mem_engine("sim")
    got = ep.run(ep.compile(g), dict(ins))
    want = es.run(es.compile(g), dict(ins))
    assert set(got) == set(want)
    for o in want:
        np.testing.assert_array_equal(got[o], want[o], err_msg=o)
    # cycle accounting is backend-independent (timing/value decoupling)
    assert ep.tally.total == es.tally.total


def test_multi_shot_plan_runs_on_pallas():
    """A partitioned (pe_limit-forced) multi-shot plan chains per-shot
    pallas kernels through the IMN/OMN buffer handoff, bit-exact."""
    ep, es = _mem_engine("pallas"), _mem_engine("sim")
    ap = ep.compile(K.axpby(3, 5), pe_limit=1)
    As = es.compile(K.axpby(3, 5), pe_limit=1)
    assert ap.n_shots > 1 and "multi-shot" in ap.features
    x, y = (rng.integers(-100, 100, 48).astype(np.int32) for _ in range(2))
    got = ep.run(ap, {"x": x, "y": y})
    want = es.run(As, {"x": x, "y": y})
    np.testing.assert_array_equal(got["out"], want["out"])
    assert ep.tally.total == es.tally.total


@pytest.mark.parametrize("client", ["gemm", "gesummv", "2mm"])
def test_engine_clients_on_pallas_match_numpy(client):
    """The Table II multi-shot benchmark clients (mac3/mac2x reduction
    shots + epilogues) run whole on the pallas backend."""
    from repro.engine import clients
    eng = _mem_engine("pallas")
    if client == "gemm":
        A = rng.integers(-9, 9, (5, 8)).astype(np.int32)
        B = rng.integers(-9, 9, (8, 7)).astype(np.int32)
        C = rng.integers(-9, 9, (5, 7)).astype(np.int32)
        want = (3 * (A.astype(np.int64) @ B) + 2 * C).astype(np.int32)
        clients.run_gemm(eng, 3, A, B, 2, C)
        np.testing.assert_array_equal(C, want)
    elif client == "gesummv":
        N = 6
        A = rng.integers(-9, 9, (N, N)).astype(np.int32)
        B = rng.integers(-9, 9, (N, N)).astype(np.int32)
        x = rng.integers(-9, 9, N).astype(np.int32)
        y = np.zeros(N, dtype=np.int32)
        clients.run_gesummv(eng, 2, 3, A, B, x, y)
        want = (2 * (A.astype(np.int64) @ x)
                + 3 * (B.astype(np.int64) @ x)).astype(np.int32)
        np.testing.assert_array_equal(y, want)
    else:
        A = rng.integers(-5, 5, (4, 6)).astype(np.int32)
        B = rng.integers(-5, 5, (6, 5)).astype(np.int32)
        C = rng.integers(-5, 5, (5, 4)).astype(np.int32)
        Dm = rng.integers(-5, 5, (4, 4)).astype(np.int32)
        want = (2 * (A.astype(np.int64) @ B @ C) + 3 * Dm).astype(np.int32)
        clients.run_2mm(eng, 2, 3, A, B, C, Dm)
        np.testing.assert_array_equal(Dm, want)
    assert eng.stats.lane_batches > 0     # shot batches rode padded grids


# ---------------------------------------------------------------------------
# lane batching: one padded grid == N per-request dispatches, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [lambda: K.mac3(16), K.fft_butterfly])
def test_lane_batched_flush_matches_per_request_run(maker):
    g = maker()
    eng, ref = _mem_engine("pallas"), _mem_engine("pallas")
    art, art_r = eng.compile(g), ref.compile(g)
    batch = [_inputs(g, 16) for _ in range(5)]
    handles = [eng.submit(art, dict(ins)) for ins in batch]
    eng.flush()
    assert eng.stats.lane_batches == 1 and eng.stats.lane_requests == 5
    for h, ins in zip(handles, batch):
        want = ref.run(art_r, dict(ins))
        for o in want:
            np.testing.assert_array_equal(h.result()[o], want[o])
    # executor agreement too (the 5-way contract, spot-checked here)
    for h, ins in zip(handles, batch):
        want = execute(g, ins)
        for o in want:
            np.testing.assert_array_equal(h.result()[o], want[o])


def test_lane_batching_requires_equal_lengths():
    g = K.relu()
    eng = _mem_engine("pallas")
    art = eng.compile(g)
    eng.submit(art, {"x": np.ones(16, np.int32)})
    eng.submit(art, {"x": np.ones(24, np.int32)})
    eng.flush()      # incompatible lengths fall back to two separate grids
    assert eng.stats.lane_batches == 0
    assert eng.stats.requests == 2


# ---------------------------------------------------------------------------
# named capability diagnostics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker,feature,fragment", [
    (K.dither, "loop-state", "loop-carried back edge"),
    (K.find2min, "loop-state", "loop-carried back edge"),
    (lambda: K.div_loop(7), "recirculation", "recirculation edge"),
    (K.find2min_brmg, "loop-state", "loop-carried back edge"),
])
def test_rejection_names_feature(maker, feature, fragment):
    g = maker()
    assert feature in dfg_features(g)
    eng = _mem_engine("pallas")
    with pytest.raises(CapabilityError) as ei:
        eng.compile(g)
    msg = str(ei.value)
    assert feature in msg and fragment in msg
    # sim still takes everything
    _mem_engine("sim").compile(g)


def test_rejection_names_segmented_reduction():
    """emit_every that is neither 0 nor the stream length is a dispatch-
    time rejection naming the node (lengths are unknown at DFG compile)."""
    from repro.kernels.fabric_reduce import run_dfg
    g = K.mac1(4)                      # emit_every=4
    ins = {k: np.ones(12, np.int32) for k in g.inputs}
    with pytest.raises(CapabilityError, match=r"'s' emits every 4 tokens"):
        run_dfg(g, ins)


def test_segmented_reduction_fails_at_submit_not_mid_flush():
    """A request the backend cannot run must be refused at submit() with
    the queue untouched — an accepted neighbor request must still execute
    at the next flush (no stranded handles)."""
    eng = _mem_engine("pallas")
    good = eng.compile(K.relu())
    bad = eng.compile(K.mac1(4))       # length unknown at DFG compile
    h1 = eng.submit(good, {"x": np.arange(12, dtype=np.int32)})
    with pytest.raises(CapabilityError, match="emits every 4 tokens"):
        eng.submit(bad, {k: np.ones(12, np.int32) for k in bad.dfg.inputs})
    h2 = eng.submit(good, {"x": np.arange(12, dtype=np.int32) - 6})
    eng.flush()
    for h in (h1, h2):
        assert h.result()["out"].shape == (12,)


def test_rejection_names_interior_reduction():
    b = D.DFG.build("acc_interior")
    x = b.inp("x")
    acc = b.alu("acc", AluOp.ADD, x, acc_init=0, emit_every=0)
    post = b.alu("post", AluOp.MUL, acc, const_b=2)
    b.out("out", post)
    g = b.done()
    assert "reduction-interior" in dfg_features(g)
    with pytest.raises(CapabilityError, match="interior"):
        _mem_engine("pallas").compile(g)


def test_rejection_names_nonassociative_reduction_op():
    b = D.DFG.build("acc_shift")
    x = b.inp("x")
    acc = b.alu("acc", AluOp.SHR, x, acc_init=-1, emit_every=0)
    b.out("out", acc)
    g = b.done()
    assert "reduction-op" in dfg_features(g)
    with pytest.raises(CapabilityError, match="non-associative"):
        _mem_engine("pallas").compile(g)


def test_rejection_names_subrate_output():
    """An unmerged branch leg drained by an OMN is a data-dependent-length
    stream — not expressible as a static pallas output shape."""
    b = D.DFG.build("leg_out")
    x = b.inp("x")
    c = b.cmp("c", CmpOp.GTZ, x)
    br = b.branch("br", x, c)
    t = b.alu("t", AluOp.ADD, br, const_b=1, a_port="t")
    f = b.alu("f", AluOp.SUB, br, const_b=1, a_port="f")
    b.out("out_t", t)
    b.out("out_f", f)
    g = b.done()
    assert "subrate-output" in dfg_features(g)
    with pytest.raises(CapabilityError, match="sub-rate"):
        from repro.kernels.fabric_reduce import run_dfg
        run_dfg(g, {"x": np.arange(-4, 4, dtype=np.int32)})


def test_rejection_names_subrate_reduction():
    """An accumulator paced by a branch leg fires only on arriving tokens;
    a speculative tile-reduce would fold every lane — must reject by name,
    never silently diverge."""
    b = D.DFG.build("leg_acc")
    x = b.inp("x")
    c = b.cmp("c", CmpOp.GTZ, x)
    br = b.branch("br", x, c)
    at = b.alu("at", AluOp.ADD, br, a_port="t", acc_init=0, emit_every=0)
    af = b.alu("af", AluOp.ADD, br, a_port="f", acc_init=0, emit_every=0)
    b.out("out_t", at)
    b.out("out_f", af)
    g = b.done()
    assert "reduction-subrate" in dfg_features(g)
    from repro.kernels.fabric_reduce import run_dfg
    with pytest.raises(CapabilityError, match="sub-rate"):
        run_dfg(g, {"x": np.array([3, -2, 5, -1], np.int32)})


def test_nonreducible_merge_raises_not_silently_selects():
    """A MERGE whose legs are not complementary branch paths (e.g. two
    full-rate streams — an arrival-ordered alternating merge) cannot be
    lowered as a select; the jnp evaluator must raise, not return leg a."""
    import jax.numpy as jnp
    from repro.kernels.ref import eval_dfg_elementwise
    b = D.DFG.build("bad_merge")
    x, y = b.inp("x"), b.inp("y")
    m = b.merge("m", x, y)
    b.out("out", m)
    g = b.done()
    with pytest.raises(ValueError, match="select-reducible"):
        eval_dfg_elementwise(g, {"x": jnp.arange(4), "y": jnp.arange(4)})
    # the capability gate flags it structurally too
    assert "merge-order" in dfg_features(g)


def test_rejection_names_merge_order():
    """A MERGE joining the same-polarity legs of two different branches is
    arrival-ordered, not a select — the gate must reject it at compile
    with the named feature (never a mid-flush ValueError)."""
    b = D.DFG.build("tt_merge")
    x, y = b.inp("x"), b.inp("y")
    cx = b.cmp("cx", CmpOp.GTZ, x)
    cy = b.cmp("cy", CmpOp.GTZ, y)
    brx = b.branch("brx", x, cx)
    bry = b.branch("bry", y, cy)
    m = b.merge("m", brx, bry, a_port="t", b_port="t")
    b.out("out", m)
    g = b.done()
    feats = dfg_features(g)
    assert "merge-order" in feats
    from repro.engine.capabilities import backend_skip_reason
    assert backend_skip_reason(g, 8, "pallas") is not None
    with pytest.raises(CapabilityError, match="arrival-ordered"):
        _mem_engine("pallas").compile(g)


def test_same_predicate_cross_branch_merge_is_reducible():
    """Two branches steered by ONE predicate wire (the find2min_brmg
    schema, acyclic here): their opposite legs ARE complementary — the
    provenance check keys on the predicate wire, not the branch node."""
    b = D.DFG.build("xbranch_merge")
    x, y = b.inp("x"), b.inp("y")
    c = b.cmp("c", CmpOp.GTZ, x)
    brx = b.branch("brx", x, c)
    bry = b.branch("bry", y, c)
    m = b.merge("m", brx, bry, a_port="t", b_port="f")
    b.out("out", m)
    g = b.done()
    assert "merge-order" not in dfg_features(g)
    from repro.kernels.fabric_reduce import run_dfg
    ins = {"x": np.array([3, -2, 5, -1], np.int32),
           "y": np.array([7, 8, 9, 10], np.int32)}
    got = run_dfg(g, ins)
    want = execute(g, ins)
    np.testing.assert_array_equal(got["out"], want["out"])


def test_shared_runner_keeps_backend_isolation():
    """Engines of different backends may share one ShotRunner (the
    multishot helpers do); a pallas dispatch must not leave its value
    substrate bound to the shared runner."""
    from repro.core.executor import execute
    from repro.core.multishot import ShotRunner
    r = ShotRunner()
    ep = Engine(backend="pallas", runner=r,
                cache=ArtifactCache(memory_only=True))
    es = Engine(backend="sim", runner=r,
                cache=ArtifactCache(memory_only=True))
    art = ep.compile(K.relu())
    x = np.arange(-4, 4, dtype=np.int32)
    np.testing.assert_array_equal(ep.run(art, {"x": x})["out"],
                                  np.maximum(x, 0))
    assert r.value_fn is execute
    # the sim engine on the same runner still takes loop-state kernels
    arts = es.compile(K.dither())
    out = es.run(arts, {"x": np.arange(8, dtype=np.int32)})
    assert out["out"].shape == (8,)


def test_mixed_length_request_fails_at_submit():
    """Stream-length disagreement is a submit-time rejection (queue
    untouched), not a mid-flush surprise."""
    eng = _mem_engine("pallas")
    art = eng.compile(K.vadd())
    with pytest.raises(ValueError, match="share a length"):
        eng.submit(art, {"x": np.ones(8, np.int32),
                         "y": np.ones(16, np.int32)})
    assert eng.pending() == 0


def test_missing_input_fails_at_submit():
    eng = _mem_engine("pallas")
    art = eng.compile(K.vadd())
    with pytest.raises(ValueError, match="missing input stream"):
        eng.submit(art, {"x": np.ones(8, np.int32)})
    assert eng.pending() == 0


def test_poisoned_request_does_not_wedge_flush():
    """A request whose execution fails mid-flush is dropped, not
    re-queued: requests behind it survive and a retry flush runs them."""
    eng = _mem_engine("sim")
    art = eng.compile(K.relu())
    h1 = eng.submit(art, {"x": np.arange(8, dtype=np.int32)})
    bad = eng.submit(art, {"x": np.full(8, 99, dtype=np.int32)})
    h2 = eng.submit(art, {"x": np.arange(8, dtype=np.int32) + 1})
    real = eng._value_fn

    def flaky(g, ins):
        if int(ins["x"][0]) == 99:
            raise RuntimeError("injected kernel failure")
        return real(g, ins)

    eng._value_fn = flaky
    with pytest.raises(RuntimeError, match="injected"):
        eng.flush()
    assert not bad._done
    assert eng.pending() == 1            # h2 survived, bad was dropped
    eng.flush()
    np.testing.assert_array_equal(h1.result()["out"],
                                  np.maximum(np.arange(8), 0))
    np.testing.assert_array_equal(h2.result()["out"],
                                  np.maximum(np.arange(8) + 1, 0))


def test_lane_grid_failure_falls_back_to_per_request(monkeypatch):
    """If a lane-batched grid fails as a unit, the flush re-dispatches its
    members individually — innocent lane neighbors are never poisoned."""
    eng = _mem_engine("pallas")
    art = eng.compile(K.relu())
    hs = [eng.submit(art, {"x": np.arange(8, dtype=np.int32) + i})
          for i in range(3)]
    monkeypatch.setattr(eng, "_run_lanes",
                        lambda batch: (_ for _ in ()).throw(
                            RuntimeError("grid failed")))
    eng.flush()
    assert eng.stats.lane_batches == 0
    for i, h in enumerate(hs):
        np.testing.assert_array_equal(h.result()["out"],
                                      np.maximum(np.arange(8) + i, 0))


def test_engine_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        Engine(backend="cuda")


# ---------------------------------------------------------------------------
# capability feature analysis itself
# ---------------------------------------------------------------------------

def test_feature_analysis_on_kernels_lib():
    assert dfg_features(K.relu()) == frozenset({"branch-merge"}) or \
        dfg_features(K.relu()) == frozenset()      # relu is MUX-based
    assert "reduction" in dfg_features(K.mac3(8))
    f2 = dfg_features(K.find2min())
    assert "loop-state" in f2 and "reduction-interior" in f2
    fd = dfg_features(K.div_loop(7))
    assert {"recirculation", "branch-merge"} <= fd


def test_artifact_carries_features():
    eng = _mem_engine("sim")
    art = eng.compile(K.mac3(8))
    assert "reduction" in art.features
    clone = type(art).from_bytes(art.to_bytes())
    assert clone.features == art.features
